"""PR-5 tentpole: the prefix-cache plane (serving/prefixcache.py).

Two trajectories on the ``multi_turn_chat`` workload (sessions replaying
their whole conversation every turn), identical workload and virtual
clock per comparison:

  * **cold vs warm** — prefix cache off vs on. Warm turns (turn >= 1)
    adopt the previous turn's committed KV by slot reference and prefill
    only the new turn chunk, so warm-turn TTFT drops and the hit rate
    (adopted tokens / warm-turn prompt prefix tokens) is the headline.
  * **recovery-with-prefix vs recovery-cold** — an AW failure mid-run
    with checkpoint-backed prefix restoration on vs off. With
    restoration, the dead AW's cached session prefixes are rebuilt from
    the checkpoint store on the failover AW, so post-failure turns still
    hit; without it, every surviving session pays a cold re-prefill.

Prefill work is charged to the virtual clock per real token
(``prefill_token_time``), so skipped prefill is visible as TTFT, exactly
as it would be on hardware. Results accumulate in
benchmarks/results/prefix.json; ``BENCH_SMOKE=1`` shrinks the run for CI.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from benchmarks.common import Row, pct, reduced_engine
from repro.data.workloads import make_workload
from repro.serving.scheduler import FailurePlan, run_serving

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "prefix.json")

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

STEP = 0.02
TOKEN_TIME = 0.002


def _engine(prefix_slots, prefix_restore=True, **kw):
    return reduced_engine(seed=0, max_batch=8, max_seq=96,
                          chunk_token_budget=16,
                          placement="session_affinity",
                          prefix_cache_slots=prefix_slots,
                          prefix_restore=prefix_restore, **kw)


def _workload(turns):
    wl = make_workload("multi_turn_chat", rate_rps=8.0,
                       duration=1.0 if SMOKE else 2.0, seed=1,
                       chat_turns=turns, chat_turn_gap=0.7,
                       chat_max_new=4)
    assert wl, "multi_turn_chat drew no sessions"
    return wl


def _turn_of(rid: str) -> int:
    return int(rid.rsplit("-t", 1)[1])


def _summarize(m, wl):
    warm_rids = {w.request_id for w in wl if w.turn >= 1}
    warm_ttft = np.asarray([v for rid, v in m.ttft.items()
                            if rid in warm_rids and v >= 0])
    cold_ttft = np.asarray([v for rid, v in m.ttft.items()
                            if rid not in warm_rids and v >= 0])
    # hit rate over the prefix tokens warm turns would otherwise prefill
    warm_prefix_tokens = sum(w.prompt_len - 1 for w in wl
                             if w.request_id in warm_rids)
    pf = m.gateway["prefix"]
    return {
        "finished": len(m.finished),
        "requests": len(wl),
        "ttft_warm_turn_p50_s": pct(warm_ttft, 50),
        "ttft_warm_turn_p95_s": pct(warm_ttft, 95),
        "ttft_first_turn_p50_s": pct(cold_ttft, 50),
        "prefix": pf,
        "hit_rate": pf["hit_tokens"] / warm_prefix_tokens
        if warm_prefix_tokens else 0.0,
        "prefill_real_tokens": m.prefill.get("chunked", {}).get(
            "real_tokens", 0),
    }


def _measure_cold_vs_warm():
    wl = _workload(turns=3 if SMOKE else 5)
    out = {"workload": "multi_turn_chat", "requests": len(wl),
           "sessions": len({w.session for w in wl})}
    outputs = {}
    for label, slots in (("cold", 0), ("warm", 3)):
        eng = _engine(slots)
        m = run_serving(eng, wl, duration=600.0, step_time=STEP,
                        prefill_token_time=TOKEN_TIME)
        out[label] = _summarize(m, wl)
        outputs[label] = m.outputs
    # exactness audit rides the bench: warm == cold, token for token
    mismatches = sum(1 for rid, toks in outputs["cold"].items()
                     if outputs["warm"].get(rid) != toks)
    out["output_mismatches"] = mismatches
    out["warm_ttft_improvement_x"] = (
        out["cold"]["ttft_warm_turn_p50_s"] /
        max(out["warm"]["ttft_warm_turn_p50_s"], 1e-9))
    return out


def _measure_recovery():
    """AW failure between turns: with prefix restoration the failover AW
    inherits the dead AW's hot session prefixes; without it the sessions
    re-prefill cold. The failure lands early so most turns are
    post-failure."""
    import zlib
    wl = _workload(turns=3 if SMOKE else 5)
    t_fail = 0.9
    # fail the AW the affinity hash pins the most sessions to — the one
    # holding hot prefixes (failing an empty AW proves nothing)
    homes = {w.session: zlib.crc32(w.session.encode()) % 2
             for w in wl if w.turn == 0}
    aw_fail = max(set(homes.values()),
                  key=lambda a: sum(1 for h in homes.values() if h == a))
    # the comparison population: for each session whose prefixes died
    # with the failed AW, the FIRST warm turn arriving after the failure
    # — later turns re-warm the cache in both variants, so this is the
    # turn restoration actually saves
    first_post: dict = {}
    for w in sorted(wl, key=lambda w: w.arrival):
        if w.arrival > t_fail and w.turn >= 1 and \
                homes.get(w.session) == aw_fail and \
                w.session not in first_post:
            first_post[w.session] = w.request_id
    post_rids = set(first_post.values())
    out = {"workload": "multi_turn_chat", "t_fail": t_fail,
           "failed_aw": aw_fail,
           "post_failure_warm_turns": len(post_rids)}
    from repro.core.orchestrator import Orchestrator
    for label, restore in (("recovery_with_prefix", True),
                           ("recovery_cold", False)):
        eng = _engine(3, prefix_restore=restore)
        orch = Orchestrator(eng, worker_init_time=0.5,
                            weight_push_time=0.1)
        m = run_serving(eng, wl, duration=600.0, orchestrator=orch,
                        failures=[FailurePlan(t_fail, "aw", aw_fail)],
                        step_time=STEP, prefill_token_time=TOKEN_TIME)
        post_ttft = np.asarray([v for rid, v in m.ttft.items()
                                if rid in post_rids and v >= 0])
        out[label] = {
            "finished": len(m.finished),
            "prefix": m.gateway["prefix"],
            "post_failure_ttft_p50_s": pct(post_ttft, 50),
        }
    wp = out["recovery_with_prefix"]["prefix"]
    cp = out["recovery_cold"]["prefix"]
    out["restored_prefixes"] = wp["restored"]
    out["hit_tokens_delta"] = wp["hit_tokens"] - cp["hit_tokens"]
    return out


def _drain(eng, hs):
    hs = hs if isinstance(hs, list) else [hs]
    steps = 0
    while not all(h.done() for h in hs) and steps < 600:
        eng.step()
        for rid in [r.rid for r in eng.requests.values() if r.done]:
            eng.release_request(rid)
        steps += 1
    for rid in [r.rid for r in eng.requests.values() if r.done]:
        eng.release_request(rid)


def _measure_paged():
    """PR-8 tentpole: the paged KV plane.

      * resident sessions — N sessions sharing a 32-token base prefix run
        to completion on the SAME KV budget (max_batch x max_seq). The
        contiguous cache retains at most prefix_cache_slots whole slots
        per AW; the paged cache pins refcounted pages, shares the base
        pages across entries, and keeps every session's own suffix
        resident (>= 1.5x is the acceptance bar). Residency is counted
        per session as "my own next turn would hit past the shared base".
      * cross-AW hit rate — the saturated-home regime: the AW holding the
        hot prefix has zero slot headroom when new sessions arrive. The
        per-AW baseline cannot route to it (capacity-gated match scan)
        and misses; the global index + migration replays the prefix onto
        the free AW and keeps hitting.
      * steps/s — decode throughput of the block-table attention path vs
        the contiguous path, same workload (trace time excluded by a
        warmup batch).
    """
    import time

    from repro.serving.api import RequestSpec

    rng = np.random.default_rng(7)
    base = rng.integers(1, 500, size=(32,)).astype(np.int32)
    n_sessions = 10
    tails = [rng.integers(1, 500, size=(8,)).astype(np.int32)
             for _ in range(n_sessions)]
    prompts = [np.concatenate([base, t]) for t in tails]
    out = {"sessions": n_sessions, "shared_base_tokens": int(len(base))}

    # -- resident shared-prefix sessions at a fixed KV budget --------------
    outputs, resident, pool_stats = {}, {}, {}
    for label, kw in (("contiguous", {}),
                      ("paged", dict(kv_page_tokens=16,
                                     prefix_global_index=True))):
        eng = _engine(3, **kw)
        outputs[label] = {}
        for i, p in enumerate(prompts):
            h = eng.client.submit(RequestSpec(
                rid=f"s{i}-0", prompt=p, max_new=2, session=f"s{i}"))
            _drain(eng, h)
            outputs[label][f"s{i}-0"] = list(h.tokens())
        # a session is resident iff its own suffix (not just the shared
        # base every entry carries) is still adoptable
        res = 0
        for i, p in enumerate(prompts):
            nxt = np.concatenate(
                [p, np.asarray(outputs[label][f"s{i}-0"], np.int32)])
            best = max((w.prefix_cache.match_len(nxt) for w in eng.aws
                        if w.prefix_cache is not None), default=0)
            res += int(best >= len(p))
        resident[label] = res
        if eng.pages is not None:
            eng.pages.check()
            pool_stats = eng.pages.stats()
    out["resident_sessions"] = {
        "contiguous": resident["contiguous"], "paged": resident["paged"],
        "ratio_x": resident["paged"] / max(resident["contiguous"], 1),
        "paged_pool": pool_stats}
    out["identity_mismatches"] = sum(
        1 for rid, toks in outputs["contiguous"].items()
        if outputs["paged"].get(rid) != toks)

    # -- cross-AW hit rate under a saturated home --------------------------
    cross = {}
    n_arrivals = 4 if SMOKE else 6
    for label, kw in (("per_aw", dict(kv_page_tokens=16)),
                      ("global", dict(kv_page_tokens=16,
                                      prefix_global_index=True,
                                      prefix_migrate=True))):
        eng = _engine(3, **kw)
        h = eng.client.submit(RequestSpec(rid="seed-0", prompt=prompts[0],
                                          max_new=2, session="seed"))
        _drain(eng, h)
        # the AW holding the hot prefix loses all slot headroom (long
        # residents in a real cluster; pinned directly here)
        if eng.prefix_plane.global_index is not None:
            home = eng.prefix_plane.global_index.match(prompts[1])[1]
        else:
            home = max(range(len(eng.aws)),
                       key=lambda a: eng.aws[a].prefix_cache.match_len(
                           prompts[1]))
        held = [eng.aws[home].slots.alloc()
                for _ in range(eng.aws[home].slots.free_count())]
        base_hits = eng.gateway.stats.prefix_hits
        for i in range(1, 1 + n_arrivals):
            h = eng.client.submit(RequestSpec(
                rid=f"g{i}-0", prompt=prompts[i], max_new=2,
                session=f"g{i}"))
            _drain(eng, h)
        for s in held:
            eng.aws[home].slots.release(s)
        st = eng.gateway.stats
        cross[label] = {
            "arrivals": n_arrivals,
            "hit_rate": (st.prefix_hits - base_hits) / n_arrivals,
            "global_hits": st.prefix_global_hits,
            "migrated": st.prefix_migrated}
        if eng.pages is not None:
            eng.pages.check()
    out["cross_aw"] = cross

    # -- decode throughput: block-table kernel path vs contiguous ----------
    perf = {}
    max_new = 6 if SMOKE else 16
    for label, kw in (("contiguous", {}),
                      ("paged", dict(kv_page_tokens=16))):
        eng = _engine(0, **kw)
        for rnd in ("warmup", "timed"):
            hs = [eng.client.submit(RequestSpec(
                rid=f"{rnd}{i}-0",
                prompt=rng.integers(1, 500, size=(12,)).astype(np.int32),
                max_new=max_new, session=f"{rnd}{i}"))
                for i in range(4)]
            t0, s0 = time.monotonic(), eng.steps
            _drain(eng, hs)
            if rnd == "timed":
                perf[label] = (eng.steps - s0) / max(
                    time.monotonic() - t0, 1e-9)
    out["decode_steps_per_s"] = {
        "contiguous": perf["contiguous"], "paged": perf["paged"],
        "paged_vs_contiguous_x": perf["paged"] / perf["contiguous"]}
    return out


def run():
    payload = {"bench": "prefix", "multi_turn_chat": None,
               "recovery": None, "paged": None}
    s = _measure_cold_vs_warm()
    payload["multi_turn_chat"] = s
    rows = [Row(
        "prefix/multi_turn_chat/ttft_warm_turn_p50/warm",
        s["warm"]["ttft_warm_turn_p50_s"] * 1e6,
        f"cold={s['cold']['ttft_warm_turn_p50_s']*1e3:.0f}ms "
        f"improvement={s['warm_ttft_improvement_x']:.1f}x "
        f"hit_rate={s['warm']['hit_rate']:.2f} "
        f"mismatches={s['output_mismatches']}")]
    r = _measure_recovery()
    payload["recovery"] = r
    rows.append(Row(
        "prefix/recovery/post_failure_ttft_p50/with_prefix",
        r["recovery_with_prefix"]["post_failure_ttft_p50_s"] * 1e6,
        f"cold_recovery="
        f"{r['recovery_cold']['post_failure_ttft_p50_s']*1e3:.0f}ms "
        f"restored={r['restored_prefixes']} "
        f"hit_tokens_delta={r['hit_tokens_delta']}"))
    p = _measure_paged()
    payload["paged"] = p
    rows.append(Row(
        "prefix/paged/resident_sessions/ratio",
        p["resident_sessions"]["ratio_x"] * 1e6,
        f"paged={p['resident_sessions']['paged']}/{p['sessions']} "
        f"contig={p['resident_sessions']['contiguous']}/{p['sessions']} "
        f"cross_aw_hit_rate="
        f"{p['cross_aw']['global']['hit_rate']:.2f}"
        f"(per_aw {p['cross_aw']['per_aw']['hit_rate']:.2f}) "
        f"migrated={p['cross_aw']['global']['migrated']} "
        f"steps_ratio="
        f"{p['decode_steps_per_s']['paged_vs_contiguous_x']:.2f}x "
        f"mismatches={p['identity_mismatches']}"))
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows
