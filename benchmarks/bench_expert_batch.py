"""Paper Appendix B: sparse activation fragments tokens into small
per-expert batches; expert GEMMs only reach the efficiency knee at moderate
batch sizes.

(1) per-expert batch-size distribution from a real router at total batch
    ~821 (the paper's Qwen3-MoE measurement point);
(2) expert-FFN latency vs batch size (CPU wall time; the knee shape is what
    matters, absolute scale is CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.kernels import ops as kops


def run():
    rows = []
    # (1) routing fragmentation: E=128 top-8 (Qwen3-MoE-like), T=821
    t, e, k = 821, 128, 8
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (t, e))
    _, idx = jax.lax.top_k(jax.nn.softmax(logits), k)
    counts = np.bincount(np.asarray(idx).ravel(), minlength=e)
    rows.append(Row("appB/expert_batch_dist", 0.0,
                    f"T={t} topk={k} mean={counts.mean():.1f} "
                    f"p50={np.percentile(counts,50):.0f} "
                    f"p95={np.percentile(counts,95):.0f} "
                    f"max={counts.max()} frac<200={np.mean(counts<200):.2f}"
                    "(paper:most<200)"))

    # (2) expert GEMM latency vs batch (knee point)
    d, f = 256, 512
    ks = jax.random.split(key, 3)
    wg = jax.random.normal(ks[0], (1, d, f)) * 0.05
    wu = jax.random.normal(ks[1], (1, d, f)) * 0.05
    wd = jax.random.normal(ks[2], (1, f, d)) * 0.05
    prev = None
    for bs in (8, 32, 128, 256, 512):
        x = jax.random.normal(key, (1, bs, d))
        fn = jax.jit(lambda xx: kops.expert_ffn(xx, wg, wu, wd))
        fn(x).block_until_ready()
        tm = time_fn(lambda: fn(x).block_until_ready(), warmup=2, iters=8)
        per_tok = tm / bs
        d_str = f"us/token={per_tok*1e6:.2f}"
        if prev is not None:
            d_str += f" gain_vs_prev={prev/per_tok:.2f}x"
        prev = per_tok
        rows.append(Row(f"appB/expert_gemm/batch={bs}", tm * 1e6, d_str))
    return rows
