"""Paper Table 1: profiled deployment parameters.

GPU-side constants (T_w, t_pre, t_dec, g_pre, g_dec) come from the paper's
Table 1; we additionally MEASURE our own engine's per-layer prefill/decode
times on CPU (reduced Mixtral) — these calibrate the failover simulator's
relative terms and demonstrate the measurement path.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row, reduced_engine, time_fn
from repro.serving.api import RequestSpec
from repro.core import costmodel as cm


def run():
    rows = []
    for p in (cm.VLLM_PROFILE, cm.MEGASCALE_PROFILE):
        rows.append(Row(f"table1/{p.name}/T_w", p.T_w * 1e6,
                        f"t_pre={p.t_pre*1e3}ms t_dec={p.t_dec*1e3}ms "
                        f"g_pre={p.g_pre} g_dec={p.g_dec}"))

    eng = reduced_engine()
    prompt = np.arange(1, 11, dtype=np.int32)
    eng.client.submit(RequestSpec(rid="r0", prompt=prompt, max_new=64))

    t_step = time_fn(lambda: eng.step(), warmup=3, iters=10)
    n_layers = eng.cfg.num_layers
    t_dec_layer = t_step / n_layers
    rows.append(Row("table1/ours-cpu/t_dec_layer", t_dec_layer * 1e6,
                    f"decode_step={t_step*1e3:.2f}ms L={n_layers}"))

    eng2 = reduced_engine(seed=1)

    def prefill_once():
        eng2.client.submit(RequestSpec(rid=f"p{len(eng2.requests)}",
                                       prompt=prompt, max_new=1))

    t_pre = time_fn(prefill_once, warmup=1, iters=3)
    rows.append(Row("table1/ours-cpu/t_pre_layer",
                    t_pre / n_layers * 1e6,
                    f"prefill={t_pre*1e3:.2f}ms prompt=10tok"))
    return rows
