"""Paper Fig. 4: inference stall time and re-execution cost vs failure point
(decoded-token index i) for monolithic (MO), decoupled-AW and decoupled-EW
failures — Eq. (1)-(4) audit — plus Tarragon's curves for contrast."""
from __future__ import annotations

from benchmarks.common import Row
from repro.core import costmodel as cm

L = 32
LAYER = L // 2
POINTS = (8, 64, 128, 256, 512)


def run():
    rows = []
    t = cm.TarragonProfile()
    for p in (cm.VLLM_PROFILE, cm.MEGASCALE_PROFILE):
        for i in POINTS:
            mo = cm.stall_monolithic(p, L, LAYER, i)
            ew = cm.stall_decoupled_ew(p, L, LAYER, i)
            taw = cm.stall_tarragon_aw(p, t, L, LAYER, i,
                                       tokens_to_restore=10 + i)
            tew = cm.stall_tarragon_ew(p, t, L, LAYER, i)
            rows.append(Row(
                f"fig4/stall/{p.name}/i={i}", mo * 1e6,
                f"ew={ew:.2f}s tarragon_aw={taw:.3f}s "
                f"tarragon_ew={tew:.3f}s"))
            g_mo = cm.gputime_monolithic(p, L, LAYER, i)
            g_ew = cm.gputime_decoupled_ew(p, L, LAYER, i)
            rows.append(Row(
                f"fig4/gputime/{p.name}/i={i}", g_mo * 1e6,
                f"ew={g_ew:.4f} ratio={g_mo/max(g_ew,1e-9):.0f}x"))
    # paper observation: decode failure at i=64 vs 128-tok-prompt prefill
    p = cm.MEGASCALE_PROFILE
    dec = ((64 - 1) * L + LAYER) * p.t_dec
    pre = L * p.t_pre
    rows.append(Row("fig4/decode_vs_prefill_replay", dec * 1e6,
                    f"{dec/pre:.1f}x_prefill(paper~19x)"))
    return rows
