"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,appC]

Perf-regression tracker: ``--compare`` diffs the fresh ``results/*.json``
against the committed ``baselines/*.json`` with per-metric tolerance
bands, prints a regression table, and exits nonzero on any breach.
Baselines are regenerated with ``--rebaseline`` (run the benches first,
then copy results into baselines/ — commit the diff deliberately).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import time
import traceback

MODULES = [
    "bench_profile",       # Table 1
    "bench_costmodel",     # Fig 4 (Eq 1-4 audit)
    "bench_failover",      # Fig 9 / §7.2 headline
    "bench_steady_state",  # Fig 10/11 / §7.3
    "bench_elastic",       # PR-3 tentpole: elastic EW plane
    "bench_prefix",        # PR-5 tentpole: prefix-cache plane
    "bench_soak",          # PR-10 tentpole: watchdog soak smoke
    "bench_checkpoint",    # §7.4 + App C
    "bench_restoration",   # Fig 12
    "bench_expert_batch",  # App B
    "bench_shadow",        # App D
    "bench_ablation",      # App F
    "bench_traffic",       # Fig 8
    "bench_roofline",      # §Roofline (dry-run artifacts)
]

_DIR = os.path.dirname(__file__)
RESULTS_DIR = os.path.join(_DIR, "results")
BASELINES_DIR = os.path.join(_DIR, "baselines")

# Per-metric tolerance bands for --compare. Modes:
#   equal          — any change is a breach (determinism claims: mismatch
#                    counts, jit trace counts, watchdog trips)
#   higher_better  — breach when fresh < baseline * (1 - tol)
#   lower_better   — breach when fresh > baseline * (1 + tol)
# Bands are generous because smoke-mode virtual-clock metrics are
# deterministic but shift legitimately when scheduling behavior changes;
# the equal-mode rows are the hard invariants.
BASELINE_SPECS = [
    ("steady_state.json", "mixed_slo.interactive_ttft_p99_improvement_x",
     "higher_better", 0.30),
    ("steady_state.json", "controller.interactive_ttft_p99_ratio",
     "lower_better", 0.30),
    ("elastic.json", "rebalance.imbalance_reduction",
     "higher_better", 0.20),
    ("elastic.json", "closed_loop.imbalance_mean_reduction_x",
     "higher_better", 0.20),
    ("elastic.json", "scale.decode_jit_traces", "equal", 0.0),
    ("prefix.json", "multi_turn_chat.output_mismatches", "equal", 0.0),
    ("prefix.json", "paged.identity_mismatches", "equal", 0.0),
    ("prefix.json", "multi_turn_chat.warm_ttft_improvement_x",
     "higher_better", 0.25),
    ("soak.json", "clean.watchdog_trips", "equal", 0.0),
    ("soak.json", "leak.detected", "equal", 0.0),
]


def _lookup(d: dict, dotted: str):
    for k in dotted.split("."):
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def compare(only=None) -> int:
    """Diff fresh results against committed baselines. Returns the number
    of breaches (0 = green). Missing results files are skipped (a partial
    --only run must not fail the benches it did not run); a missing
    metric inside an existing file IS a breach."""
    rows, breaches, skipped = [], 0, []
    for fname, path, mode, tol in BASELINE_SPECS:
        if only and not any(o in fname for o in only):
            continue
        bpath = os.path.join(BASELINES_DIR, fname)
        rpath = os.path.join(RESULTS_DIR, fname)
        if not os.path.exists(bpath):
            skipped.append(f"{fname} (no baseline committed)")
            continue
        if not os.path.exists(rpath):
            skipped.append(f"{fname} (no fresh results)")
            continue
        with open(bpath) as f:
            base = _lookup(json.load(f), path)
        with open(rpath) as f:
            fresh = _lookup(json.load(f), path)
        if base is None:
            skipped.append(f"{fname}:{path} (not in baseline)")
            continue
        if fresh is None:
            rows.append((fname, path, base, "MISSING", mode, "BREACH"))
            breaches += 1
            continue
        if mode == "equal":
            ok = fresh == base
        elif mode == "higher_better":
            ok = float(fresh) >= float(base) * (1.0 - tol)
        else:
            ok = float(fresh) <= float(base) * (1.0 + tol)
        rows.append((fname, path, base, fresh,
                     f"{mode}±{tol:g}" if tol else mode,
                     "ok" if ok else "BREACH"))
        if not ok:
            breaches += 1
    w = max((len(r[1]) for r in rows), default=10)
    print(f"{'file':<20} {'metric':<{w}} {'baseline':>12} "
          f"{'fresh':>12} {'band':<18} verdict")
    for fname, path, base, fresh, band, verdict in rows:
        fb = base if isinstance(base, (int, bool)) else f"{base:.4g}"
        ff = fresh if isinstance(fresh, (int, bool, str)) \
            else f"{fresh:.4g}"
        print(f"{fname:<20} {path:<{w}} {fb!s:>12} {ff!s:>12} "
              f"{band:<18} {verdict}")
    for s in skipped:
        print(f"# skipped: {s}", file=sys.stderr)
    print(f"# compare: {len(rows)} metrics, {breaches} breach(es)",
          file=sys.stderr)
    return breaches


def rebaseline(only=None):
    os.makedirs(BASELINES_DIR, exist_ok=True)
    files = sorted({s[0] for s in BASELINE_SPECS})
    for fname in files:
        if only and not any(o in fname for o in only):
            continue
        src = os.path.join(RESULTS_DIR, fname)
        if os.path.exists(src):
            shutil.copy(src, os.path.join(BASELINES_DIR, fname))
            print(f"# rebaselined {fname}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated substring filters on module names")
    ap.add_argument("--compare", action="store_true",
                    help="diff fresh results/*.json against committed "
                         "baselines/*.json and exit nonzero on breach "
                         "(does not run the benches)")
    ap.add_argument("--rebaseline", action="store_true",
                    help="copy fresh results over the committed baselines")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    if args.compare:
        breaches = compare(only)
        if breaches:
            raise SystemExit(2)
        return
    if args.rebaseline:
        rebaseline(only)
        return

    print("name,us_per_call,derived")
    failed = []
    for modname in MODULES:
        if only and not any(o in modname for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{modname}",
                             fromlist=["run"])
            for row in mod.run():
                print(row.csv())
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{modname},0.00,ERROR:{type(e).__name__}")
            failed.append(modname)
        print(f"# {modname} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
