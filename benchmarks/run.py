"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,appC]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "bench_profile",       # Table 1
    "bench_costmodel",     # Fig 4 (Eq 1-4 audit)
    "bench_failover",      # Fig 9 / §7.2 headline
    "bench_steady_state",  # Fig 10/11 / §7.3
    "bench_elastic",       # PR-3 tentpole: elastic EW plane
    "bench_prefix",        # PR-5 tentpole: prefix-cache plane
    "bench_checkpoint",    # §7.4 + App C
    "bench_restoration",   # Fig 12
    "bench_expert_batch",  # App B
    "bench_shadow",        # App D
    "bench_ablation",      # App F
    "bench_traffic",       # Fig 8
    "bench_roofline",      # §Roofline (dry-run artifacts)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated substring filters on module names")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failed = []
    for modname in MODULES:
        if only and not any(o in modname for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{modname}",
                             fromlist=["run"])
            for row in mod.run():
                print(row.csv())
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{modname},0.00,ERROR:{type(e).__name__}")
            failed.append(modname)
        print(f"# {modname} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
