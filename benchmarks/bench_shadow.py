"""Paper Appendix D: inactive shadow experts must not slow the datapath.

Compare decode-step latency: (a) Tarragon engine with a loaded-but-inactive
shadow bank, (b) MegaScale-style engine with no shadow slots, (c) Tarragon
with shadows ACTIVE (EW failed -> experts run from shadow slots). Also
report the shadow bank's memory budget (§5.3)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, reduced_engine, time_fn
from repro.serving.api import RequestSpec
from repro.core.shadow import shadow_memory_bytes
from repro.core import ert as ert_lib


def _step_time(eng):
    prompt = np.arange(1, 11, dtype=np.int32)
    eng.client.submit(RequestSpec(rid="r", prompt=prompt, max_new=200))
    return time_fn(lambda: eng.step(), warmup=3, iters=12)


def run():
    rows = []
    t_shadow = _step_time(reduced_engine(tarragon=True, checkpoint=False))
    t_none = _step_time(reduced_engine(tarragon=False, checkpoint=False))
    over = (t_shadow - t_none) / t_none * 100
    rows.append(Row("appD/inactive_shadow", t_shadow * 1e6,
                    f"no_shadow={t_none*1e6:.0f}us "
                    f"delta={over:+.1f}%(paper:~0)"))

    eng = reduced_engine(tarragon=True, checkpoint=False)
    eng.fail_ew(0)  # shadows become active
    t_active = _step_time(eng)
    rows.append(Row("appD/active_shadow", t_active * 1e6,
                    f"vs_inactive={(t_active-t_shadow)/t_shadow*100:+.1f}%"))

    # §5.3 memory budget at full scale (kimi-k2 geometry, bf16)
    p = ert_lib.default_placement(384, 16)
    b = shadow_memory_bytes(p, 7168, 2048)
    rows.append(Row("appD/shadow_mem_kimi", 0.0,
                    f"{b/2**30:.1f}GiB total "
                    f"({b/p.num_ew/2**30:.2f}GiB/EW, "
                    f"paper: ~2.5GB/expert DeepSeek-R1)"))
    return rows
