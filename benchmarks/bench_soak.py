"""Soak smoke with continuous health watchdogs (PR-10 forensics plane).

Two runs on a paged, chunked, preemptible engine with the watchdogs on:

* **clean soak** — a few hundred mixed-SLO requests with two injected
  faults (one AW, one EW). The acceptance bar: the watchdogs stay
  completely quiet — failover churn is *expected* behavior, and the
  disturbance suppression must keep the leak/stall detectors from
  mistaking it for degradation. The run also exercises the
  postmortem-on-demand path: the flight recorder's bundle is dumped to
  ``results/soak_postmortem.json`` at the end.
* **seeded-leak soak** — the same engine shape under a light steady
  trickle, with one KV page allocated-and-orphaned every few ticks (an
  injected allocator leak that keeps ``PagePool.check()`` green — only
  the watermark-trend detector can see it). The bar: the leak watchdog
  trips within its sliding window.

Writes benchmarks/results/soak.json; ``BENCH_SMOKE=1`` shrinks the
request count.
"""
from __future__ import annotations

import dataclasses
import json
import os

from benchmarks.common import Row, reduced_engine
from repro.core.costmodel import TarragonProfile
from repro.core.orchestrator import Orchestrator
from repro.data.workloads import make_workload
from repro.serving.scheduler import FailurePlan, run_serving

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "soak.json")
POSTMORTEM_PATH = os.path.join(os.path.dirname(__file__), "results",
                               "soak_postmortem.json")
SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

STEP = 0.02
PF_TOK = 0.002


def _cap(wl, prompt=16, max_new=8):
    return [dataclasses.replace(w, prompt_len=min(w.prompt_len, prompt),
                                max_new_tokens=min(w.max_new_tokens,
                                                   max_new))
            for w in sorted(wl, key=lambda r: (r.arrival, r.request_id))]


def _engine(**kw):
    defaults = dict(max_batch=8, max_seq=96, num_aw=2, num_ew=2,
                    kv_page_tokens=16, chunk_token_budget=32,
                    prefill_token_cap=256, preempt=True, telemetry=True,
                    watchdogs=True)
    defaults.update(kw)
    return reduced_engine(seed=3, **defaults)


def clean_soak() -> dict:
    rate = 10.0 if SMOKE else 20.0
    dur = 12.0 if SMOKE else 20.0
    wl = _cap(make_workload("mixed_slo", rate_rps=rate, duration=dur,
                            seed=21, interactive_deadline=0.3,
                            batch_wave=6, batch_every=4.0))
    eng = _engine()
    orch = Orchestrator(eng, profile=TarragonProfile(detect=0.05,
                                                     detect_retries=2),
                        worker_init_time=0.4, weight_push_time=0.2)
    faults = [FailurePlan(1.0, "aw", 0), FailurePlan(3.0, "ew", 1)]
    m = run_serving(eng, wl, duration=120.0, orchestrator=orch,
                    failures=faults, step_time=STEP,
                    prefill_token_time=PF_TOK)
    wd = eng.flightrec.watchdogs
    eng.flightrec.dump(POSTMORTEM_PATH, reason="soak postmortem "
                       "(on demand, end of clean soak)")
    return {"workload": "mixed_slo", "requests": len(wl),
            "finished": len(m.finished), "faults": len(faults),
            "duration_virtual_s": m.duration,
            "watchdog_trips": len(wd.trips),
            "watchdog_trips_by_kind": dict(wd.trip_counts),
            "watchdog_intervals": wd.intervals,
            "recorder_records": len(eng.flightrec.records),
            "recorder_dropped": eng.flightrec.records_dropped,
            "postmortem": os.path.relpath(
                POSTMORTEM_PATH, os.path.dirname(__file__))}


def leak_soak() -> dict:
    rate = 2.0 if SMOKE else 3.0
    dur = 8.0 if SMOKE else 12.0
    wl = _cap(make_workload("mixed_slo", rate_rps=rate, duration=dur,
                            seed=22, interactive_deadline=0.3,
                            batch_wave=2, batch_every=5.0))
    eng = _engine(wd_interval=0.25, wd_window=4, wd_leak_min_drop=3,
                  wd_settle=0.5)
    wd = eng.flightrec.watchdogs
    pool, ticks = eng.pages, [0]
    orig_step = eng.step

    def leaky_step(now=None):
        ticks[0] += 1
        # orphan one page every 4 ticks until the detector fires (keep a
        # floor of free pages so the serving path itself never starves)
        if not wd.trips and ticks[0] % 4 == 0 and \
                sum(pool.free_pages(a) for a in range(pool.num_aw)) > 16:
            pool.alloc(ticks[0] % pool.num_aw)
        return orig_step(now=now)

    eng.step = leaky_step
    m = run_serving(eng, wl, duration=120.0, step_time=STEP,
                    prefill_token_time=PF_TOK)
    pool.check()    # the leak is invisible to the allocator oracle
    leak_trips = wd.trip_counts.get("leak", 0)
    first = next((t for t in wd.trips if t["kind"] == "leak"), None)
    return {"workload": "mixed_slo", "requests": len(wl),
            "finished": len(m.finished),
            "pages_leaked": len(
                {p for p in range(1, pool.num_pages) if pool.ref[p] > 0}
                - {int(p) for p in pool.bt[pool.bt > 0]}),
            "leak_trips": leak_trips,
            "detected": leak_trips >= 1,
            "first_trip": first,
            "invariant_trips": wd.trip_counts.get("invariant", 0)}


def run():
    clean = clean_soak()
    leak = leak_soak()
    payload = {"bench": "soak", "smoke": SMOKE, "clean": clean,
               "leak": leak}
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows = [
        Row("soak_clean_finished", 0.0,
            f"{clean['finished']}/{clean['requests']}"),
        Row("soak_clean_watchdog_trips", 0.0,
            str(clean["watchdog_trips"])),
        Row("soak_leak_detected", 0.0,
            "pass" if leak["detected"] else "FAIL"),
    ]
    assert clean["watchdog_trips"] == 0, (
        f"clean soak tripped watchdogs: {clean['watchdog_trips_by_kind']}")
    assert leak["detected"], "seeded page leak was not detected"
    return rows
