"""Roofline terms per (architecture x input shape) from the multi-pod
dry-run artifacts (deliverable g). Reads reports/dryrun_single_pod.json
produced by ``python -m repro.launch.dryrun --all --json ...`` — re-run that
first if the file is missing."""
from __future__ import annotations

import json
import os

from benchmarks.common import Row

REPORT = os.path.join(os.path.dirname(__file__), "..", "reports",
                      "dryrun_single_pod.json")


def run():
    rows = []
    if not os.path.exists(REPORT):
        return [Row("roofline/missing", 0.0,
                    "run repro.launch.dryrun --all --json first")]
    with open(REPORT) as f:
        results = json.load(f)
    for r in results:
        if r["status"] == "skipped":
            rows.append(Row(f"roofline/{r['name']}", 0.0, "skipped(DESIGN)"))
            continue
        if r["status"] != "ok":
            rows.append(Row(f"roofline/{r['name']}", 0.0, "ERROR"))
            continue
        dom_s = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}[r["dominant"]]
        rows.append(Row(
            f"roofline/{r['name']}", dom_s * 1e6,
            f"dom={r['dominant']} compute={r['compute_s']*1e3:.2f}ms "
            f"mem={r['memory_s']*1e3:.2f}ms "
            f"coll={r['collective_s']*1e3:.2f}ms "
            f"useful={r['useful_ratio']:.2f}"))
    return rows
