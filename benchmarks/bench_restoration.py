"""Paper Fig. 12: AW restoration strategies at varying failure points.

Three strategies, all executed for real on the reduced engine:
  * sequential replay — re-prefill the prompt, then re-decode token by token
    up to the failure point on the alternate AW.
  * parallel replay  — one prefill over prompt + generated prefix.
  * tarragon         — per-request restoration from the checkpoint store.

Metrics per failure point: restoration wall time, data transferred
(AW-EW expert traffic for replays, store->AW bytes for Tarragon), and GPU
recompute (re-executed layer-steps).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Row, reduced_engine
from repro.serving.api import RequestSpec
from repro.core import costmodel as cm


FAIL_POINTS = (4, 8, 16, 32)


def _expert_replay_bytes(cfg, tokens):
    v = cm.expert_traffic_bytes(cfg.d_model, cfg.moe.top_k, 4)
    return tokens * cfg.num_layers * v


def run():
    rows = []
    prompt = np.arange(1, 11, dtype=np.int32)
    for n in FAIL_POINTS:
        # ---- reference run up to failure point --------------------------
        eng = reduced_engine(seed=9, max_seq=128)
        eng.client.submit(RequestSpec(rid="r", prompt=prompt,
                                      max_new=n + 6))
        for _ in range(n):
            eng.step()
        cfg = eng.cfg
        gen = list(eng.requests["r"].tokens)

        # ---- tarragon: per-request restore ------------------------------
        eng.fail_aw(0)
        t0 = time.monotonic()
        eng.recover_aw_requests()
        jax.block_until_ready(eng.cache)
        t_tar = time.monotonic() - t0
        bytes_tar = eng.store.stats.bytes_restored
        # resume and verify it still completes
        while not eng.requests["r"].done:
            eng.step()

        # ---- sequential replay -------------------------------------------
        eng2 = reduced_engine(seed=9, max_seq=128)
        t0 = time.monotonic()
        eng2.client.submit(RequestSpec(rid="r2", prompt=prompt,
                                       max_new=n + 6))
        for _ in range(n):
            eng2.step()
        t_seq = time.monotonic() - t0
        bytes_seq = _expert_replay_bytes(cfg, len(prompt) + n)
        gpu_seq = (1 + n) * cfg.num_layers   # prefill pass + n decode steps

        # ---- parallel replay ----------------------------------------------
        eng3 = reduced_engine(seed=9, max_seq=128)
        long_prompt = np.asarray(list(prompt) + gen[:n], np.int32)
        t0 = time.monotonic()
        eng3.client.submit(RequestSpec(rid="r3", prompt=long_prompt,
                                       max_new=4))
        t_par = time.monotonic() - t0
        bytes_par = bytes_seq
        gpu_par = cfg.num_layers

        rows.append(Row(f"fig12/time/fail@{n}", t_tar * 1e6,
                        f"seq={t_seq*1e3:.1f}ms par={t_par*1e3:.1f}ms "
                        f"speedup_seq={t_seq/max(t_tar,1e-9):.1f}x"))
        rows.append(Row(f"fig12/bytes/fail@{n}", float(bytes_tar),
                        f"seq={bytes_seq} par={bytes_par} "
                        f"ratio={bytes_seq/max(bytes_tar,1):.1f}x"))
        rows.append(Row(f"fig12/gpu_layersteps/fail@{n}", 0.0,
                        f"tarragon=0 seq={gpu_seq} par={gpu_par}"))
    # full-scale analytic traffic ratio for Mixtral (paper: ~8x):
    # replay moves V = 2*topk*d per token-layer, restore moves
    # C = 2*Hkv*head_dim -> V/C = topk*H/Hkv = 2*32/8 = 8.
    ratio = (2 * 2 * 4096) / (2 * 8 * (4096 // 32))
    rows.append(Row("fig12/traffic_ratio_fullscale", 0.0,
                    f"analytic={ratio:.0f}x(paper~8x)"))
    return rows
