"""Paper Fig. 8: AW-EW traffic is bursty; attention-compute gaps provide
natural windows for incremental KV checkpointing."""
from __future__ import annotations

from benchmarks.common import Row
from repro.core.events import SimConfig, link_trace


def run():
    events, info = link_trace(SimConfig(), n_layers=8)
    busy = sum(e - s for s, e, k in events if k in ("dispatch", "gather"))
    idle = sum(e - s for s, e, k in events if k == "idle")
    total = max(e for _, e, _ in events)
    return [
        Row("fig8/link_busy_frac", busy / total * 1e6,
            f"busy={busy/total*100:.0f}% idle={idle/total*100:.0f}%"),
        Row("fig8/ckpt_in_gap", info["t_ckpt"] * 1e6,
            f"gap={info['t_attn']*1e6:.0f}us fits={info['ckpt_fits_gap']}"
            "(paper:fits)"),
    ]
