"""Paper §7.4 (checkpointing schemes) + Appendix C (traffic sizing).

(1) Simulated throughput of no-checkpointing vs Tarragon-incremental vs
    Pause-Checkpoint-Resume at several intervals (paper: 2.15x drop at 8).
(2) Analytic App-C segment/expert-traffic ratio for the paper model and all
    assigned architectures (GQA/MQA make checkpointing cheap).
(3) Measured checkpoint bytes + wall overhead on the real reduced engine.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, reduced_engine, time_fn
from repro.serving.api import RequestSpec
from repro.configs import all_configs
from repro.core import costmodel as cm
from repro.core.events import SimConfig, checkpoint_scheme_throughput


def run():
    rows = []
    c = SimConfig()
    base = checkpoint_scheme_throughput(c, "none")
    inc = checkpoint_scheme_throughput(c, "incremental")
    rows.append(Row("ckpt/scheme/none", 1e6 / base, f"{base:.0f}tok/s"))
    rows.append(Row("ckpt/scheme/incremental", 1e6 / inc,
                    f"{inc:.0f}tok/s overhead="
                    f"{(base-inc)/base*100:.2f}%(paper<3%)"))
    for interval in (4, 8, 16, 64):
        p = checkpoint_scheme_throughput(c, "pause",
                                         interval_tokens=interval)
        rows.append(Row(f"ckpt/scheme/pause@{interval}", 1e6 / p,
                        f"{p:.0f}tok/s drop={base/p:.2f}x"
                        + ("(paper:2.15x)" if interval == 8 else "")))

    # Appendix C ratios
    mix = cm.checkpoint_traffic_ratio(4096, 32, 8, 2)
    rows.append(Row("appC/ratio/mixtral-8x7b", 0.0,
                    f"{mix*100:.1f}%(paper~12.5%)"))
    for name, cfg in all_configs().items():
        if not cfg.moe.enabled:
            continue
        r = cm.checkpoint_traffic_ratio(cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, cfg.moe.top_k)
        rows.append(Row(f"appC/ratio/{name}", 0.0, f"{r*100:.2f}%"))

    # measured: checkpointing on vs off, real engine decode steps
    prompt = np.arange(1, 11, dtype=np.int32)
    eng_on = reduced_engine(checkpoint=True, seed=2)
    eng_on.client.submit(RequestSpec(rid="r", prompt=prompt, max_new=80))
    t_on = time_fn(lambda: eng_on.step(), warmup=3, iters=12)
    eng_off = reduced_engine(checkpoint=False, seed=2)
    eng_off.client.submit(RequestSpec(rid="r", prompt=prompt,
                                      max_new=80))
    t_off = time_fn(lambda: eng_off.step(), warmup=3, iters=12)
    over = (t_on - t_off) / t_off * 100
    rows.append(Row("ckpt/engine_step_overhead", t_on * 1e6,
                    f"no_ckpt={t_off*1e6:.0f}us overhead={over:.1f}%"))
    st = eng_on.store.stats
    rows.append(Row("ckpt/engine_bytes_written", 0.0,
                    f"{st.bytes_written}B updates={st.updates}"))
    return rows
