"""Paper Fig. 10/11 + §7.3: the cost of failure resiliency when NO failures
occur. Tarragon mode vs MegaScale-style static binding (no ERT / no shadow
slots / no checkpointing), measured wall-clock on the real reduced engine
for both workloads. Paper claim: within 2.8% throughput, negligible latency
delta."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Row, reduced_engine
from repro.data.workloads import make_workload
from repro.serving.scheduler import run_serving


def _workload(kind, n=6, out=10):
    wl = make_workload(kind, rate_rps=4.0, duration=2.0, seed=3)
    wl = [dataclasses.replace(w, arrival=0.0,
                              prompt_len=min(w.prompt_len, 12),
                              max_new_tokens=out) for w in wl]
    return wl[:n]


def _measure(tarragon: bool, checkpoint: bool, kind: str):
    """Median steady-state decode-step time with a full continuous batch
    (prefill/compile excluded — the §7.3 comparison is decode-path cost)."""
    import time
    eng = reduced_engine(tarragon=tarragon, checkpoint=checkpoint, seed=0)
    for i, w in enumerate(_workload(kind, out=200)):
        eng.submit(w.request_id, w.prompt_tokens(eng.cfg.vocab_size), 200)
    for _ in range(3):  # warmup (compile)
        eng.step()
    ts = []
    for _ in range(15):
        t0 = time.monotonic()
        eng.step()
        ts.append(time.monotonic() - t0)
    step = float(np.median(ts))
    n_active = len(eng.active_requests())
    thr = n_active / step
    return thr, step, float(np.percentile(ts, 95))


def run():
    rows = []
    for kind in ("random", "sharegpt"):
        thr_t, tbt_t, p95_t = _measure(True, True, kind)
        thr_e, tbt_e, _ = _measure(True, False, kind)   # ERT+shadow only
        thr_m, tbt_m, p95_m = _measure(False, False, kind)
        over = (thr_m - thr_t) / max(thr_m, 1e-9) * 100
        over_ert = (thr_m - thr_e) / max(thr_m, 1e-9) * 100
        over_ckpt = over - over_ert
        # the reduced model's shadow bank doubles its expert slots
        # (P=2E); at assigned-arch scale shadows are P/E-1 ~= 8.3% of
        # expert FLOPs (kimi: 416/384). Scale the shadow share down and
        # keep the ckpt/ERT share as measured.
        shadow_frac_reduced = 1.0     # P/E - 1 at reduced scale
        shadow_frac_full = 32 / 384   # kimi-k2 geometry
        over_full = over_ckpt + over_ert * (shadow_frac_full /
                                            shadow_frac_reduced)
        rows.append(Row(f"fig11/throughput/{kind}/tarragon",
                        1e6 / max(thr_t, 1e-9),
                        f"{thr_t:.1f}tok/s"))
        rows.append(Row(f"fig11/throughput/{kind}/megascale",
                        1e6 / max(thr_m, 1e-9),
                        f"{thr_m:.1f}tok/s overhead_measured={over:.1f}% "
                        f"[ert+shadow={over_ert:.1f}% ckpt={over_ckpt:.1f}%]"
                        f" scale_adj={over_full:.1f}%(paper<=2.8%)"))
        rows.append(Row(f"fig10/tbt/{kind}", tbt_t * 1e6,
                        f"median_megascale={tbt_m*1e3:.1f}ms "
                        f"p95_t={p95_t*1e3:.1f}ms p95_m={p95_m*1e3:.1f}ms"))
    return rows
