"""Paper Fig. 10/11 + §7.3: the cost of failure resiliency when NO failures
occur. Tarragon mode vs MegaScale-style static binding (no ERT / no shadow
slots / no checkpointing), measured wall-clock on the real reduced engine
for both workloads. Paper claim: within 2.8% throughput, negligible latency
delta.

Also reports the serving-plane metrics of the layered stack — queueing
delay p50/p99 at the Gateway and prefill-batch occupancy from the
ContinuousBatchScheduler — plus the chunked-prefill plane's TBT isolation
under a long-prompt burst (chunked vs whole-prompt prefill on the same
workload and virtual clock) — and dumps everything as JSON
(benchmarks/results/steady_state.json) so the perf trajectory accumulates
across PRs.

``BENCH_SMOKE=1`` shrinks every section for the CI smoke step."""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from benchmarks.common import Row, pct, reduced_engine
from repro.serving.api import RequestSpec
from repro.data.workloads import make_workload
from repro.serving.scheduler import run_serving

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "steady_state.json")

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))


def _workload(kind, n=6, out=10):
    wl = make_workload(kind, rate_rps=4.0, duration=2.0, seed=3)
    wl = [dataclasses.replace(w, arrival=0.0,
                              prompt_len=min(w.prompt_len, 12),
                              max_new_tokens=out) for w in wl]
    return wl[:n]


def _measure(tarragon: bool, checkpoint: bool, kind: str):
    """Median steady-state decode-step time with a full continuous batch
    (prefill/compile excluded — the §7.3 comparison is decode-path cost)."""
    import time
    eng = reduced_engine(tarragon=tarragon, checkpoint=checkpoint, seed=0)
    for i, w in enumerate(_workload(kind, out=200)):
        eng.client.submit(RequestSpec(
            rid=w.request_id,
            prompt=w.prompt_tokens(eng.cfg.vocab_size), max_new=200))
    for _ in range(3):  # warmup (compile)
        eng.step()
    ts = []
    for _ in range(4 if SMOKE else 15):
        t0 = time.monotonic()
        eng.step()
        ts.append(time.monotonic() - t0)
    step = float(np.median(ts))
    n_active = len(eng.active_requests())
    thr = n_active / step
    return thr, step, float(np.percentile(ts, 95))


def _measure_serving(kind: str):
    """Gateway/scheduler-plane metrics under an arrival stream with more
    requests than slots (a real waiting queue forms): queueing-delay
    percentiles and prefill-batch occupancy, all on the virtual clock."""
    eng = reduced_engine(seed=0, max_batch=8)
    wl = make_workload(kind, rate_rps=40.0, duration=0.5, seed=4)
    wl = [dataclasses.replace(w, prompt_len=min(w.prompt_len, 14),
                              max_new_tokens=8) for w in wl][:8 if SMOKE
                                                            else 16]
    m = run_serving(eng, wl, duration=400.0, step_time=0.02)
    qd = m.queue_delay_values()
    return {
        "workload": kind,
        "requests": len(wl),
        "finished": len(m.finished),
        "throughput_tok_per_s": m.throughput(),
        "queue_delay_p50_s": pct(qd, 50),
        "queue_delay_p99_s": pct(qd, 99),
        "ttft_p50_s": pct(list(m.ttft.values()), 50),
        "prefill": m.prefill,       # calls / requests / occupancy / batch
    }


def _measure_chunked_prefill():
    """Long-prompt burst: identical workload and virtual clock, whole-prompt
    prefill vs the chunked plane. Prefill work is charged to the clock per
    real token, so a whole-prompt prefill of a long prompt is the TBT stall
    it would be on hardware; the chunked plane bounds it at
    chunk_token_budget tokens per tick."""
    n_req = 8 if SMOKE else 14
    max_new = 6 if SMOKE else 10
    wl = make_workload("long_prompt_burst", rate_rps=30.0, duration=1.0,
                       seed=5, max_prompt=72, max_new=max_new)
    wl = [dataclasses.replace(w, max_new_tokens=max_new)
          for w in wl][:n_req]
    out = {"workload": "long_prompt_burst", "requests": len(wl)}
    for label, budget in (("whole", 0), ("chunked", 16)):
        eng = reduced_engine(seed=0, max_batch=8, max_seq=96,
                             chunk_token_budget=budget,
                             prefill_token_cap=8 * budget)
        m = run_serving(eng, wl, duration=600.0, step_time=0.02,
                        prefill_token_time=0.002)
        tbt = m.tbt_values()
        out[label] = {
            "finished": len(m.finished),
            "tbt_p50_s": pct(tbt, 50),
            "tbt_p99_s": pct(tbt, 99),
            "max_stall_s": m.max_stall(),
            "ttft_p50_s": pct(list(m.ttft.values()), 50),
            "prefill": m.prefill,
        }
    return out


def _measure_mixed_slo():
    """Multi-class admission plane under a saturating batch wave +
    interactive Poisson stream: per-class TTFT/TBT percentiles with
    preempt-and-requeue on vs off (same workload, same virtual clock),
    plus a preemption-stall audit — what the evicted batch victims pay
    (their max token gap) to buy the interactive TTFT win."""
    batch_new = 40 if SMOKE else 150
    dur = 2.0 if SMOKE else 3.0
    wl = make_workload("mixed_slo", rate_rps=3.0, duration=dur, seed=7,
                       max_new=batch_new, interactive_deadline=0.3,
                       batch_wave=8, batch_every=dur + 1.0)
    out = {"workload": "mixed_slo", "requests": len(wl),
           "interactive": sum(1 for w in wl
                              if w.slo_class == "interactive"),
           "batch": sum(1 for w in wl if w.slo_class == "batch")}
    for label, preempt in (("no_preempt", False), ("preempt", True)):
        eng = reduced_engine(seed=0, max_batch=8, preempt=preempt)
        m = run_serving(eng, wl, duration=600.0, step_time=0.02)
        sec = {"finished": len(m.finished),
               "preemptions": m.gateway["preemptions"],
               "by_class": m.gateway["by_class"]}
        for cls in ("interactive", "batch"):
            ttft = m.ttft_values(cls)
            tbt = m.tbt_values(cls)
            sec[cls] = {
                "ttft_p50_s": pct(ttft, 50),
                "ttft_p99_s": pct(ttft, 99),
                "tbt_p99_s": pct(tbt, 99),
                "max_stall_s": m.max_stall(cls),
            }
        out[label] = sec
    out["interactive_ttft_p99_improvement_x"] = \
        out["no_preempt"]["interactive"]["ttft_p99_s"] / \
        max(out["preempt"]["interactive"]["ttft_p99_s"], 1e-9)
    return out


def _measure_controller_mixed_slo():
    """Closed-loop control plane vs the hand-tuned static configuration on
    the same ``mixed_slo`` run (virtual clock, so the comparison is exact):
    batch waves carry LONG prompts whose per-tick prefill charge stalls
    co-resident interactive decodes; the static config runs the shipped
    fixed chunk budget + remaining-work preemption, while the controller
    adapts the budget to interactive deadline headroom and gates
    preemption on actual deadline risk (victim_policy="controller").
    Reports per-class TTFT/TBT p50/p99 and the decision audit."""
    batch_new = 30 if SMOKE else 60
    dur = 2.0 if SMOKE else 3.0
    wl = make_workload("mixed_slo", rate_rps=3.0, duration=dur, seed=7,
                       max_new=batch_new, interactive_deadline=0.3,
                       batch_wave=6, batch_every=dur + 1.0)
    # long batch prompts: the chunk budget becomes the knob that decides
    # how much prefill stall interactive requests absorb per tick
    wl = [dataclasses.replace(w, prompt_len=64)
          if w.slo_class == "batch" else w for w in wl]
    out = {"workload": "mixed_slo", "requests": len(wl),
           "interactive": sum(1 for w in wl
                              if w.slo_class == "interactive"),
           "batch": sum(1 for w in wl if w.slo_class == "batch")}
    base_kw = dict(seed=0, max_batch=8, max_seq=96, preempt=True,
                   chunk_token_budget=16, prefill_token_cap=128)
    for label, kw in (
            ("static", {}),
            ("controller", {"controller": "on",
                            "victim_policy": "controller"})):
        eng = reduced_engine(**base_kw, **kw)
        m = run_serving(eng, wl, duration=600.0, step_time=0.02,
                        prefill_token_time=0.002)
        sec = {"finished": len(m.finished),
               "preemptions": m.gateway["preemptions"]}
        for cls in ("interactive", "batch"):
            ttft = m.ttft_values(cls)
            tbt = m.tbt_values(cls)
            sec[cls] = {
                "ttft_p50_s": pct(ttft, 50),
                "ttft_p99_s": pct(ttft, 99),
                "tbt_p50_s": pct(tbt, 50),
                "tbt_p99_s": pct(tbt, 99),
                "max_stall_s": m.max_stall(cls),
            }
        if eng.controller is not None:
            sec["decisions"] = dict(eng.controller.counts)
            sec["budget_changes"] = [
                d["detail"] for d in eng.controller.decisions
                if d["kind"] == "budget"]
            sec["decode_jit_traces"] = eng._decode._cache_size()
        out[label] = sec
    s, c = out["static"], out["controller"]
    out["interactive_ttft_p99_ratio"] = \
        c["interactive"]["ttft_p99_s"] / \
        max(s["interactive"]["ttft_p99_s"], 1e-9)
    out["interactive_tbt_p99_ratio"] = \
        c["interactive"]["tbt_p99_s"] / \
        max(s["interactive"]["tbt_p99_s"], 1e-9)
    # acceptance: the closed loop matches or beats the hand-tuned static
    # config on interactive TTFT/TBT p99 (<= within rounding)
    assert out["interactive_ttft_p99_ratio"] <= 1.001, out
    assert out["interactive_tbt_p99_ratio"] <= 1.001, out
    assert c["decisions"]["budget"] >= 1, out
    return out


def _measure_telemetry():
    """Observability-plane cost + fidelity (telemetry.py): wall-clock
    overhead of the plane on identical virtual-clock serving work,
    bit-identity of outputs on/off, streamed-histogram percentiles vs the
    exact per-token lists, and a failure-injection run exported as the
    metrics snapshot + Prometheus text + Perfetto trace, with the outage
    attributed across detection/restore/queue components."""
    import gc
    import math
    import time
    from repro.core.costmodel import TarragonProfile
    from repro.core.orchestrator import Orchestrator
    from repro.serving.scheduler import FailurePlan

    # more requests than slots: the AW failure's victims then *wait* to be
    # re-admitted, so the outage shows up as restore-attributed stalls
    n_req = 14 if SMOKE else 20
    out_toks = 16 if SMOKE else 48
    wl = make_workload("random", rate_rps=12.0, duration=3.0, seed=5)
    wl = [dataclasses.replace(w, prompt_len=min(w.prompt_len, 24),
                              max_new_tokens=out_toks) for w in wl][:n_req]

    def serve(telemetry, failures=()):
        # threshold below the outage's restore wait (~80 ms here) but
        # above a prefill-budget tick charge (52 ms)
        eng = reduced_engine(seed=0, max_batch=8, chunk_token_budget=16,
                             telemetry=telemetry, stall_threshold=0.06)
        orch = Orchestrator(eng, profile=TarragonProfile(detect=0.05,
                                                         detect_retries=2),
                            worker_init_time=0.5)
        t0 = time.monotonic()
        m = run_serving(eng, wl, duration=120.0, orchestrator=orch,
                        failures=list(failures), step_time=0.02,
                        prefill_token_time=0.002)
        return eng, m, time.monotonic() - t0

    out = {"requests": len(wl)}
    # -- overhead: same workload, same virtual clock (the engine does
    # identical jitted work either way — the plane is host-side only), so
    # the wall-time delta IS the plane's cost. The first run on each
    # engine warms every jit shape and is discarded (compile time is
    # seconds of noise); timed repeats rerun a decode-heavy workload on
    # the same engine with a fresh plane, interleaved on/off best-of-R so
    # machine drift hits both sides equally.
    from repro.serving.telemetry import TelemetryPlane
    over_toks = 60 if SMOKE else 120
    wl_over = make_workload("random", rate_rps=12.0, duration=1.0, seed=9)
    wl_over = [dataclasses.replace(w, prompt_len=min(w.prompt_len, 24),
                                   max_new_tokens=over_toks)
               for w in wl_over][:8]
    # shared-box wall clocks here show ~8% run-to-run CV, but the *floor*
    # (best-of-N) is stable to ~1.5% — compare floors, interleaved so a
    # load spike cannot hit only one side
    repeats = 8 if SMOKE else 10
    inner = 2                                  # serving runs per sample
    engines = {}
    samples = {"on": [], "off": []}
    toks = {}
    for label, tel_on in (("off", False), ("on", True)):
        eng = reduced_engine(seed=0, max_batch=8, chunk_token_budget=16,
                             telemetry=tel_on, stall_threshold=0.06)
        run_serving(eng, wl_over, duration=120.0, step_time=0.02,
                    prefill_token_time=0.002)          # compile warmup
        engines[label] = eng
    for _ in range(repeats):
        for label in ("off", "on"):
            eng = engines[label]
            if label == "on":
                eng.telemetry = TelemetryPlane(eng)
                eng.gateway.telemetry = eng.telemetry
            gc.collect()           # keep GC pauses out of the sample
            t0 = time.monotonic()
            for _ in range(inner):
                m = run_serving(eng, wl_over, duration=120.0,
                                step_time=0.02, prefill_token_time=0.002)
            samples[label].append((time.monotonic() - t0) / inner)
            toks[label] = len(m.token_log)
    assert toks["on"] == toks["off"]
    wall = {k: min(v) for k, v in samples.items()}
    steps_per_run = int(
        engines["on"].telemetry.registry.counters["engine.steps"])
    # the A/B floor comparison corroborates, but its resolution is the
    # box's noise floor; the *gated* number times the actual per-step
    # hook work (a full batch of token observations + the step span)
    # against the measured step time — precise at any machine load
    plane = TelemetryPlane(engines["on"])
    rids = [f"r{i}" for i in range(8)]
    iters = 2000
    # best-of-N floors: interference only inflates a timed block, so the
    # minimum over repeats is the true hook cost
    hook_s_per_step = float("inf")
    for _ in range(5):
        gc.collect()
        t0 = time.monotonic()
        for i in range(iters):
            plane.on_step(i * 0.02, (i + 1) * 0.02, 16, 0.032, 8)
            for rid in rids:
                plane.observe_tokens(rid, (i + 1) * 0.02, 1)
        hook_s_per_step = min(hook_s_per_step,
                              (time.monotonic() - t0) / iters)
    # the flight recorder (serving/flightrec.py) ticks once per engine
    # step too: bus drain + ring append + a fingerprint every
    # flight_fingerprint_every of virtual time — same gate, same method
    fr = engines["on"].flightrec
    rec_s_per_step = float("inf")
    for _ in range(5):
        gc.collect()
        base = fr._next_fp          # keep the fingerprint cadence live
        t0 = time.monotonic()
        for i in range(iters):
            fr.tick(base + i * 0.02)
        rec_s_per_step = min(rec_s_per_step,
                             (time.monotonic() - t0) / iters)
    step_wall_s = wall["off"] / max(steps_per_run, 1)
    out["overhead"] = {
        "wall_s_on": wall["on"], "wall_s_off": wall["off"],
        "tokens": toks["on"],
        "steps_per_run": steps_per_run,
        "tok_per_s_on": toks["on"] / wall["on"],
        "tok_per_s_off": toks["off"] / wall["off"],
        "overhead_ab_pct": (wall["on"] - wall["off"]) / wall["off"] * 100,
        "hook_us_per_step": hook_s_per_step * 1e6,
        "recorder_us_per_step": rec_s_per_step * 1e6,
        "overhead_pct":
            (hook_s_per_step + rec_s_per_step) / step_wall_s * 100,
    }

    # -- failure-injection export run: on/off twins, AW 0 dies mid-run
    failures = [FailurePlan(0.4, "aw", 0)]
    eng, m, _ = serve(True, failures)
    _, m_off, _ = serve(False, failures)
    tel = m.telemetry
    mismatches = sum(m.outputs[r] != m_off.outputs[r] for r in m_off.outputs)

    def exact_rank(vals, q):
        v = np.sort(np.asarray(vals))
        if not v.size:
            return 0.0
        return float(v[min(v.size - 1, max(0, math.ceil(q * v.size) - 1))])

    def fidelity(hname, vals):
        h = tel.registry.hist(hname)
        sec = {"count_stream": h.count, "count_exact": int(np.size(vals))}
        for q in (0.50, 0.99):
            s, e = h.quantile(q), exact_rank(vals, q)
            sec[f"p{int(q * 100)}"] = {
                "stream_s": s, "exact_s": e,
                "within_one_bucket":
                    abs(h.bucket_index(s) - h.bucket_index(e)) <= 1}
        return sec

    out["fidelity"] = {
        "ttft": fidelity("ttft", m.ttft_values()),
        "tbt": fidelity("tbt", m.tbt_values()),
        "output_mismatches_vs_off": mismatches,
    }
    assert mismatches == 0, "telemetry changed tokens"
    for sec in (out["fidelity"]["ttft"], out["fidelity"]["tbt"]):
        assert sec["count_stream"] == sec["count_exact"], sec
        for q in ("p50", "p99"):
            assert sec[q]["within_one_bucket"], (q, sec)

    # -- stall attribution of the outage
    rep = tel.stall_report()
    by_cause = {}
    for s in rep:
        assert abs(sum(s["components"].values()) - s["gap"]) < 1e-9, s
        for c, v in s["components"].items():
            if v > 0:
                by_cause[c] = by_cause.get(c, 0.0) + v
    out["stalls"] = {
        "n": len(rep),
        "threshold_s": tel.stall_threshold,
        "max_gap_s": max((s["gap"] for s in rep), default=0.0),
        "by_cause_s": {k: round(v, 6)
                       for k, v in sorted(by_cause.items())},
    }
    assert by_cause.get("restore", 0.0) > 0.0, by_cause

    # -- exports: snapshot JSON + Prometheus text + Perfetto trace
    rdir = os.path.dirname(RESULTS_PATH)
    os.makedirs(rdir, exist_ok=True)
    snap = tel.snapshot()
    with open(os.path.join(rdir, "telemetry_snapshot.json"), "w") as f:
        json.dump(snap, f, indent=1)
    with open(os.path.join(rdir, "metrics.prom"), "w") as f:
        f.write(tel.prometheus_text())
    trace = tel.export_chrome(os.path.join(rdir, "trace.perfetto.json"))
    out["exports"] = {
        "snapshot": "results/telemetry_snapshot.json",
        "prometheus": "results/metrics.prom",
        "perfetto": "results/trace.perfetto.json",
        "trace_events": len(trace["traceEvents"]),
        "spans_closed": snap["spans"]["closed"],
    }
    return out


def _measure_device_decode():
    """Device-resident decode loop (serving/decode_loop.py): steps/s at
    batch 1 and full slot occupancy for decode_segment_len 1 vs 8, the
    host-sync rate (drains per generated token per request — 1/seg_len by
    construction, measured here from GatewayStats), and bit-identity of
    segmented decode vs per-step decode, including across an AW crash that
    loses an uncommitted segment."""
    import time
    prompt = np.arange(1, 13, dtype=np.int32)

    def fresh(seg, **kw):
        return reduced_engine(seed=0, max_batch=8, max_seq=96,
                              decode_segment_len=seg, greedy=False,
                              temperature=1.1, top_k=12, sample_seed=5,
                              **kw)

    out = {"segment_lens": [1, 8], "perf": {}, "identity": {}}
    # -- throughput: timing engines run checkpoint-free (the §7.3 decode
    # loop itself; resilience overhead is priced separately above).
    # Best-of-`repeats` timing; iteration counts are sized so every timed
    # segment is full (max_new=80 = 10 full seg-8 segments after warmup).
    for label, bsz in (("batch_1", 1), ("full_batch", 8)):
        sec = {}
        for seg in (1, 8):
            eng = fresh(seg, checkpoint=False, tarragon=False)
            for i in range(bsz):
                eng.client.submit(RequestSpec(rid=f"r{i}", prompt=prompt,
                                              max_new=80))
            for _ in range(3 if seg == 1 else 2):    # warmup (compile)
                eng.step()
            if SMOKE:
                repeats, iters = 1, (8 if seg == 1 else 2)
            else:
                repeats, iters = (3, 25) if seg == 1 else (4, 2)
            best = None
            for _ in range(repeats):
                hs0 = eng.gateway.stats.host_syncs
                ntok = 0
                t0 = time.monotonic()
                for _ in range(iters):
                    o = eng.step()
                    ntok += sum(len(v) for v in o.values())
                dt = time.monotonic() - t0
                syncs = eng.gateway.stats.host_syncs - hs0
                if best is None or dt < best[0]:
                    best = (dt, ntok, syncs)
            dt, ntok, syncs = best
            per_req = ntok / bsz                     # tokens per request
            sec[f"seg{seg}"] = {
                "steps_per_s": iters * seg / dt,
                "tokens_per_s": ntok / dt,
                "host_syncs_per_token": syncs / max(per_req, 1e-9),
            }
        sec["speedup_x"] = sec["seg8"]["steps_per_s"] / \
            max(sec["seg1"]["steps_per_s"], 1e-9)
        # the cost segments amortize: per-token loop overhead (dispatch +
        # h2d/d2h drain + scheduler tick) = step time beyond the in-scan
        # compute floor, for which the seg-8 token time is the proxy
        sec["overhead_ms_amortized_per_token"] = \
            1e3 / max(sec["seg1"]["steps_per_s"], 1e-9) - \
            1e3 / max(sec["seg8"]["steps_per_s"], 1e-9)
        out["perf"][label] = sec
    # On this CPU backend the in-scan model forward (~1.6 ms/token at the
    # reduced scale — per-op overhead, not FLOPs) dominates the ~0.6 ms
    # per-step loop overhead, which bounds the end-to-end seg-8 speedup
    # well below the dispatch-bound accelerator regime; the amortization
    # itself (host_syncs_per_token, overhead_ms_amortized_per_token) is
    # the backend-independent effect.
    out["perf"]["note"] = (
        "end_to_end speedup on CPU is compute-bound; loop-overhead "
        "amortization (1/seg_len host syncs, overhead_ms column) is the "
        "device-resident loop's backend-independent effect")

    # -- bit-identity: checkpointed engines, seg8 vs seg1, same workload
    specs = [dict(rid="a", prompt=prompt, max_new=5),
             dict(rid="b", prompt=np.arange(2, 12, dtype=np.int32),
                  max_new=11),
             dict(rid="c", prompt=np.arange(5, 14, dtype=np.int32),
                  max_new=16),
             dict(rid="d", prompt=prompt[:8], max_new=20)]

    def run_all(eng, inject_failure=False):
        hs = [eng.client.submit(RequestSpec(**s)) for s in specs]
        if inject_failure:
            eng.step()                       # segment 1 commits
            eng.aws[0].checkpointer.flush = lambda: None
            eng.step()                       # segment 2 never commits
            eng.fail_aw(0)
            eng.recover_aw_requests()
        n = 0
        while not all(h.done() for h in hs) and n < 400:
            eng.step()
            n += 1
        assert all(h.done() for h in hs)
        return {h.rid: h.tokens() for h in hs}

    ref = run_all(fresh(1))
    plain = run_all(fresh(8))
    failed = run_all(fresh(8), inject_failure=True)
    out["identity"] = {
        "requests": len(specs),
        "mismatches": sum(plain[r] != ref[r] for r in ref),
        "mismatches_after_aw_failure": sum(failed[r] != ref[r]
                                           for r in ref),
    }
    assert out["identity"]["mismatches"] == 0, out["identity"]
    assert out["identity"]["mismatches_after_aw_failure"] == 0, \
        out["identity"]
    return out


def run():
    rows = []
    payload = {"bench": "steady_state", "serving": [], "decode_path": [],
               "chunked_prefill": None, "mixed_slo": None,
               "device_decode": None, "telemetry": None,
               "controller": None}
    t = _measure_telemetry()
    payload["telemetry"] = t
    rows.append(Row(
        "serving/telemetry/overhead",
        t["overhead"]["wall_s_on"] * 1e6 / max(t["overhead"]["tokens"], 1),
        f"on={t['overhead']['tok_per_s_on']:.0f}tok/s "
        f"off={t['overhead']['tok_per_s_off']:.0f}tok/s "
        f"overhead={t['overhead']['overhead_pct']:.2f}% "
        f"mismatches={t['fidelity']['output_mismatches_vs_off']}"))
    rows.append(Row(
        "serving/telemetry/stall_restore_attributed",
        t["stalls"]["by_cause_s"].get("restore", 0.0) * 1e6,
        f"stalls={t['stalls']['n']} "
        f"max_gap={t['stalls']['max_gap_s']*1e3:.0f}ms "
        f"ttft_p99 stream={t['fidelity']['ttft']['p99']['stream_s']*1e3:.1f}"
        f"ms exact={t['fidelity']['ttft']['p99']['exact_s']*1e3:.1f}ms "
        f"trace_events={t['exports']['trace_events']}"))
    dd = _measure_device_decode()
    payload["device_decode"] = dd
    for label in ("batch_1", "full_batch"):
        s = dd["perf"][label]
        rows.append(Row(
            f"serving/device_decode/steps_per_s/{label}/seg8",
            1e6 / max(s["seg8"]["steps_per_s"], 1e-9),
            f"seg1={s['seg1']['steps_per_s']:.1f}steps/s "
            f"seg8={s['seg8']['steps_per_s']:.1f}steps/s "
            f"speedup={s['speedup_x']:.2f}x "
            f"syncs/token={s['seg8']['host_syncs_per_token']:.3f} "
            f"mismatches={dd['identity']['mismatches']}+"
            f"{dd['identity']['mismatches_after_aw_failure']}(failure)"))
    cl = _measure_controller_mixed_slo()
    payload["controller"] = cl
    n_dec = sum(v for k, v in cl["controller"]["decisions"].items()
                if k != "preempt_denied")
    rows.append(Row(
        "serving/controller/interactive_ttft_p99",
        cl["controller"]["interactive"]["ttft_p99_s"] * 1e6,
        f"static={cl['static']['interactive']['ttft_p99_s']*1e3:.0f}ms "
        f"ratio={cl['interactive_ttft_p99_ratio']:.2f} "
        f"tbt_ratio={cl['interactive_tbt_p99_ratio']:.2f} "
        f"decisions={n_dec} "
        f"jit_traces={cl['controller']['decode_jit_traces']}"))
    s = _measure_mixed_slo()
    payload["mixed_slo"] = s
    rows.append(Row(
        "serving/mixed_slo/interactive_ttft_p99/preempt",
        s["preempt"]["interactive"]["ttft_p99_s"] * 1e6,
        f"no_preempt={s['no_preempt']['interactive']['ttft_p99_s']*1e3:.0f}"
        f"ms improvement={s['interactive_ttft_p99_improvement_x']:.1f}x "
        f"preemptions={s['preempt']['preemptions']} "
        f"victim_stall={s['preempt']['batch']['max_stall_s']*1e3:.0f}ms"))
    c = _measure_chunked_prefill()
    payload["chunked_prefill"] = c
    rows.append(Row(
        "serving/long_prompt_burst/tbt_p99/chunked",
        c["chunked"]["tbt_p99_s"] * 1e6,
        f"whole={c['whole']['tbt_p99_s']*1e3:.1f}ms "
        f"max_stall chunked={c['chunked']['max_stall_s']*1e3:.1f}ms "
        f"whole={c['whole']['max_stall_s']*1e3:.1f}ms"))
    for kind in ("random", "sharegpt"):
        s = _measure_serving(kind)
        payload["serving"].append(s)
        rows.append(Row(
            f"serving/queue_delay_p99/{kind}",
            s["queue_delay_p99_s"] * 1e6,
            f"p50={s['queue_delay_p50_s']*1e3:.1f}ms "
            f"finished={s['finished']}/{s['requests']}"))
        rows.append(Row(
            f"serving/prefill_occupancy/{kind}",
            s["prefill"]["mean_batch"],
            f"occupancy={s['prefill']['occupancy']:.2f} "
            f"calls={s['prefill']['calls']} "
            f"reqs={s['prefill']['requests']}"))
    for kind in ("random", "sharegpt"):
        thr_t, tbt_t, p95_t = _measure(True, True, kind)
        thr_e, tbt_e, _ = _measure(True, False, kind)   # ERT+shadow only
        thr_m, tbt_m, p95_m = _measure(False, False, kind)
        over = (thr_m - thr_t) / max(thr_m, 1e-9) * 100
        over_ert = (thr_m - thr_e) / max(thr_m, 1e-9) * 100
        over_ckpt = over - over_ert
        # the reduced model's shadow bank doubles its expert slots
        # (P=2E); at assigned-arch scale shadows are P/E-1 ~= 8.3% of
        # expert FLOPs (kimi: 416/384). Scale the shadow share down and
        # keep the ckpt/ERT share as measured.
        shadow_frac_reduced = 1.0     # P/E - 1 at reduced scale
        shadow_frac_full = 32 / 384   # kimi-k2 geometry
        over_full = over_ckpt + over_ert * (shadow_frac_full /
                                            shadow_frac_reduced)
        rows.append(Row(f"fig11/throughput/{kind}/tarragon",
                        1e6 / max(thr_t, 1e-9),
                        f"{thr_t:.1f}tok/s"))
        rows.append(Row(f"fig11/throughput/{kind}/megascale",
                        1e6 / max(thr_m, 1e-9),
                        f"{thr_m:.1f}tok/s overhead_measured={over:.1f}% "
                        f"[ert+shadow={over_ert:.1f}% ckpt={over_ckpt:.1f}%]"
                        f" scale_adj={over_full:.1f}%(paper<=2.8%)"))
        rows.append(Row(f"fig10/tbt/{kind}", tbt_t * 1e6,
                        f"median_megascale={tbt_m*1e3:.1f}ms "
                        f"p95_t={p95_t*1e3:.1f}ms p95_m={p95_m*1e3:.1f}ms"))
        payload["decode_path"].append({
            "workload": kind,
            "throughput_tarragon": thr_t, "throughput_megascale": thr_m,
            "tbt_tarragon_s": tbt_t, "tbt_megascale_s": tbt_m,
            "overhead_measured_pct": over,
            "overhead_scale_adjusted_pct": over_full})
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows
