"""Paper Appendix F: steady-state cost of each resiliency component.

Alt-0 = full Tarragon; Alt-1 = no KV checkpointing; Alt-2 = additionally no
failure detection (no probe work — host-side here, so measured via the
orchestrator-less path); Alt-3 = additionally no ERT (static binding =
MegaScale-like). No failures injected; paper: all within 3%."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Row, reduced_engine
from repro.data.workloads import make_workload
from repro.serving.scheduler import run_serving


def _thr(tarragon, checkpoint, kind="random"):
    eng = reduced_engine(tarragon=tarragon, checkpoint=checkpoint, seed=1)
    wl = make_workload(kind, rate_rps=4.0, duration=1.5, seed=4)
    wl = [dataclasses.replace(w, arrival=0.0, prompt_len=8,
                              max_new_tokens=10) for w in wl][:6]
    m = run_serving(eng, wl, duration=300.0)
    return m.throughput()


def run():
    rows = []
    for kind in ("random", "sharegpt"):
        full = _thr(True, True, kind)
        alt1 = _thr(True, False, kind)    # - checkpointing
        alt3 = _thr(False, False, kind)   # - detection - ERT (static)
        worst = max(abs(full - x) / max(full, 1e-9) * 100
                    for x in (alt1, alt3))
        rows.append(Row(f"appF/{kind}/full", 1e6 / max(full, 1e-9),
                        f"{full:.1f}tok/s"))
        rows.append(Row(f"appF/{kind}/alt1_no_ckpt", 1e6 / max(alt1, 1e-9),
                        f"{alt1:.1f}tok/s"))
        rows.append(Row(f"appF/{kind}/alt3_static", 1e6 / max(alt3, 1e-9),
                        f"{alt3:.1f}tok/s max_dev={worst:.1f}%"
                        "(paper<3%)"))
    return rows
