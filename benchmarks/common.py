"""Shared benchmark helpers: reduced-scale engines + CSV emission.

Every bench_* module exposes ``run() -> list[Row]``; run.py aggregates to
the required ``name,us_per_call,derived`` CSV. "us_per_call" is the measured
(or simulated) latency of the benchmark's unit operation; "derived" carries
the paper-comparable figure (a ratio, a percentage, a pass marker).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Callable, List

import jax
import numpy as np

from repro.configs import get_config
from repro.serving.engine import EngineConfig, InferenceEngine
# one home for the empty-array-guarded percentile helpers every bench and
# driver used to copy-paste (serving/telemetry.py owns them; re-exported
# here so benches import from one place)
from repro.serving.telemetry import pct, summarize_latency  # noqa: F401


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def reduced_engine(arch="mixtral_8x7b", cap_factor=4.0, seed=0, **kw) -> \
        InferenceEngine:
    cfg = get_config(arch).reduced()
    if cfg.moe.enabled and cap_factor:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap_factor))
    defaults = dict(max_batch=8, max_seq=96, num_aw=2, num_ew=2)
    defaults.update(kw)
    ecfg = EngineConfig(**defaults)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(seed))


def time_fn(fn: Callable, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time (seconds) of fn()."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.monotonic()
        fn()
        ts.append(time.monotonic() - t0)
    return float(np.median(ts))
