"""Paper Fig. 9 + §7.2 headline: end-to-end failover behaviour (TBT and
output tokens/s around an injected failure), from the calibrated event
simulator, PLUS a functional failover run on the real reduced-scale engine
(exact-token recovery check)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, reduced_engine
from repro.serving.api import RequestSpec
from repro.core.events import (SimConfig, failover_summary,
                               simulate_megascale_failure,
                               simulate_tarragon_aw_failure,
                               simulate_tarragon_ew_failure)


def run():
    rows = []
    c = SimConfig()
    s = failover_summary(c)
    rows.append(Row("fig9/megascale_stall", s["megascale_stall_s"] * 1e6,
                    "paper~64s"))
    rows.append(Row("fig9/tarragon_aw_stall", s["tarragon_aw_stall_s"] * 1e6,
                    f"improvement={s['aw_improvement_x']:.0f}x(paper:160x)"))
    rows.append(Row("fig9/tarragon_ew_stall", s["tarragon_ew_stall_s"] * 1e6,
                    f"improvement={s['ew_improvement_x']:.0f}x(paper:213x)"))

    for sim, nm in ((simulate_megascale_failure, "megascale"),
                    (simulate_tarragon_aw_failure, "tarragon_aw"),
                    (simulate_tarragon_ew_failure, "tarragon_ew")):
        tl = sim(c)
        pre = tl.throughput[tl.t < c.fail_time].mean()
        post = tl.throughput[tl.t > c.fail_time + tl.stall + 1].mean()
        rows.append(Row(f"fig9/timeline/{nm}", tl.stall * 1e6,
                        f"thr_pre={pre:.0f} thr_post={post:.0f} tok/s"))

    # functional check on the real engine: EW failover must be exact
    prompt = np.arange(1, 9, dtype=np.int32)
    ref = reduced_engine(seed=7).generate("r", prompt, 12)
    eng = reduced_engine(seed=7)
    eng.client.submit(RequestSpec(rid="r", prompt=prompt, max_new=12))
    for _ in range(4):
        eng.step()
    eng.fail_ew(0)
    while not eng.requests["r"].done:
        eng.step()
    exact = eng.requests["r"].tokens == ref
    rows.append(Row("fig9/engine_ew_failover_exact", 0.0,
                    "exact" if exact else "MISMATCH"))
    return rows
