"""Elastic expert-worker plane: load-imbalance + stall trajectories across
placement generations (the PR-3 tentpole's acceptance benchmark).

Two sections, both on the real reduced engine:

  * **rebalance** — the ``skewed_expert_load`` workload (Zipf token ids ->
    a few hot experts) against a static placement vs the same workload with
    one load-aware rebalance installed mid-run. Reports the per-EW dispatch
    load imbalance (max/mean of the placement manager's EMAs, fed by the
    device-side summed-one-hot counters in ``refe.route``) before and after
    the plan flip, plus the imbalance trajectory.
  * **scale** — a serving run with EW scale-out, graceful scale-in, and an
    EW failure handled by *permanent shadow promotion*, all on the virtual
    clock (T_w + T_push modeled by the orchestrator). Reports TBT/stall
    around the events and the placement-generation audit trail: every
    transition must be a plan install (``placement_changed`` event), never
    a re-trace or a token gap beyond the detection stall.

Writes benchmarks/results/elastic.json; ``BENCH_SMOKE=1`` shrinks both
sections for the CI smoke step.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from benchmarks.common import Row, pct
from repro.serving.api import RequestSpec
from repro.configs import get_config
from repro.core.orchestrator import Orchestrator
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import FailurePlan, ScalePlan, run_serving

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "elastic.json")

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

NUM_EXPERTS = 16   # the stock reduced() caps at 4 experts — too few for
#                    token-skew to concentrate (top-2 of 4 touches half the
#                    bank every token); 16 routed experts over 4 EWs gives
#                    the rebalancer a realistic hot/cold spread to fix


def _elastic_engine(num_ew=4, max_ew=0, **kw):
    cfg = get_config("mixtral_8x7b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=NUM_EXPERTS, capacity_factor=4.0))
    ecfg = EngineConfig(max_batch=8, max_seq=96, num_aw=2, num_ew=num_ew,
                        max_ew=max_ew, **kw)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(0))


def _skewed_requests(n, vocab_hint=None):
    wl = make_workload("skewed_expert_load", rate_rps=8.0, duration=2.0,
                       seed=11)
    wl = [dataclasses.replace(w, arrival=0.0, prompt_len=10,
                              max_new_tokens=300) for w in wl]
    return wl[:n]


def _measure_rebalance():
    """Static vs rebalanced placement on the same skewed decode stream:
    per-EW dispatch-load imbalance trajectory, one plan flip in between."""
    steps_warm = 12 if SMOKE else 20    # EMA settles on the skew
    steps_after = 12 if SMOKE else 25
    out = {"workload": "skewed_expert_load", "num_ew": 4,
           "num_experts": NUM_EXPERTS}
    for label, do_rebalance in (("static", False), ("rebalanced", True)):
        eng = _elastic_engine(num_ew=4)
        for w in _skewed_requests(8):
            eng.client.submit(RequestSpec(
                rid=w.request_id,
                prompt=w.prompt_tokens(eng.cfg.vocab_size),
                max_new=w.max_new_tokens))
        traj = []
        for _ in range(steps_warm):
            eng.step()
            traj.append(eng.placement_mgr.imbalance())
        before = eng.placement_mgr.imbalance()
        if do_rebalance:
            eng.rebalance(now=float(eng.steps))
        for _ in range(steps_after):
            eng.step()
            traj.append(eng.placement_mgr.imbalance())
        after = eng.placement_mgr.imbalance()
        out[label] = {
            "imbalance_before": float(before),
            "imbalance_after": float(after),
            "per_ew_load": {str(k): round(v, 2) for k, v in
                            eng.placement_mgr.per_ew_load().items()},
            "generation": eng.placement_generation,
            "trajectory": [round(float(v), 3) for v in traj],
            "decode_jit_traces": eng._decode._cache_size(),
        }
    s, r = out["static"], out["rebalanced"]
    out["imbalance_reduction"] = (
        s["imbalance_after"] / max(r["imbalance_after"], 1e-9))
    return out


def _measure_scale_events():
    """Scale-out, scale-in, and failure->promotion on the serving loop's
    virtual clock: TBT around the events + the placement audit trail."""
    n_req = 6 if SMOKE else 10
    wl = make_workload("skewed_expert_load", rate_rps=20.0, duration=0.5,
                       seed=7)
    wl = [dataclasses.replace(w, prompt_len=8, max_new_tokens=40)
          for w in wl][:n_req]
    eng = _elastic_engine(num_ew=2, max_ew=4)
    orch = Orchestrator(eng, worker_init_time=0.4, weight_push_time=0.2,
                        ew_policy="promote")
    scales = [ScalePlan(0.5, "add_ew"),
              ScalePlan(2.0, "rebalance"),
              ScalePlan(3.5, "drain_ew", worker_id=2)]
    failures = [FailurePlan(5.0, "ew", 0)]
    m = run_serving(eng, wl, duration=600.0, orchestrator=orch,
                    failures=failures, scale_events=scales, step_time=0.02)
    tbt = m.tbt_values()
    gens = [e for e in orch.events if e.kind == "placement_changed"]
    return {
        "requests": len(wl), "finished": len(m.finished),
        "tbt_p50_s": pct(tbt, 50),
        "tbt_p99_s": pct(tbt, 99),
        "max_stall_s": m.max_stall(),
        "detect_stall_s": orch.detection_latency(),
        "final_pool": sorted(eng.live_ews),
        "final_generation": eng.placement_generation,
        "decode_jit_traces": eng._decode._cache_size(),
        "events": [f"t={e.t:.2f} {e.kind} {e.worker} {e.detail}"
                   for e in orch.events],
        "generations": [f"t={e.t:.2f} {e.worker}: {e.detail}"
                        for e in gens],
    }


def _measure_closed_loop_skew():
    """Closed-loop control plane vs the fixed-threshold auto-rebalancer on
    the same ``skewed_expert_load`` serving run: the controller fires off
    the imbalance EMA *trajectory* (slope + predicted crossing, so the
    plan lands around when the fixed threshold is first breached) and
    packs *weighted* split replicas sized to measured expert heat; the
    baseline waits for the instantaneous max/mean threshold and packs
    parity splits. Reports the imbalance trajectory, its post-warmup mean,
    and the rebalance/scale event counts."""
    n_req = 6 if SMOKE else 8
    max_new = 80 if SMOKE else 160
    wl = make_workload("skewed_expert_load", rate_rps=8.0, duration=2.0,
                       seed=11)
    wl = [dataclasses.replace(w, arrival=0.0, prompt_len=10,
                              max_new_tokens=max_new) for w in wl][:n_req]
    out = {"workload": "skewed_expert_load", "num_ew": 4,
           "num_experts": NUM_EXPERTS}
    for label, kw in (("fixed_threshold", {}),
                      ("controller", {"controller": "on"})):
        eng = _elastic_engine(num_ew=4, **kw)
        orch = Orchestrator(eng, worker_init_time=0.4,
                            weight_push_time=0.2,
                            auto_rebalance=(label == "fixed_threshold"))
        traj = []
        orig_step = eng.step

        def sampled_step(now=None, _eng=eng, _traj=traj, _orig=orig_step):
            o = _orig(now=now)
            _traj.append(float(_eng.placement_mgr.imbalance()))
            return o

        eng.step = sampled_step
        m = run_serving(eng, wl, duration=600.0, orchestrator=orch,
                        step_time=0.02)
        warm = min(len(traj) - 1, 15)   # EMA needs steps to see the skew
        settled = traj[warm:]
        rebs = sum(1 for e in orch.events
                   if e.kind == "rebalance_started")
        sec = {
            "finished": len(m.finished),
            "rebalances": rebs,
            "scale_events": sum(1 for e in orch.events if e.kind in
                                ("scale_out_started", "drain_started")),
            "imbalance_mean": float(np.mean(settled)),
            "imbalance_final": float(traj[-1]),
            "imbalance_peak": float(np.max(settled)),
            "generation": eng.placement_generation,
            "decode_jit_traces": eng._decode._cache_size(),
            "trajectory": [round(v, 3) for v in traj],
        }
        if label == "controller":
            sec["decisions"] = dict(eng.controller.counts)
            sec["first_trigger"] = next(
                (d["detail"] for d in eng.controller.decisions
                 if d["kind"] == "rebalance"), "")
        out[label] = sec
    f, c = out["fixed_threshold"], out["controller"]
    out["imbalance_mean_reduction_x"] = \
        f["imbalance_mean"] / max(c["imbalance_mean"], 1e-9)
    # acceptance: the trajectory trigger + weighted splits beat the fixed
    # threshold + parity splits on sustained per-EW max/mean imbalance
    assert c["imbalance_mean"] <= f["imbalance_mean"] + 1e-9, out
    assert c["rebalances"] >= 1, out
    return out


def _model_timelines():
    """GPU-comparable cost-model timelines (core/events.py) for the scale
    events: the paper-scale analogue of the measured engine section —
    scale-out/in are stall-free plan installs; promotion pays only the
    detection+flip stall, with fault tolerance back after T_push << T_w."""
    from repro.core import events as ev
    c = ev.SimConfig(duration=120.0, fail_time=60.0)
    out_tl = ev.simulate_tarragon_scale_out(c)
    in_tl = ev.simulate_tarragon_scale_in(c)
    pr_tl = ev.simulate_tarragon_promotion(c)
    rv_tl = ev.simulate_tarragon_ew_failure(c)
    return {
        "scale_out": {"stall_s": out_tl.stall, "events": out_tl.events},
        "scale_in": {"stall_s": in_tl.stall, "events": in_tl.events},
        "promotion": {"stall_s": pr_tl.stall, "events": pr_tl.events,
                      "vs_revive_stall_s": rv_tl.stall},
    }


def run():
    rows = []
    reb = _measure_rebalance()
    scale = _measure_scale_events()
    loop = _measure_closed_loop_skew()
    model = _model_timelines()
    payload = {"bench": "elastic", "rebalance": reb, "scale": scale,
               "closed_loop": loop, "model_timelines": model}
    rows.append(Row(
        "elastic/model/promotion_stall",
        model["promotion"]["stall_s"] * 1e6,
        f"scale_out_stall={model['scale_out']['stall_s']*1e3:.0f}ms "
        f"scale_in_stall={model['scale_in']['stall_s']*1e3:.0f}ms"))
    rows.append(Row(
        "elastic/imbalance/static",
        reb["static"]["imbalance_after"] * 1e6,
        f"max/mean={reb['static']['imbalance_after']:.2f}"))
    rows.append(Row(
        "elastic/imbalance/rebalanced",
        reb["rebalanced"]["imbalance_after"] * 1e6,
        f"max/mean={reb['rebalanced']['imbalance_after']:.2f} "
        f"reduction={reb['imbalance_reduction']:.2f}x "
        f"gen={reb['rebalanced']['generation']}"))
    rows.append(Row(
        "elastic/closed_loop/imbalance_mean",
        loop["controller"]["imbalance_mean"] * 1e6,
        f"fixed={loop['fixed_threshold']['imbalance_mean']:.3f} "
        f"ctl={loop['controller']['imbalance_mean']:.3f} "
        f"reduction={loop['imbalance_mean_reduction_x']:.2f}x "
        f"rebalances ctl={loop['controller']['rebalances']} "
        f"fixed={loop['fixed_threshold']['rebalances']} "
        f"jit_traces={loop['controller']['decode_jit_traces']}"))
    rows.append(Row(
        "elastic/scale_events/max_stall", scale["max_stall_s"] * 1e6,
        f"tbt_p99={scale['tbt_p99_s']*1e3:.1f}ms "
        f"pool={scale['final_pool']} gen={scale['final_generation']} "
        f"jit_traces={scale['decode_jit_traces']} "
        f"finished={scale['finished']}/{scale['requests']}"))
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows
