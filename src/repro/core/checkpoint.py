"""Asynchronous, incremental KV-cache checkpointing + per-request restoration
(paper §6).

The store mirrors the paper's RDMA design at the semantic level:

  * ``register_aw`` — AW announces its cache layout; the store allocates a
    bucket (here: a dict keyed by request id).
  * ``async_update`` — one-sided write of one token's KV segment, tagged with
    a monotonically increasing *sequence number*. Writes may arrive out of
    order (the RDMA WR reordering the paper guards against); the store only
    advances the **commit watermark** over a contiguous seq prefix, exactly
    the "async log + commit record" design (§6.1).
  * ``restore_request`` — returns the committed token index and the KV
    segments for one request, which the engine injects into a healthy AW's
    cache region (per-request restoration, §6.2). Uncommitted (gap) suffixes
    are never restored.

Segments are host numpy arrays (device_get of the [L, 2, Hkv, Dh] slice the
decode step just wrote) — the analogue of the GPUDirect one-sided write into
the store's pre-registered bucket.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


def _seg_nbytes(segment) -> int:
    if isinstance(segment, (list, tuple)):
        return sum(np.asarray(s).nbytes for s in segment)
    return np.asarray(segment).nbytes


@dataclass
class _RequestLog:
    segments: Dict[int, np.ndarray] = field(default_factory=dict)
    token_values: Dict[int, int] = field(default_factory=dict)
    # seq_no -> token_idx, for watermark accounting
    seq_to_token: Dict[int, int] = field(default_factory=dict)
    next_seq: int = 0              # AW-side monotonically increasing WR id
    committed_seq: int = -1        # highest contiguous seq received
    prompt_len: int = 0
    aw_id: int = -1

    @property
    def committed_token(self) -> int:
        """Highest token index restorable (contiguous-prefix rule)."""
        if self.committed_seq < 0:
            return -1
        return max((self.seq_to_token[s]
                    for s in range(self.committed_seq + 1)), default=-1)


@dataclass
class StoreStats:
    bytes_written: int = 0
    bytes_restored: int = 0
    updates: int = 0
    out_of_order: int = 0
    restores: int = 0


class CheckpointStore:
    """Host-side checkpoint store service."""

    def __init__(self):
        self._logs: Dict[str, _RequestLog] = {}
        self._aw_requests: Dict[int, set] = {}
        self.stats = StoreStats()

    # -- registration ------------------------------------------------------
    def register_request(self, request_id: str, aw_id: int,
                         prompt_len: int = 0):
        log = self._logs.setdefault(request_id, _RequestLog())
        log.aw_id = aw_id
        log.prompt_len = prompt_len
        self._aw_requests.setdefault(aw_id, set()).add(request_id)

    def reassign(self, request_id: str, new_aw: int):
        log = self._logs[request_id]
        self._aw_requests.get(log.aw_id, set()).discard(request_id)
        log.aw_id = new_aw
        self._aw_requests.setdefault(new_aw, set()).add(request_id)

    def release(self, request_id: str):
        log = self._logs.pop(request_id, None)
        if log is not None:
            self._aw_requests.get(log.aw_id, set()).discard(request_id)

    def rename(self, old: str, new: str):
        """Re-key a log (prefix-cache adoption: a finished request's log
        becomes the cache entry's restoration backing under a reserved
        key, so the original rid can be reused for a fresh request
        without inheriting — or corrupting — the cached segments)."""
        assert new not in self._logs, new
        log = self._logs.pop(old)
        self._logs[new] = log
        s = self._aw_requests.get(log.aw_id)
        if s is not None:
            s.discard(old)
            s.add(new)

    # -- write path ----------------------------------------------------------
    def next_seq(self, request_id: str) -> int:
        log = self._logs[request_id]
        s = log.next_seq
        log.next_seq += 1
        return s

    def async_update(self, request_id: str, token_idx: int,
                     segment, seq_no: int, token_value: int = -1):
        """One-sided write; tolerates out-of-order arrival. ``segment`` is a
        numpy array or a flat list of numpy arrays (one cache-leaf each);
        ``token_value`` is the token id emitted at ``token_idx`` (the store
        hands it back at restoration so decode can resume, §6.2)."""
        log = self._logs[request_id]
        log.segments[token_idx] = segment
        log.token_values[token_idx] = token_value
        log.seq_to_token[seq_no] = token_idx
        self.stats.updates += 1
        self.stats.bytes_written += _seg_nbytes(segment)
        if seq_no != log.committed_seq + 1:
            self.stats.out_of_order += 1
        # advance commit watermark over the contiguous prefix
        while (log.committed_seq + 1) in log.seq_to_token:
            log.committed_seq += 1

    # -- read / recovery path -----------------------------------------------
    def committed_token(self, request_id: str) -> int:
        return self._logs[request_id].committed_token

    def active_requests_on(self, aw_id: int) -> List[str]:
        return sorted(self._aw_requests.get(aw_id, set()))

    def restore_request(self, request_id: str
                        ) -> Tuple[int, int, Dict[int, list]]:
        """Per-request restoration: (committed token idx, token id at that
        idx, {token_idx: segment}).

        Only segments within the committed prefix are returned — segments
        beyond a sequence gap are unusable for recovery (§6.1).

        Restoration also truncates the log to the commit record: WRs past
        the watermark either died with the failed AW (dropped pending) or
        describe state the restored request is about to recompute, so the
        new owner's stream restarts at ``committed_seq + 1``. Without this
        a dropped WR's sequence number would leave a permanent gap and no
        later write could ever commit."""
        log = self._logs[request_id]
        c = log.committed_token
        committed_tokens = {log.seq_to_token[s]
                            for s in range(log.committed_seq + 1)}
        segs = {t: log.segments[t] for t in sorted(committed_tokens)
                if t in log.segments}
        log.seq_to_token = {s: t for s, t in log.seq_to_token.items()
                            if s <= log.committed_seq}
        log.segments = dict(segs)
        log.token_values = {t: v for t, v in log.token_values.items()
                            if t in committed_tokens}
        log.next_seq = log.committed_seq + 1
        self.stats.restores += 1
        self.stats.bytes_restored += sum(_seg_nbytes(s)
                                         for s in segs.values())
        return c, log.token_values.get(c, -1), segs


# --------------------------------------------------------------------------
# AW-side checkpointer
# --------------------------------------------------------------------------

class KVCheckpointer:
    """AW-side incremental checkpointing of decode-time KV segments.

    After each decode step the engine hands over the per-request segment
    (the KV slice the step just appended). The copy is issued immediately —
    the opportunistic-interleave claim (§6.1/Fig. 8) is that this transfer
    rides the AW-EW link's idle gaps; the event simulator models the timing,
    while here we preserve the *ordering/commit* semantics.

    ``reorder`` optionally shuffles delivery within a small window to
    exercise the out-of-order tolerance (tests).
    """

    def __init__(self, store: CheckpointStore, aw_id: int,
                 reorder_window: int = 0, seed: int = 0):
        self.store = store
        self.aw_id = aw_id
        self.reorder_window = reorder_window
        self._rng = np.random.default_rng(seed)
        self._pending: List[Tuple[str, int, np.ndarray, int]] = []

    def register(self, request_id: str, prompt_len: int = 0):
        self.store.register_request(request_id, self.aw_id, prompt_len)

    def checkpoint_token(self, request_id: str, token_idx: int,
                         segment, token_value: int = -1):
        seq = self.store.next_seq(request_id)
        self._pending.append((request_id, token_idx, segment, seq,
                              token_value))
        if len(self._pending) > self.reorder_window:
            self.flush()

    def checkpoint_range(self, request_id: str, start: int,
                         seg_stack: List[np.ndarray],
                         token_values: List[int]):
        """Bulk chunk-boundary path (§6.1 extended to prefill): stream the
        ``len(token_values)`` contiguous token segments a prefill chunk
        just produced, starting at token index ``start``. ``seg_stack`` is
        one array per cache leaf with a leading per-token axis (the output
        of CacheLayout.make_slot_range_extractor). Each token still gets
        its own sequence number, so the store's contiguous-prefix commit
        watermark applies unchanged; delivery rides the same reorder/flush
        policy as decode-time segments."""
        for i, tv in enumerate(token_values):
            self.checkpoint_token(request_id, start + i,
                                  [leaf[i] for leaf in seg_stack],
                                  token_value=int(tv))

    def checkpoint_blocks(self, request_id: str, start: int,
                          seg_stack: List[np.ndarray],
                          token_values: List[int], page_tokens: int):
        """Block-granular variant for paged AWs: split the token run at
        physical page boundaries, so each ``checkpoint_range`` batch
        covers at most one KV page and a page's worth of WRs commits (or
        dies with the worker) together. The store's segments remain
        token-granular and layout-independent — paged checkpoints restore
        onto contiguous engines and vice versa."""
        n = len(token_values)
        t = 0
        while t < n:
            take = min(n - t, page_tokens - ((start + t) % page_tokens))
            self.checkpoint_range(request_id, start + t,
                                  [leaf[t:t + take] for leaf in seg_stack],
                                  token_values[t:t + take])
            t += take

    def drop_pending(self) -> int:
        """Crash path: WRs not yet handed to the store die with the AW.
        Returns the number of segments lost (they stay uncommitted, so
        recovery resumes from the last committed token)."""
        n = len(self._pending)
        self._pending = []
        return n

    def drop_request(self, request_id: str) -> int:
        """Teardown path (cancel / release): discard this request's pending
        WRs without touching other requests' stream. Only valid right
        before the store log itself is released — the dropped WRs' sequence
        numbers are already allocated, so keeping the log would leave a
        permanent commit gap. Returns the number of WRs discarded."""
        kept = [p for p in self._pending if p[0] != request_id]
        n = len(self._pending) - len(kept)
        self._pending = kept
        return n

    def pending_for(self, request_id: str) -> int:
        return sum(1 for p in self._pending if p[0] == request_id)

    def flush(self):
        pending = self._pending
        if self.reorder_window and len(pending) > 1:
            idx = self._rng.permutation(len(pending))
            pending = [pending[i] for i in idx]
        for rid, tok, seg, seq, tv in pending:
            self.store.async_update(rid, tok, seg, seq, token_value=tv)
        self._pending = []
