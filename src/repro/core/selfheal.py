"""Self-healing state transitions (paper §5).

All transitions are *array updates* on RouteState — the device-side routing
consumes them on the next step without recompilation. This module also
carries the EW-side "sufficient subset" batching policy (§5.2) used by both
the engine and the event simulator.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ert as ert_lib
from repro.core.refe import RouteState


# --------------------------------------------------------------------------
# health transitions
# --------------------------------------------------------------------------

def fail_ew(rs: RouteState, ew_id: int) -> RouteState:
    return rs._replace(ew_health=rs.ew_health.at[ew_id].set(False))


def recover_ew(rs: RouteState, ew_id: int) -> RouteState:
    return rs._replace(ew_health=rs.ew_health.at[ew_id].set(True))


def fail_aw(rs: RouteState, aw_id: int) -> RouteState:
    return rs._replace(aw_health=rs.aw_health.at[aw_id].set(False))


def recover_aw(rs: RouteState, aw_id: int) -> RouteState:
    return rs._replace(aw_health=rs.aw_health.at[aw_id].set(True))


# --------------------------------------------------------------------------
# shadow re-pointing (background provisioning of expert capacity, §5.3-§5.4)
# --------------------------------------------------------------------------

def repoint_shadows(rs: RouteState, placement: ert_lib.ExpertPlacement,
                    protect_ew: int) -> RouteState:
    """Re-point the shadow slots to protect ``protect_ew``'s experts.

    Host-side weight push (NOT on the failover critical path). The bank is
    gathered through ``slot_expert`` at apply time, so re-pointing is a pure
    RouteState update: new candidates + slot residency, no param surgery.
    Engines with an ExpertPlacementManager go through its versioned
    ``plan_reprotect`` instead; this helper serves manager-less callers."""
    assign = ert_lib.initial_shadow_assignment(placement, protect_ew)
    cand = ert_lib.build_candidates(placement, assign)
    return rs._replace(
        candidates=jnp.asarray(cand, jnp.int32),
        slot_expert=jnp.asarray(
            ert_lib.initial_slot_expert(placement, assign), jnp.int32))


def experts_without_healthy_replica(rs: RouteState,
                                    placement: ert_lib.ExpertPlacement
                                    ) -> np.ndarray:
    """Logical experts currently unreachable (every candidate slot parked or
    on a dead EW) — these tokens are dropped until provisioning/re-protection
    completes."""
    _, alive = ert_lib.resolve_active_slots(
        rs.candidates, rs.ew_health, rs.slot_owner)
    return np.asarray(~alive).nonzero()[0]


# --------------------------------------------------------------------------
# EW-side sufficient-subset batching (§5.2)
# --------------------------------------------------------------------------

def ew_should_start(received_from: np.ndarray, aw_healthy: np.ndarray,
                    batch_tokens: int, min_batch: int,
                    probe_expired: bool) -> bool:
    """Decide whether an EW starts expert compute for a layer batch.

    Starts when (i) all currently-healthy AWs have delivered, or (ii) the
    buffered batch reached the GPU-efficiency knee ``min_batch``, or (iii)
    the probing window for missing AWs expired (they are then treated as
    failed for this layer and their slots omitted)."""
    healthy_delivered = bool(np.all(received_from[aw_healthy]))
    return healthy_delivered or batch_tokens >= min_batch or probe_expired
