"""Versioned expert placement: the elastic EW plane's control brain.

The paper treats EWs as stateless failure domains whose experts can be
re-pointed without stopping the pipeline (§5.3-§5.4). This module turns that
one-shot failover trick into a *placement subsystem*:

  * ``PlacementPlan`` — an immutable, generation-numbered snapshot of the
    expert plane: which logical expert is resident in each physical slot
    (``slot_expert``), which EW owns each slot (``slot_owner``), each
    expert's designated primary slot, and which replicas are load-bearing
    (``split_slot``). Installing a plan is a pure RouteState array update —
    ERT candidates and bank indices are rebuilt host-side and pushed as
    data, so the jitted decode/prefill steps never re-trace.
  * ``ExpertPlacementManager`` — owns the current plan plus per-expert
    dispatch-load EMAs (drained from the device-side summed one-hot counters
    in ``refe.route``) and computes new plans for the orchestrator's
    elasticity events: load-aware **rebalance** (replicate hot experts into
    spare slots, pack cold ones), **scale-out** (a joining EW takes over
    parked/stolen slots), **scale-in** (a draining EW's experts migrate
    out), **shadow promotion** (a dead EW's replicas become primaries
    permanently), and **re-protection** (fresh replicas for the most
    load-critical EW).

Weight movement is never on the jit path: a plan that changes residency
implies a host-side weight push, which the orchestrator charges to the
virtual clock as ``T_push`` before activating the plan (§5.4's
layer-aligned background join).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.core import ert as ert_lib


# number of ERT candidate columns: designated primary + one replica. The
# column count is a jit-visible shape, so it is fixed; plans express richer
# layouts by choosing WHICH replica fills column 1.
NUM_CANDIDATES = 2


@dataclass(frozen=True)
class PlacementPlan:
    """One generation of the expert plane. All arrays are host-side numpy;
    the engine converts them to device arrays on install."""

    generation: int
    slot_expert: np.ndarray      # [P] resident logical expert (-1 empty)
    slot_owner: np.ndarray       # [P] owning EW (-1 parked / EW gone)
    primary: np.ndarray          # [E] designated primary slot per expert
    split_slot: np.ndarray       # [E] load-bearing replica (-1 none)
    members: Tuple[int, ...]     # live EW pool at plan time (sorted)
    reason: str = ""

    @property
    def num_slots(self) -> int:
        return int(self.slot_expert.shape[0])

    @property
    def num_experts(self) -> int:
        return int(self.primary.shape[0])

    def candidates(self) -> np.ndarray:
        """ERT candidate table [E, NUM_CANDIDATES]: primary first, then the
        first replica on a *different, live* EW (a same-EW replica would die
        with the primary, exactly the legacy shadow rule)."""
        e = self.num_experts
        cand = np.full((e, NUM_CANDIDATES), -1, np.int32)
        cand[:, 0] = self.primary
        for s in range(self.num_slots):
            ex = self.slot_expert[s]
            if ex < 0 or s == self.primary[ex] or cand[ex, 1] >= 0:
                continue
            if self.slot_owner[s] < 0 or self.primary[ex] < 0:
                continue
            if self.slot_owner[s] != self.slot_owner[self.primary[ex]]:
                cand[ex, 1] = s
        return cand

    def replica_of(self, expert: int) -> int:
        return int(self.candidates()[expert, 1])

    def slots_of_ew(self, ew: int) -> np.ndarray:
        return np.nonzero(self.slot_owner == ew)[0]

    def resident_experts(self, ew: int) -> List[int]:
        return [int(self.slot_expert[s]) for s in self.slots_of_ew(ew)
                if self.slot_expert[s] >= 0]

    def moved_slots(self, prev: "PlacementPlan") -> int:
        """Slots whose (resident expert, owner) changed — the host-side
        weight-push volume a plan transition implies."""
        return int(np.sum((self.slot_expert != prev.slot_expert) |
                          (self.slot_owner != prev.slot_owner)))


@dataclass
class LoadStats:
    """Per-expert / per-EW dispatch-load EMAs, drained from device counters."""

    ema_expert: np.ndarray       # [E] EMA of per-step dispatched tokens
    ema_ew: np.ndarray           # [max_ew] EMA over slot owners
    total_recorded: float = 0.0  # raw tokens ever recorded (signal gate)
    decay: float = 0.9

    def record(self, slot_load: np.ndarray, slot_expert: np.ndarray,
               slot_owner: np.ndarray):
        per_e = np.zeros_like(self.ema_expert)
        per_w = np.zeros_like(self.ema_ew)
        live = (slot_expert >= 0) & (slot_load > 0)
        np.add.at(per_e, slot_expert[live], slot_load[live])
        owned = live & (slot_owner >= 0)
        np.add.at(per_w, slot_owner[owned], slot_load[owned])
        self.ema_expert = self.decay * self.ema_expert + \
            (1 - self.decay) * per_e
        self.ema_ew = self.decay * self.ema_ew + (1 - self.decay) * per_w
        self.total_recorded += float(slot_load.sum())


class ExpertPlacementManager:
    """Computes and versions PlacementPlans from load telemetry + pool
    membership. Pure host-side; the engine installs the arrays."""

    def __init__(self, placement: ert_lib.ExpertPlacement, num_ew: int,
                 max_ew: int = 0, ema_decay: float = 0.9,
                 rebalance_threshold: float = 1.25,
                 min_load_signal: float = 32.0):
        self.geom = placement
        self.max_ew = max(max_ew or num_ew, num_ew)
        self.members: List[int] = list(range(num_ew))
        self.load = LoadStats(
            ema_expert=np.zeros((placement.num_experts,), np.float64),
            ema_ew=np.zeros((self.max_ew,), np.float64), decay=ema_decay)
        self.rebalance_threshold = rebalance_threshold
        self.min_load_signal = min_load_signal
        # replica packing discipline for leftover slots:
        #   "parity"   — hottest-first onto the globally lightest EW (the
        #                pre-controller behavior, byte-identical plans)
        #   "weighted" — best-fit-decreasing against the measured per-EW
        #                deficit (set by the control plane)
        # Either way the DEVICE split is parity — a replica takes exactly
        # half its expert's traffic by (token, choice) parity — so the
        # mode changes which experts replicate and where, never routing
        # semantics, and stays bit-identical while capacity doesn't bind.
        self.split_mode = "parity"
        self.plan = self._initial_plan()
        self.history: List[PlacementPlan] = [self.plan]

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def record_slot_load(self, slot_load: np.ndarray):
        self.load.record(np.asarray(slot_load, np.float64),
                         self.plan.slot_expert, self.plan.slot_owner)

    def per_ew_load(self) -> Dict[int, float]:
        return {m: float(self.load.ema_ew[m]) for m in self.members}

    def imbalance(self) -> float:
        """max/mean dispatch load over pool members (1.0 = perfectly even)."""
        loads = np.asarray([self.load.ema_ew[m] for m in self.members])
        if loads.size == 0 or loads.sum() <= 0:
            return 1.0
        return float(loads.max() / loads.mean())

    def choose_protect_ew(self, exclude: Tuple[int, ...] = ()) -> int:
        """The EW whose failure would hurt most = highest dispatch load
        (ties -> lowest id). Replaces the orchestrator's hardcoded
        (worker_id + 1) % num_ew neighbor rule."""
        best, best_load = -1, -1.0
        for m in self.members:
            if m in exclude:
                continue
            load = float(self.load.ema_ew[m])
            if load > best_load + 1e-12:
                best, best_load = m, load
        if best < 0:
            best = min(self.members) if self.members else 0
        return best

    # ------------------------------------------------------------------
    # plan construction
    # ------------------------------------------------------------------
    def _initial_plan(self) -> PlacementPlan:
        """Generation 0 mirrors the legacy static layout exactly (identity
        primaries, striped shadows protecting EW0), so a manager-driven
        engine boots bit-identical to the pre-elastic one."""
        p = self.geom
        assign = ert_lib.initial_shadow_assignment(p)
        return PlacementPlan(
            generation=0,
            slot_expert=ert_lib.initial_slot_expert(p, assign),
            slot_owner=np.asarray(p.slot_owner(), np.int32),
            primary=np.arange(p.num_experts, dtype=np.int32),
            split_slot=np.full((p.num_experts,), -1, np.int32),
            members=tuple(self.members), reason="initial")

    def _commit(self, slot_expert, slot_owner, primary, split_slot,
                reason: str) -> PlacementPlan:
        plan = PlacementPlan(
            generation=self.plan.generation + 1,
            slot_expert=np.asarray(slot_expert, np.int32),
            slot_owner=np.asarray(slot_owner, np.int32),
            primary=np.asarray(primary, np.int32),
            split_slot=np.asarray(split_slot, np.int32),
            members=tuple(sorted(self.members)), reason=reason)
        self.plan = plan
        self.history.append(plan)
        return plan

    def _owned_slots(self, slot_owner: np.ndarray = None,
                     members: List[int] = None) -> int:
        so = self.plan.slot_owner if slot_owner is None else slot_owner
        mm = self.members if members is None else members
        return int(np.sum(np.isin(so, list(mm))))

    def _balanced_assignment(self, slot_owner: np.ndarray, reason: str,
                             pack_members: List[int] = None
                             ) -> PlacementPlan:
        """Greedy longest-processing-time packing of experts onto the member
        EWs' slots, by load EMA: hot experts spread first, cold ones pack
        into the gaps; leftover slots become load-bearing replicas of the
        hottest experts (placed off the primary's EW, halving its load under
        parity splitting).

        ``pack_members`` restricts *placement targets* (e.g. to currently
        healthy members during a revival window) without changing pool
        membership."""
        p = self.geom
        e = p.num_experts
        members = sorted(self.members if pack_members is None
                         else pack_members)
        owned = self._owned_slots(slot_owner, members)
        if owned < e:
            # refusing loudly beats silently orphaning reachable experts
            # (their tokens would reroute with no error)
            raise ValueError(
                f"cannot place {e} experts into {owned} owned slots "
                f"(targets={members}, reason={reason})")
        # uniform prior so zero-load experts still spread evenly
        load = self.load.ema_expert + max(1e-6, self.load.ema_expert.sum()
                                          / max(1, e)) * 0.01
        free: Dict[int, List[int]] = {
            m: list(np.nonzero(slot_owner == m)[0]) for m in members}
        ew_load = {m: 0.0 for m in members}
        slot_expert = np.full((p.num_slots,), -1, np.int32)
        primary = np.full((e,), -1, np.int32)
        order = np.argsort(-load, kind="stable")
        for ex in order:
            cands = [m for m in members if free[m]]
            if not cands:
                break
            m = min(cands, key=lambda w: (ew_load[w], w))
            s = free[m].pop(0)
            slot_expert[s] = ex
            primary[ex] = s
            ew_load[m] += float(load[ex])
        # replicas into leftover slots; a replica on a different EW than
        # the primary takes half the expert's traffic
        split_slot = np.full((e,), -1, np.int32)
        if self.split_mode == "weighted":
            self._weighted_splits(load, slot_owner, members, free, ew_load,
                                  slot_expert, primary, split_slot)
        else:
            # parity mode: hottest experts first, each onto the globally
            # lightest EW with a free slot
            for ex in order:
                if primary[ex] < 0 or split_slot[ex] >= 0:
                    continue
                home = int(slot_owner[primary[ex]])
                cands = [m for m in members if free[m] and m != home]
                if not cands:
                    continue
                half = float(load[ex]) / 2.0
                m = min(cands, key=lambda w: (ew_load[w], w))
                # only replicate if it actually helps the imbalance
                if ew_load[m] + half >= ew_load[home]:
                    continue
                s = free[m].pop(0)
                slot_expert[s] = ex
                split_slot[ex] = s
                ew_load[m] += half
                ew_load[home] -= half
        return self._commit(slot_expert, slot_owner, primary, split_slot,
                            reason)

    @staticmethod
    def _weighted_splits(load, slot_owner, members, free, ew_load,
                         slot_expert, primary, split_slot):
        """Best-fit-decreasing replica packing (``split_mode="weighted"``):
        each round targets the most-deficient member EW and picks the
        un-split expert whose half-heat best fills that EW's gap to the
        pool mean, instead of walking experts hottest-first. The replica
        still takes exactly half its expert's traffic on device; what this
        sizes to the measured load is WHICH experts replicate and WHERE —
        so a 70/20/10 heat profile lands replicas that close the 70's
        overhang rather than whatever the hottest-first walk happens to
        pick. Mutates ``free``/``ew_load``/``slot_expert``/``split_slot``
        in place."""
        while True:
            mean = sum(ew_load.values()) / max(1, len(ew_load))
            targets = [m for m in members if free[m] and ew_load[m] < mean]
            if not targets:
                return
            m = min(targets, key=lambda w: (ew_load[w], w))
            deficit = mean - ew_load[m]
            best_ex, best_fit = -1, None
            for ex in range(len(primary)):
                if primary[ex] < 0 or split_slot[ex] >= 0:
                    continue
                home = int(slot_owner[primary[ex]])
                if home == m:
                    continue
                half = float(load[ex]) / 2.0
                # the same improvement guard as parity mode: a split that
                # overshoots past its donor makes the imbalance worse
                if ew_load[m] + half >= ew_load[home]:
                    continue
                fit = abs(deficit - half)
                if best_fit is None or fit < best_fit - 1e-12:
                    best_ex, best_fit = ex, fit
            if best_ex < 0:
                return
            home = int(slot_owner[primary[best_ex]])
            half = float(load[best_ex]) / 2.0
            s = free[m].pop(0)
            slot_expert[s] = best_ex
            split_slot[best_ex] = s
            ew_load[m] += half
            ew_load[home] -= half

    def adopt(self, slot_expert, slot_owner=None, primary=None,
              split_slot=None, reason: str = "custom") -> PlacementPlan:
        """Version an externally computed assignment as the next generation
        (operator override; also the hook tests use to pin exotic layouts).
        Unspecified arrays carry over from the current plan."""
        plan = self.plan
        return self._commit(
            slot_expert,
            plan.slot_owner if slot_owner is None else slot_owner,
            plan.primary if primary is None else primary,
            np.full_like(plan.primary, -1) if split_slot is None
            else split_slot,
            reason)

    # ------------------------------------------------------------------
    # elasticity events
    # ------------------------------------------------------------------
    def should_rebalance(self) -> bool:
        return (len(self.members) > 1 and
                self._owned_slots() >= self.geom.num_experts and
                self.load.total_recorded >= self.min_load_signal and
                self.imbalance() > self.rebalance_threshold)

    def can_scale_out(self) -> bool:
        return any(w not in self.members for w in range(self.max_ew))

    def plan_rebalance(self, live: Tuple[int, ...] = None) -> PlacementPlan:
        """Load-aware re-packing over the current slot ownership. ``live``
        (when given) restricts placement to currently healthy members — a
        failed-but-member EW (revival in flight) must not be handed
        primaries it cannot serve."""
        pack = None if live is None else \
            [m for m in self.members if m in live]
        return self._balanced_assignment(self.plan.slot_owner.copy(),
                                         reason="rebalance",
                                         pack_members=pack)

    def plan_scale_out(self) -> Tuple[int, PlacementPlan]:
        """Admit a new EW: it takes parked slots first, then an even share
        stolen from the largest current owners; experts are then re-packed
        load-aware over the grown pool (§5.4 background join — the weight
        push happens off the critical path, charged as T_push)."""
        spare = [w for w in range(self.max_ew) if w not in self.members]
        if not spare:
            raise ValueError("EW pool already at max_ew "
                             f"({self.max_ew}); cannot scale out")
        new_ew = spare[0]
        slot_owner = self.plan.slot_owner.copy()
        self.members = sorted(self.members + [new_ew])
        share = self.geom.num_slots // len(self.members)
        granted = list(np.nonzero(slot_owner < 0)[0])[:share]
        for s in granted:
            slot_owner[s] = new_ew
        while len(granted) < share:
            counts = {m: int(np.sum(slot_owner == m))
                      for m in self.members if m != new_ew}
            donor = max(counts, key=lambda m: (counts[m], -m))
            donor_slots = np.nonzero(slot_owner == donor)[0]
            # prefer donating empty / replica slots over primaries
            s = min(donor_slots,
                    key=lambda x: (self.plan.slot_expert[x] >= 0 and
                                   self.plan.primary[
                                       self.plan.slot_expert[x]] == x, x))
            slot_owner[s] = new_ew
            granted.append(int(s))
        plan = self._balanced_assignment(slot_owner,
                                         reason=f"scale_out ew{new_ew}")
        return new_ew, plan

    def plan_scale_in(self, ew: int) -> PlacementPlan:
        """Graceful drain: the EW's slots park, its resident experts migrate
        into the remaining members' slots (weight push = T_push; the EW keeps
        serving the old plan until the new one activates)."""
        if ew not in self.members:
            raise ValueError(f"EW{ew} is not a pool member")
        if len(self.members) <= 1:
            raise ValueError("cannot drain the last EW")
        slot_owner = self.plan.slot_owner.copy()
        slot_owner[slot_owner == ew] = -1
        remaining = int(np.sum(slot_owner >= 0))
        if remaining < self.geom.num_experts:
            raise ValueError(
                f"draining EW{ew} leaves {remaining} slots for "
                f"{self.geom.num_experts} experts")
        self.members = [m for m in self.members if m != ew]
        return self._balanced_assignment(slot_owner,
                                         reason=f"scale_in ew{ew}")

    def promote_shadows(self, dead_ew: int) -> PlacementPlan:
        """Permanent shadow promotion (pool shrinks instead of reviving):
        every expert whose primary died re-points to its live replica as the
        new primary — an instant, zero-push array flip. Experts with no live
        replica stay parked (masked) until a re-protection plan lands."""
        if dead_ew not in self.members:
            raise ValueError(f"EW{dead_ew} is not a pool member")
        plan = self.plan
        cand = plan.candidates()
        slot_expert = plan.slot_expert.copy()
        slot_owner = plan.slot_owner.copy()
        primary = plan.primary.copy()
        split_slot = plan.split_slot.copy()
        self.members = [m for m in self.members if m != dead_ew]
        for ex in range(plan.num_experts):
            pr = primary[ex]
            if pr >= 0 and slot_owner[pr] == dead_ew:
                rep = cand[ex, 1]
                if rep >= 0 and slot_owner[rep] >= 0 and \
                        slot_owner[rep] != dead_ew:
                    primary[ex] = rep
            if split_slot[ex] >= 0 and slot_owner[split_slot[ex]] == dead_ew:
                split_slot[ex] = -1
        # the dead EW's slots (and the weights in them) are gone: park them
        dead_slots = slot_owner == dead_ew
        slot_expert[dead_slots] = -1
        slot_owner[dead_slots] = -1
        return self._commit(slot_expert, slot_owner, primary, split_slot,
                            reason=f"promote ew{dead_ew}")

    def plan_reprotect(self, protect_ew: int,
                       dead_ews: Tuple[int, ...] = ()) -> PlacementPlan:
        """Re-point the non-primary (replica) slots to protect
        ``protect_ew``'s resident experts — the background weight push after
        a failure or promotion (§5.3's pre-loading, now plan-versioned).
        Every protected expert gets a replica on a *different* EW.

        ``dead_ews``: members currently failed (not yet revived). Replicas
        that are the only reachable copy of a dead EW's experts are load-
        bearing failover paths and are NOT recycled."""
        plan = self.plan
        slot_expert = plan.slot_expert.copy()
        slot_owner = plan.slot_owner.copy()
        primary = plan.primary.copy()
        split_slot = np.full_like(plan.split_slot, -1)
        is_primary = np.zeros((plan.num_slots,), bool)
        for ex in range(plan.num_experts):
            if primary[ex] >= 0:
                is_primary[primary[ex]] = True
        # clear replica slots (keep primaries, and keep the active failover
        # replicas of experts whose primary EW is down)
        for s in range(plan.num_slots):
            if slot_owner[s] < 0 or is_primary[s]:
                continue
            ex = slot_expert[s]
            if ex >= 0 and primary[ex] >= 0 and \
                    slot_owner[primary[ex]] in dead_ews and \
                    slot_owner[s] not in dead_ews:
                continue
            slot_expert[s] = -1
        protected = [ex for ex in plan.resident_experts(protect_ew)
                     if primary[ex] >= 0 and
                     slot_owner[primary[ex]] == protect_ew]
        # orphans first: experts with a parked/dead primary get re-homed
        # into free slots (they are unreachable until this lands). Free
        # slots on still-dead EWs are useless as targets — a replica there
        # would be born unreachable.
        orphans = [ex for ex in range(plan.num_experts)
                   if primary[ex] < 0 or slot_owner[primary[ex]] < 0]
        free = [s for s in range(plan.num_slots)
                if slot_owner[s] >= 0 and slot_owner[s] not in dead_ews and
                slot_expert[s] < 0]
        for ex in orphans:
            if not free:
                break
            s = free.pop(0)
            slot_expert[s] = ex
            primary[ex] = s
        for ex in protected:
            home = slot_owner[primary[ex]]
            pick = next((s for s in free if slot_owner[s] != home), None)
            if pick is None:
                continue
            free.remove(pick)
            slot_expert[pick] = ex
        return self._commit(slot_expert, slot_owner, primary, split_slot,
                            reason=f"reprotect ew{protect_ew}")

    # ------------------------------------------------------------------
    def ew_member_mask(self) -> np.ndarray:
        mask = np.zeros((self.max_ew,), bool)
        mask[list(self.members)] = True
        return mask


def push_seconds(moved_slots: int, d_model: int, d_ff: int,
                 link_gbps: float = 400.0, bytes_per_el: int = 2,
                 gated: bool = True) -> float:
    """Host-side weight-push time for a plan transition: bytes of expert
    weights whose residency changed, over the provisioning link."""
    per_expert = (3 if gated else 2) * d_model * d_ff * bytes_per_el
    return moved_slots * per_expert / (link_gbps / 8 * 1e9)
