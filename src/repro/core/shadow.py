"""Shadow experts (paper §5.3): pre-loaded, normally-inactive expert replicas.

Weights live in a separate *shadow bank* appended to the physical slot space
(slots E..P-1). The bank is populated host-side by the orchestrator
("pre-loading into residual GPU memory"); activation is purely an ERT flip —
no weight movement on the failover critical path, which is the point.

Inactive shadows consume memory but no compute: the dispatch one-hot never
selects an inactive slot, so its [C, D] input buffer stays zero and (on real
hardware) the Pallas moe_gemm tile for an empty slot is skippable. This
mirrors App. D's measurement that a loaded-but-idle shadow adds no latency.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.ert import ExpertPlacement


def sync_shadow_bank(expert_params: dict, shadow_assignment) -> dict:
    """Populate the shadow bank from primary expert weights.

    expert_params: {"wg": [..., E, D, F], "wu": [..., E, D, F],
    "wd": [..., E, F, D]} — the expert axis is -3 in every bank (works both
    for per-layer params and scan-stacked [R, E, ...] params).
    shadow_assignment: [S] int32 — resident logical expert per shadow slot.
    Returns the shadow bank with the same keys, expert axis sized S.
    """
    idx = jnp.asarray(shadow_assignment)
    return {k: jnp.take(v, idx, axis=-3) for k, v in expert_params.items()}


def full_slot_bank(expert_params: dict, shadow_bank: dict,
                   primary_slots: int = 0) -> dict:
    """Concatenate primary + shadow banks into the [..., P, ...] slot bank.
    Primaries are zero-padded to ``primary_slots`` (sharding divisibility —
    pad slots hold zero weights and the ERT never routes to them)."""
    out = {}
    for k in expert_params:
        prim = expert_params[k]
        e = prim.shape[-3]
        if primary_slots and primary_slots > e:
            pad_widths = [(0, 0)] * prim.ndim
            pad_widths[prim.ndim - 3] = (0, primary_slots - e)
            prim = jnp.pad(prim, pad_widths)
        out[k] = jnp.concatenate([prim, shadow_bank[k]], axis=-3)
    return out


def shadow_memory_bytes(placement: ExpertPlacement, d_model: int, d_ff: int,
                        bytes_per_el: int = 2, gated: bool = True) -> int:
    """Residual-memory cost of the shadow bank (paper §5.3's budget check)."""
    per_expert = (3 if gated else 2) * d_model * d_ff * bytes_per_el
    return placement.num_shadow_slots * per_expert
