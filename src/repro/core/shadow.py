"""Shadow experts (paper §5.3): pre-loaded, normally-inactive expert replicas.

Weights live in a separate *shadow bank* appended to the physical slot space
(slots E..P-1). The bank is populated host-side by the orchestrator
("pre-loading into residual GPU memory"); activation is purely an ERT flip —
no weight movement on the failover critical path, which is the point.

Inactive shadows consume memory but no compute: the dispatch one-hot never
selects an inactive slot, so its [C, D] input buffer stays zero and (on real
hardware) the Pallas moe_gemm tile for an empty slot is skippable. This
mirrors App. D's measurement that a loaded-but-idle shadow adds no latency.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.ert import ExpertPlacement


def resident_slot_bank(expert_params: dict, slot_expert) -> dict:
    """Gather the full [..., P, ...] slot bank through the slot-indirection
    array (RouteState.slot_expert): slot s serves the weights of its
    resident logical expert. Runs *inside* the jitted step, so a placement
    change (rebalance / promotion / scale event) re-points the bank without
    a new trace — the simulation stand-in for weights the orchestrator's
    background push (T_push on the virtual clock) made resident. Empty
    slots (-1) gather row 0 but are never routed to."""
    idx = jnp.maximum(jnp.asarray(slot_expert), 0)
    return {k: jnp.take(v, idx, axis=-3) for k, v in expert_params.items()}


def shadow_memory_bytes(placement: ExpertPlacement, d_model: int, d_ff: int,
                        bytes_per_el: int = 2, gated: bool = True) -> int:
    """Residual-memory cost of the shadow bank (paper §5.3's budget check)."""
    per_expert = (3 if gated else 2) * d_model * d_ff * bytes_per_el
    return placement.num_shadow_slots * per_expert
