"""Recovery cost model — paper §2.2.2, Eq. (1)-(4).

For a failure while decoding token i at frontier layer l of an L-layer model:

  monolithic / decoupled-AW failure (full replay):
      T_stall(l, i) ~= T_w + L*t_pre + ((i-1)*L + l) * t_dec          (1)
      G(l, i)      ~= M * (L*g_pre + ((i-1)*L + l) * g_dec)          (3)

  decoupled EW failure (stateless replay at the frontier):
      T_stall ~= T_w + t_dec                                          (2)
      G       ~= g_dec                                                (4)

  Tarragon (derived in §3/§6; audited by the failover simulator):
      AW failure: detection + per-request restore + 1 frontier layer
      EW failure: detection + reroute to shadow + 1 frontier layer
      (T_w moves off the critical path: background provisioning)
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class DeploymentProfile:
    """Profiled parameters (paper Table 1 units: seconds / GPU-time)."""

    name: str
    T_w: float        # worker (re)init: process + CUDA ctx + weights + comms
    t_pre: float      # one prefill layer (whole prompt), seconds
    t_dec: float      # one decoding layer (single token), seconds
    g_pre: float      # GPU-time of one prefill layer
    g_dec: float      # GPU-time of one decoding layer
    num_workers: int = 16


# Paper Table 1 (Mixtral-8x7B, 32 layers, 16 workers)
VLLM_PROFILE = DeploymentProfile("vLLM", 24.0, 1.68e-3, 0.58e-3,
                                 0.010, 0.0028)
MEGASCALE_PROFILE = DeploymentProfile("MegaScale-Infer", 18.5, 2.18e-3,
                                      0.85e-3, 0.006, 0.0022)


@dataclass(frozen=True)
class TarragonProfile:
    """Tarragon-side recovery constants (§5-§7)."""

    detect: float = 0.010       # probe interval (10 ms, §7.1)
    detect_retries: int = 3     # consecutive timeouts -> fail-stop (App. E)
    ert_update: float = 0.001   # orchestrator pushes new ERT/health arrays
    restore_per_token: float = 2.0e-6   # checkpoint-store -> AW copy, per
                                        # token KV segment (one-sided write)
    restore_fixed: float = 0.050        # per-request control overhead
    shadow_activate: float = 0.001      # ERT flip; weights already resident
    resched: float = 0.25       # batch re-formation + pipeline refill after
                                # failover (measured-system effect, §7.2)


# Measured-system overheads of a coarse-grained FULL restart beyond Eq. (1):
# staggered restart of all workers, weight-reload contention on shared
# storage, CCL re-initialization and scheduler warm-up. Eq. (1) with Table-1
# constants gives ~22 s for the Fig. 9 setting; the paper *measures* ~64 s.
# The audit benchmark reports both (model vs measured-calibrated).
FULL_RESTART_EXTRA = 42.0


def stall_monolithic(p: DeploymentProfile, L: int, layer: int, i: int):
    return p.T_w + L * p.t_pre + ((i - 1) * L + layer) * p.t_dec


def stall_decoupled_aw(p: DeploymentProfile, L: int, layer: int, i: int):
    # same replay structure as monolithic (Fig. 3b)
    return stall_monolithic(p, L, layer, i)


def stall_decoupled_ew(p: DeploymentProfile, L: int, layer: int, i: int):
    return p.T_w + p.t_dec


def gputime_monolithic(p: DeploymentProfile, L: int, layer: int, i: int):
    return p.num_workers * (L * p.g_pre + ((i - 1) * L + layer) * p.g_dec)


def gputime_decoupled_aw(p: DeploymentProfile, L: int, layer: int, i: int):
    return gputime_monolithic(p, L, layer, i)


def gputime_decoupled_ew(p: DeploymentProfile, L: int, layer: int, i: int):
    return p.g_dec


def stall_tarragon_aw(p: DeploymentProfile, t: TarragonProfile, L: int,
                      layer: int, i: int, tokens_to_restore: int):
    """Per-request restoration: detection + restore + resume at frontier.
    No prefill/decode replay; T_w is off the critical path."""
    detect = t.detect * t.detect_retries
    restore = t.restore_fixed + tokens_to_restore * L * t.restore_per_token
    return detect + t.ert_update + t.resched + restore + layer * p.t_dec


def stall_tarragon_ew(p: DeploymentProfile, t: TarragonProfile, L: int,
                      layer: int, i: int):
    """Shadow-expert failover: detection + ERT flip + frontier replay."""
    detect = t.detect * t.detect_retries
    return detect + t.shadow_activate + t.ert_update + t.resched + p.t_dec


def gputime_tarragon_aw(p: DeploymentProfile, L: int, layer: int, i: int):
    # only the frontier layer of the affected request is recomputed
    return layer * p.g_dec / max(1, L)


def gputime_tarragon_ew(p: DeploymentProfile, L: int, layer: int, i: int):
    return p.g_dec


# --------------------------------------------------------------------------
# Checkpoint traffic model (paper Appendix C)
# --------------------------------------------------------------------------

def kv_segment_bytes(d_model: int, n_heads: int, n_kv_heads: int,
                     bytes_per_el: int = 2) -> int:
    """C = 2 * H_kv * (hidden/H_attn) * S_elem — per token per layer."""
    return 2 * n_kv_heads * (d_model // n_heads) * bytes_per_el


def expert_traffic_bytes(d_model: int, top_k: int,
                         bytes_per_el: int = 2) -> int:
    """V = 2 * top_k * hidden * S_elem — per token per MoE layer."""
    return 2 * top_k * d_model * bytes_per_el


def checkpoint_traffic_ratio(d_model: int, n_heads: int, n_kv_heads: int,
                             top_k: int) -> float:
    return kv_segment_bytes(d_model, n_heads, n_kv_heads) / \
        expert_traffic_bytes(d_model, top_k)
