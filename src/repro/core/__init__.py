"""Tarragon core: reconfigurable expert routing (ERT/REFE), shadow experts,
self-healing health masks, KV-cache checkpointing, orchestrator control plane,
recovery cost model and the failover event simulator."""
