"""Centralized orchestrator (paper Fig. 5): liveness monitoring, ERT/health
updates on failures, per-request restoration triggering, background worker
provisioning, and — on top of the versioned placement plane
(core/placement.py) — EW pool elasticity: scale-out/scale-in with the
weight-push time ``T_push`` modeled on the virtual clock, permanent shadow
promotion as an alternative to revival, and load-aware rebalancing driven
by the placement manager's dispatch-load EMAs.

Failure detection model (§5 + App. E): implicit heartbeats are the per-step
data-plane activity; a silent worker gets explicit probes every
``detect_interval``; after ``retries`` consecutive timeouts the worker is
declared fail-stop and self-healing fires.

EW failure policies:
  * ``revive``  (default) — classic §5.4: shadows absorb traffic, a
    replacement worker is provisioned in the background (T_w) and the
    shadow slots are re-pointed to protect the placement manager's choice
    of most-load-critical EW (no more hardcoded neighbor).
  * ``promote`` — elastic: the dead EW's shadows are promoted to primaries
    *permanently* (instant ERT flip, zero weight movement) and the pool
    shrinks; a re-protection plan (fresh replicas for the now most-critical
    EW) lands after T_push. Recovery becomes a routing update, not a
    revival event.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.costmodel import TarragonProfile


@dataclass
class WorkerEvent:
    t: float
    kind: str       # fail_aw|fail_ew|detected|healed|provisioned|
    #                 placement_changed|scale_out_started|scaled_out|
    #                 drain_started|scaled_in|rebalance_started|rebalanced|
    #                 preempted|cancelled|deadline_missed (request plane)
    worker: str
    detail: str = ""


@dataclass
class _PendingFailure:
    kind: str
    worker_id: int
    t_fail: float
    detected: bool = False


@dataclass
class _PendingProvision:
    kind: str       # "aw" | "ew" | "reprotect"
    worker_id: int
    t_ready: float


@dataclass
class _PendingScale:
    kind: str       # "add_ew" | "drain_ew" | "rebalance"
    worker_id: int  # -1 for add/rebalance
    t_ready: float


class Orchestrator:
    def __init__(self, engine, profile: Optional[TarragonProfile] = None,
                 worker_init_time: float = 18.5,
                 weight_push_time: float = 1.0,
                 ew_policy: str = "revive",
                 auto_rebalance: bool = False,
                 rebalance_cooldown: float = 2.0):
        assert ew_policy in ("revive", "promote")
        self.engine = engine
        self.profile = profile or TarragonProfile()
        self.T_w = worker_init_time
        self.T_push = weight_push_time
        self.ew_policy = ew_policy
        self.auto_rebalance = auto_rebalance
        self.rebalance_cooldown = rebalance_cooldown
        self._last_rebalance = -1e30
        self.events: List[WorkerEvent] = []
        self._failures: List[_PendingFailure] = []
        self._provisions: List[_PendingProvision] = []
        self._scales: List[_PendingScale] = []
        # telemetry plane (serving/telemetry.py): control-plane events
        # publish to the engine's bus at emission, so cursor-based
        # consumers see them without waiting for (or racing) this
        # orchestrator's own audit log
        self.bus = getattr(engine, "bus", None)
        # control plane (serving/controller.py): the controller decides,
        # this orchestrator actuates — attach so scale/rebalance requests
        # land on the same virtual clock as operator-driven ones
        ctl = getattr(engine, "controller", None)
        if ctl is not None:
            ctl.attach_orchestrator(self)
        # forensics plane (serving/flightrec.py): pin this orchestrator's
        # timing/policy parameters so a postmortem bundle can rebuild an
        # identically-clocked one for replay
        fr = getattr(engine, "flightrec", None)
        if fr is not None:
            fr.note_orchestrator(self)

    def _emit(self, ev: WorkerEvent):
        self.events.append(ev)
        if self.bus is not None:
            self.bus.publish(ev)
        return ev

    # -- failure injection (the SIGINT of §7.2) -----------------------------
    def inject_failure(self, kind: str, worker_id: int, now: float):
        assert kind in ("aw", "ew")
        self._failures.append(_PendingFailure(kind, worker_id, now))
        self._emit(WorkerEvent(now, f"fail_{kind}", f"{kind}{worker_id}"))

    def detection_latency(self) -> float:
        return self.profile.detect * self.profile.detect_retries

    # -- elasticity requests (complete after T_w / T_push on the clock) -----
    def request_scale_out(self, now: float):
        """Grow the EW pool by one: worker init (T_w) + expert weight push
        (T_push) happen in the background; the layer-aligned join (§5.4)
        installs the new plan between steps once both complete. Validated
        at request time — a bad request should fail at the call site, not
        crash the control loop T_w seconds later."""
        mgr = self.engine.placement_mgr
        if mgr is None:
            raise ValueError("scale_out requires an elastic expert plane "
                             "(MoE + tarragon)")
        if not mgr.can_scale_out():
            raise ValueError(f"EW pool already at max_ew={mgr.max_ew}; "
                             "raise EngineConfig.max_ew to add spares")
        t_ready = now + self.T_w + self.T_push
        self._scales.append(_PendingScale("add_ew", -1, t_ready))
        self._emit(WorkerEvent(
            now, "scale_out_started", "ew?",
            f"join in T_w+T_push={self.T_w + self.T_push:.2f}s"))

    def request_scale_in(self, ew: int, now: float):
        """Drain an EW: its resident experts migrate to the survivors
        (weight push = T_push, during which it keeps serving the old
        plan), then it retires to spare."""
        mgr = self.engine.placement_mgr
        if mgr is None or ew not in mgr.members:
            raise ValueError(f"EW{ew} is not an elastic pool member")
        if len(mgr.members) <= 1:
            raise ValueError("cannot drain the last EW")
        self._scales.append(_PendingScale("drain_ew", ew, now + self.T_push))
        self._emit(WorkerEvent(
            now, "drain_started", f"ew{ew}",
            f"migrating experts, T_push={self.T_push:.2f}s"))

    def request_rebalance(self, now: float):
        if self.engine.placement_mgr is None:
            raise ValueError("rebalance requires an elastic expert plane "
                             "(MoE + tarragon)")
        self._scales.append(_PendingScale("rebalance", -1,
                                          now + self.T_push))
        self._emit(WorkerEvent(now, "rebalance_started", "pool",
                               f"T_push={self.T_push:.2f}s"))

    def _maybe_auto_rebalance(self, now: float):
        mgr = getattr(self.engine, "placement_mgr", None)
        if mgr is None or not self.auto_rebalance:
            return
        if now - self._last_rebalance < self.rebalance_cooldown:
            return
        if any(s.kind == "rebalance" for s in self._scales):
            return
        if self.engine.failed_ews:
            # mid-failure is the wrong moment to churn placement: wait for
            # revival/promotion to settle, then judge the real imbalance
            return
        if mgr.should_rebalance():
            self._last_rebalance = now
            self.request_rebalance(now)

    # -- control loop --------------------------------------------------------
    def tick(self, now: float) -> List[WorkerEvent]:
        """Advance the control plane to virtual time ``now``. Returns the
        events that fired during this tick."""
        fired: List[WorkerEvent] = []
        for f in self._failures:
            if f.detected or now < f.t_fail + self.detection_latency():
                continue
            f.detected = True
            ev = WorkerEvent(now, "detected", f"{f.kind}{f.worker_id}")
            tel = getattr(self.engine, "telemetry", None)
            if tel is not None:
                # the detection window [t_fail, now] is the T_w component
                # of every stall this failure causes
                tel.on_failure_detected(f.kind, f.worker_id, f.t_fail, now)
            if f.kind == "ew":
                # AW-side self-healing: ERT remap to shadows (instant once
                # detected)
                self.engine.fail_ew(f.worker_id)
                if self.ew_policy == "promote" and \
                        self.engine.placement_mgr is not None:
                    # permanent promotion: pool shrinks, shadows become
                    # primaries now; fresh replicas for the most critical
                    # survivor land after the background weight push
                    self.engine.promote_shadows(f.worker_id, now=now)
                    ev.detail = "shadows promoted to primaries (pool -1)"
                    self._provisions.append(_PendingProvision(
                        "reprotect", f.worker_id, now + self.T_push))
                else:
                    ev.detail = "ERT remap -> shadow experts"
                    self._provisions.append(
                        _PendingProvision(f.kind, f.worker_id,
                                          now + self.T_w))
            else:
                # EW-side self-healing: health mask drops the AW's slots;
                # per-request restoration re-admits its requests through
                # the Gateway (unplaceable ones stay queued and retry).
                self.engine.fail_aw(f.worker_id)
                n = len(self.engine.recover_aw_requests(now=now))
                ev.detail = f"restored {n} requests"
                waiting = self.engine.gateway.depth()
                if waiting:
                    ev.detail += f" ({waiting} queued for retry)"
                self._provisions.append(
                    _PendingProvision(f.kind, f.worker_id, now + self.T_w))
            self._emit(ev)
            fired.append(ev)

        remaining = []
        for p in self._provisions:
            if now < p.t_ready:
                remaining.append(p)
                continue
            if p.kind == "ew":
                # layer-aligned join (§5.4) + shadow re-pointing to protect
                # the placement manager's pick of most-load-critical EW
                # (background weight push) — no hardcoded neighbor. Still-
                # failed EWs are excluded both as protect target and from
                # replica recycling (their failover replicas stay pinned).
                dead = self.engine.failed_ews - {p.worker_id}
                protect = self.engine.choose_protect_ew(exclude=dead)
                if protect is None:
                    protect = (p.worker_id + 1) % max(
                        1, len(self.engine.ews))
                self.engine.provision_ew(p.worker_id,
                                         repoint_protect=protect, now=now)
                ev = WorkerEvent(now, "provisioned", f"ew{p.worker_id}",
                                 f"shadows protect ew{protect}")
            elif p.kind == "reprotect":
                protect = self.engine.choose_protect_ew(
                    exclude=self.engine.failed_ews)
                if protect is not None:
                    self.engine.repoint_shadows(protect, now=now)
                ev = WorkerEvent(now, "reprotected", f"ew{p.worker_id}",
                                 f"new replicas protect ew{protect}")
            else:
                self.engine.provision_aw(p.worker_id)
                # freshly provisioned capacity drains the waiting queue
                # (recovery entries sit at the front)
                self.engine.scheduler.admit(now)
                ev = WorkerEvent(now, "provisioned", f"aw{p.worker_id}")
            self._emit(ev)
            fired.append(ev)
        self._provisions = remaining

        remaining_s = []
        for s in self._scales:
            if now < s.t_ready:
                remaining_s.append(s)
                continue
            try:
                if s.kind == "add_ew":
                    new_ew = self.engine.add_ew(now=now)
                    # a scale-out invalidates the rebalance cooldown: the
                    # joiner starts empty, and a rebalance suppressed by a
                    # recent (pre-join) window would leave it idle for the
                    # rest of the cooldown — reset so the next auto pass
                    # may ship load to it immediately
                    self._last_rebalance = -1e30
                    ev = WorkerEvent(now, "scaled_out", f"ew{new_ew}",
                                     f"pool={sorted(self.engine.live_ews)}")
                elif s.kind == "drain_ew":
                    self.engine.drain_ew(s.worker_id, now=now)
                    ev = WorkerEvent(now, "scaled_in", f"ew{s.worker_id}",
                                     f"pool={sorted(self.engine.live_ews)}")
                else:
                    plan = self.engine.rebalance(now=now)
                    detail = f"gen{plan.generation}" if plan is not None \
                        else ""
                    ev = WorkerEvent(now, "rebalanced", "pool", detail)
            except ValueError as e:
                # the pool changed between request and completion (e.g. the
                # drain target died and was promoted away): surface it as an
                # event, don't kill the control loop
                ev = WorkerEvent(now, "scale_failed", s.kind, str(e))
            self._emit(ev)
            fired.append(ev)
        self._scales = remaining_s

        self._maybe_auto_rebalance(now)

        # surface placement-generation changes made by the engine this tick
        # (benchmarks/tests audit plan generations through the event log).
        # These were already published to the bus at emission — the drains
        # below only feed this legacy audit log, never the bus.
        for ev in self.engine.drain_plan_events() \
                if hasattr(self.engine, "drain_plan_events") else []:
            self.events.append(ev)
            fired.append(ev)
        # ... and request-lifecycle events (preempted/cancelled/
        # deadline_missed): the admission plane's timeline rides the same
        # audit log as the worker plane's
        for ev in self.engine.drain_request_events() \
                if hasattr(self.engine, "drain_request_events") else []:
            self.events.append(ev)
            fired.append(ev)
        return fired

    @property
    def outstanding(self) -> int:
        return len(self._provisions) + len(self._scales) + \
            sum(1 for f in self._failures if not f.detected)
