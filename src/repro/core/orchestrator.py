"""Centralized orchestrator (paper Fig. 5): liveness monitoring, ERT/health
updates on failures, per-request restoration triggering, and background
worker provisioning — over a virtual clock so detection latency and
provisioning time (T_w) are modelled faithfully while the functional
recovery runs for real on the engine.

Failure detection model (§5 + App. E): implicit heartbeats are the per-step
data-plane activity; a silent worker gets explicit probes every
``detect_interval``; after ``retries`` consecutive timeouts the worker is
declared fail-stop and self-healing fires.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.costmodel import TarragonProfile


@dataclass
class WorkerEvent:
    t: float
    kind: str       # fail_aw|fail_ew|detected|healed|provisioned
    worker: str
    detail: str = ""


@dataclass
class _PendingFailure:
    kind: str
    worker_id: int
    t_fail: float
    detected: bool = False


@dataclass
class _PendingProvision:
    kind: str
    worker_id: int
    t_ready: float


class Orchestrator:
    def __init__(self, engine, profile: Optional[TarragonProfile] = None,
                 worker_init_time: float = 18.5):
        self.engine = engine
        self.profile = profile or TarragonProfile()
        self.T_w = worker_init_time
        self.events: List[WorkerEvent] = []
        self._failures: List[_PendingFailure] = []
        self._provisions: List[_PendingProvision] = []

    # -- failure injection (the SIGINT of §7.2) -----------------------------
    def inject_failure(self, kind: str, worker_id: int, now: float):
        assert kind in ("aw", "ew")
        self._failures.append(_PendingFailure(kind, worker_id, now))
        self.events.append(WorkerEvent(now, f"fail_{kind}", f"{kind}{worker_id}"))

    def detection_latency(self) -> float:
        return self.profile.detect * self.profile.detect_retries

    # -- control loop --------------------------------------------------------
    def tick(self, now: float) -> List[WorkerEvent]:
        """Advance the control plane to virtual time ``now``. Returns the
        events that fired during this tick."""
        fired: List[WorkerEvent] = []
        for f in self._failures:
            if f.detected or now < f.t_fail + self.detection_latency():
                continue
            f.detected = True
            ev = WorkerEvent(now, "detected", f"{f.kind}{f.worker_id}")
            if f.kind == "ew":
                # AW-side self-healing: ERT remap to shadows (instant once
                # detected); background EW provisioning starts now.
                self.engine.fail_ew(f.worker_id)
                ev.detail = "ERT remap -> shadow experts"
            else:
                # EW-side self-healing: health mask drops the AW's slots;
                # per-request restoration re-admits its requests through
                # the Gateway (unplaceable ones stay queued and retry).
                self.engine.fail_aw(f.worker_id)
                n = len(self.engine.recover_aw_requests(now=now))
                ev.detail = f"restored {n} requests"
                waiting = self.engine.gateway.depth()
                if waiting:
                    ev.detail += f" ({waiting} queued for retry)"
            self._provisions.append(
                _PendingProvision(f.kind, f.worker_id, now + self.T_w))
            self.events.append(ev)
            fired.append(ev)

        remaining = []
        for p in self._provisions:
            if now < p.t_ready:
                remaining.append(p)
                continue
            if p.kind == "ew":
                # layer-aligned join (§5.4) + shadow re-pointing to protect
                # a new EW (background weight push)
                nxt = (p.worker_id + 1) % self.engine.ecfg.num_ew
                self.engine.provision_ew(p.worker_id, repoint_protect=nxt)
            else:
                self.engine.provision_aw(p.worker_id)
                # freshly provisioned capacity drains the waiting queue
                # (recovery entries sit at the front)
                self.engine.scheduler.admit(now)
            ev = WorkerEvent(now, "provisioned", f"{p.kind}{p.worker_id}")
            self.events.append(ev)
            fired.append(ev)
        self._provisions = remaining
        return fired

    @property
    def outstanding(self) -> int:
        return len(self._provisions) + \
            sum(1 for f in self._failures if not f.detected)
