"""Discrete-event failover simulator — produces the paper's end-to-end
timelines (Fig. 9: TBT + output tokens/s around an injected failure) from the
calibrated cost model.

Why a simulator: this container has no GPUs/TPUs, so absolute wall-clock
failover cannot be *measured*; the paper's own §2.2.2 audit shows the stall
behaviour is captured by the (T_w, t_pre, t_dec) cost model, which we
calibrate from Table 1 (GPU-comparable) or from our engine's measured
per-layer times (CPU). The reproduction targets are the ratios
(160-213x stall reduction, <3% overhead), which are scale-free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from repro.core import costmodel as cm


@dataclass
class SimConfig:
    num_layers: int = 32
    num_aw: int = 8
    num_ew: int = 8
    num_requests: int = 20          # concurrently decoding requests
    prompt_len: int = 10
    max_output: int = 128           # "Random" workload: 10 in / 128 out
    duration: float = 160.0         # seconds simulated
    fail_time: float = 78.0         # paper Fig. 9(a): failure at ~78 s
    sample_dt: float = 0.1
    expert_time_frac: float = 0.45  # share of a decode layer spent in EWs
    profile: cm.DeploymentProfile = field(
        default_factory=lambda: cm.MEGASCALE_PROFILE)
    tarragon: cm.TarragonProfile = field(default_factory=cm.TarragonProfile)


@dataclass
class Timeline:
    mode: str
    t: np.ndarray              # sample times
    throughput: np.ndarray     # output tokens/s
    tbt: np.ndarray            # time-between-tokens of an affected request
    stall: float               # longest token gap introduced by the failure
    events: List[str] = field(default_factory=list)


def _token_period(c: SimConfig) -> float:
    return c.num_layers * c.profile.t_dec


def _emit(c: SimConfig, period_fn, stall_windows, affected_frac=1.0
          ) -> Timeline:
    """Integrate token emission with piecewise TBT and stall windows.

    period_fn(t) -> current TBT for an affected request.
    stall_windows: list of (start, end, frac_affected) during which the
    affected fraction emits nothing.
    """
    samples = np.arange(0.0, c.duration, c.sample_dt)
    thr = np.zeros_like(samples)
    tbt = np.zeros_like(samples)
    base = c.num_requests / _token_period(c)
    for i, t in enumerate(samples):
        period = period_fn(t)
        stalled_frac = 0.0
        for (s, e, frac) in stall_windows:
            if s <= t < e:
                stalled_frac = max(stalled_frac, frac)
        active = c.num_requests * (1.0 - stalled_frac * affected_frac)
        thr[i] = active / period
        in_stall = any(s <= t < e for (s, e, _) in stall_windows)
        tbt[i] = period if not in_stall else 0.0
    # represent the affected request's max token gap
    stall = max((e - s for (s, e, f) in stall_windows if f > 0), default=0.0)
    # catch-up bump right after global stalls (queued demand drains)
    return Timeline("", samples, thr, tbt, stall)


def simulate_megascale_failure(c: SimConfig) -> Timeline:
    """Coarse-grained recovery: any worker failure -> restart + full replay
    (Fig. 3 / Fig. 9a). Stall covers ALL requests."""
    period = _token_period(c)
    # decoded tokens of the deepest in-flight request, bounded by workload
    i_fail = min(int(c.fail_time / period), c.max_output)
    layer = c.num_layers // 2
    t_model = cm.stall_decoupled_aw(c.profile, c.num_layers, layer, i_fail)
    t_stall = t_model + cm.FULL_RESTART_EXTRA  # measured-system effects
    tl = _emit(c, lambda t: period,
               [(c.fail_time, c.fail_time + t_stall, 1.0)])
    tl.mode = "megascale"
    tl.events = [f"fail@{c.fail_time:.1f}s",
                 f"Eq.1 model {t_model:.1f}s",
                 f"restart+replay {t_stall:.1f}s"]
    tl.stall = t_stall
    return tl


def simulate_tarragon_aw_failure(c: SimConfig) -> Timeline:
    """AW failure: per-request restore for the failed AW's share; the rest of
    the pipeline never pauses (Fig. 9b)."""
    period = _token_period(c)
    i_fail = min(int(c.fail_time / period), c.max_output)
    layer = c.num_layers // 2
    t_stall = cm.stall_tarragon_aw(
        c.profile, c.tarragon, c.num_layers, layer, i_fail,
        tokens_to_restore=c.prompt_len + i_fail)
    frac = 1.0 / c.num_aw
    tl = _emit(c, lambda t: period,
               [(c.fail_time, c.fail_time + t_stall, frac)])
    tl.mode = "tarragon_aw"
    tl.stall = t_stall
    tl.events = [f"fail@{c.fail_time:.1f}s",
                 f"detect+restore {t_stall * 1e3:.0f}ms",
                 f"newAW@{c.fail_time + c.profile.T_w:.1f}s"]
    return tl


def simulate_tarragon_ew_failure(c: SimConfig) -> Timeline:
    """EW failure: shadow-expert failover masks the failure (~0.3 s), reduced
    expert capacity elevates TBT until the replacement EW joins (Fig. 9c)."""
    period = _token_period(c)
    layer = c.num_layers // 2
    t_stall = cm.stall_tarragon_ew(c.profile, c.tarragon, c.num_layers,
                                   layer, 0)
    rejoin = c.fail_time + c.profile.T_w
    fe = c.expert_time_frac
    degraded = period * (1.0 + fe / max(1, c.num_ew - 1))

    def period_fn(t):
        if c.fail_time <= t < rejoin:
            return degraded
        return period

    tl = _emit(c, period_fn, [(c.fail_time, c.fail_time + t_stall, 1.0)])
    tl.mode = "tarragon_ew"
    tl.stall = t_stall
    tl.events = [f"fail@{c.fail_time:.1f}s",
                 f"shadow-failover {t_stall * 1e3:.0f}ms",
                 f"newEW@{rejoin:.1f}s"]
    return tl


def simulate_tarragon_scale_out(c: SimConfig, t_scale: float = None,
                                t_push: float = 1.0) -> Timeline:
    """EW scale-out on the versioned placement plane: the joining worker
    initializes (T_w) and receives its expert weights (T_push) entirely in
    the background; the plan installs at a layer boundary (§5.4), so there
    is NO stall window — only a TBT step-down once the expert axis widens.
    """
    period = _token_period(c)
    t_scale = c.fail_time if t_scale is None else t_scale
    join = t_scale + c.profile.T_w + t_push
    fe = c.expert_time_frac
    # expert compute spreads over one more EW after the join
    improved = period * (1.0 - fe / (c.num_ew + 1))

    def period_fn(t):
        return improved if t >= join else period

    tl = _emit(c, period_fn, [])
    tl.mode = "tarragon_scale_out"
    tl.stall = 0.0
    tl.events = [f"scale_out@{t_scale:.1f}s",
                 f"join@{join:.1f}s (T_w+T_push, zero stall)"]
    return tl


def simulate_tarragon_scale_in(c: SimConfig, t_scale: float = None,
                               t_push: float = 1.0) -> Timeline:
    """Graceful EW drain: residents migrate during T_push while the EW
    keeps serving; the shrink is again a plan install at a layer boundary —
    capacity drops, but no token gap is introduced."""
    period = _token_period(c)
    t_scale = c.fail_time if t_scale is None else t_scale
    leave = t_scale + t_push
    fe = c.expert_time_frac
    degraded = period * (1.0 + fe / max(1, c.num_ew - 1))

    def period_fn(t):
        return degraded if t >= leave else period

    tl = _emit(c, period_fn, [])
    tl.mode = "tarragon_scale_in"
    tl.stall = 0.0
    tl.events = [f"drain@{t_scale:.1f}s",
                 f"leave@{leave:.1f}s (T_push migration, zero stall)"]
    return tl


def simulate_tarragon_promotion(c: SimConfig) -> Timeline:
    """EW failure under the *promote* policy: shadows become primaries
    permanently (instant ERT flip after detection — same short stall as the
    revive policy), but the degraded-capacity window ends at re-protection
    (T_push) instead of waiting out a full worker re-init (T_w >> T_push).
    """
    period = _token_period(c)
    layer = c.num_layers // 2
    t_stall = cm.stall_tarragon_ew(c.profile, c.tarragon, c.num_layers,
                                   layer, 0)
    t_push = 1.0
    reprotect = c.fail_time + t_push
    fe = c.expert_time_frac
    degraded = period * (1.0 + fe / max(1, c.num_ew - 1))

    def period_fn(t):
        # the pool stays one EW smaller permanently: degraded TBT persists,
        # but full fault tolerance is back at t_reprotect, not t_fail + T_w
        return period if t < c.fail_time else degraded

    tl = _emit(c, period_fn, [(c.fail_time, c.fail_time + t_stall, 1.0)])
    tl.mode = "tarragon_promote"
    tl.stall = t_stall
    tl.events = [f"fail@{c.fail_time:.1f}s",
                 f"promote {t_stall * 1e3:.0f}ms",
                 f"reprotect@{reprotect:.1f}s (pool -1)"]
    return tl


def simulate_preemption_restore(c: SimConfig, t_evict: float = None,
                                wait: float = 1.0) -> Timeline:
    """Planned eviction on the recovery substrate (serving/api.py): an
    interactive burst needs the victim's slot for ``wait`` seconds. The
    victim's resident KV is already committed (the stream is flushed at
    eviction — no detection, no recompute), so its stall is the wait plus
    the per-request restore copy when it re-enters. Every other request
    keeps decoding; preemption is failure you chose, minus the failure."""
    period = _token_period(c)
    t_evict = c.fail_time if t_evict is None else t_evict
    i_evict = min(int(t_evict / period), c.max_output)
    restore = c.tarragon.restore_fixed + \
        (c.prompt_len + i_evict) * c.num_layers * \
        c.tarragon.restore_per_token
    t_stall = wait + restore + c.tarragon.resched
    frac = 1.0 / c.num_requests          # exactly one victim stalls
    tl = _emit(c, lambda t: period,
               [(t_evict, t_evict + t_stall, frac)])
    tl.mode = "preempt_restore"
    tl.stall = t_stall
    tl.events = [f"evict@{t_evict:.1f}s (watermark flushed)",
                 f"slot lent {wait:.1f}s",
                 f"restore {restore * 1e3:.0f}ms from cursor "
                 f"{c.prompt_len + i_evict} tokens"]
    return tl


def simulate_preemption_recompute(c: SimConfig, t_evict: float = None,
                                  wait: float = 1.0) -> Timeline:
    """Baseline without checkpoint-backed preemption: evicting a request
    discards its KV, so re-admission re-prefills the prompt AND replays
    every generated token (the MegaScale restart structure, scheduled
    instead of crashed)."""
    period = _token_period(c)
    t_evict = c.fail_time if t_evict is None else t_evict
    i_evict = min(int(t_evict / period), c.max_output)
    layer = c.num_layers // 2
    replay = c.num_layers * c.profile.t_pre + \
        max(0, (i_evict - 1) * c.num_layers + layer) * c.profile.t_dec
    t_stall = wait + replay + c.tarragon.resched
    frac = 1.0 / c.num_requests
    tl = _emit(c, lambda t: period,
               [(t_evict, t_evict + t_stall, frac)])
    tl.mode = "preempt_recompute"
    tl.stall = t_stall
    tl.events = [f"evict@{t_evict:.1f}s (KV discarded)",
                 f"slot lent {wait:.1f}s",
                 f"re-prefill + replay {replay:.2f}s "
                 f"({i_evict} tokens from scratch)"]
    return tl


def preemption_summary(c: SimConfig, wait: float = 1.0) -> Dict[str, float]:
    """Checkpoint-backed preemption vs discard-and-recompute: both lend
    the slot for ``wait`` seconds; the difference is what the victim pays
    on top of the loan."""
    restore = simulate_preemption_restore(c, wait=wait)
    recompute = simulate_preemption_recompute(c, wait=wait)
    return {
        "preempt_restore_stall_s": restore.stall,
        "preempt_recompute_stall_s": recompute.stall,
        "restore_overhead_s": restore.stall - wait,
        "recompute_overhead_s": recompute.stall - wait,
        "overhead_improvement_x": (recompute.stall - wait) /
                                  max(restore.stall - wait, 1e-9),
    }


def failover_summary(c: SimConfig) -> Dict[str, float]:
    base = simulate_megascale_failure(c)
    aw = simulate_tarragon_aw_failure(c)
    ew = simulate_tarragon_ew_failure(c)
    return {
        "megascale_stall_s": base.stall,
        "tarragon_aw_stall_s": aw.stall,
        "tarragon_ew_stall_s": ew.stall,
        "aw_improvement_x": base.stall / aw.stall,
        "ew_improvement_x": base.stall / ew.stall,
    }


# --------------------------------------------------------------------------
# live-engine event timeline (telemetry bus consumer)
# --------------------------------------------------------------------------

def timeline_from_bus(bus, consumer: str = "events.timeline"
                      ) -> List[str]:
    """Fig. 9-style event annotations from a live engine's telemetry bus
    (serving/telemetry.py) instead of the cost model: each call drains
    only the events past this ``consumer``'s own cursor, so the
    orchestrator audit log, the exporters, and this timeline can all
    observe the same failure without stealing from each other (the old
    destructive ``drain_*`` lists could not make that guarantee)."""
    return [f"{ev.kind}@{ev.t:.2f}s {ev.worker}"
            + (f" ({ev.detail})" if ev.detail else "")
            for ev in bus.drain(consumer)]


# --------------------------------------------------------------------------
# AW-EW link occupancy trace (paper Fig. 8) and checkpoint interleaving
# --------------------------------------------------------------------------

def link_trace(c: SimConfig, n_layers: int = 8, link_gbps: float = 400.0,
               tokens_per_dispatch: int = 64, d_model: int = 4096,
               top_k: int = 2):
    """Per-layer timeline of AW-EW link busy/idle within one decode step.

    Each layer: [attention compute (link idle)] [dispatch burst] [expert
    compute] [gather burst]. Checkpoint segments are scheduled into the
    idle attention-compute gaps (opportunistic interleaving, §6.1)."""
    t_layer = c.profile.t_dec
    fe = c.expert_time_frac
    t_attn = t_layer * (1 - fe) * 0.8
    bytes_dispatch = tokens_per_dispatch * cm.expert_traffic_bytes(
        d_model, top_k) / 2  # one direction
    t_burst = bytes_dispatch / (link_gbps / 8 * 1e9)
    seg_bytes = tokens_per_dispatch * cm.kv_segment_bytes(d_model, 32, 8)
    t_ckpt = seg_bytes / (link_gbps / 8 * 1e9)

    events = []  # (t_start, t_end, kind)
    t = 0.0
    for _ in range(n_layers):
        events.append((t, t + t_attn, "idle"))
        # checkpoint rides the idle gap
        events.append((t, t + min(t_ckpt, t_attn), "ckpt"))
        t += t_attn
        events.append((t, t + t_burst, "dispatch"))
        t += t_burst
        t_e = t_layer * fe
        events.append((t, t + t_e, "expert_idle"))
        t += t_e
        events.append((t, t + t_burst, "gather"))
        t += t_burst
    return events, {"t_burst": t_burst, "t_ckpt": t_ckpt, "t_attn": t_attn,
                    "ckpt_fits_gap": t_ckpt <= t_attn}


def checkpoint_scheme_throughput(c: SimConfig, scheme: str,
                                 interval_tokens: int = 8,
                                 kv_tokens: int = 512,
                                 d_model: int = 4096, n_heads: int = 32,
                                 n_kv_heads: int = 8,
                                 link_gbps: float = 400.0) -> float:
    """Output tokens/s under a checkpointing scheme (§7.4).

    'none'        — upper bound.
    'incremental' — Tarragon: rides idle gaps; overhead only if a segment
                    exceeds the available gap (it doesn't, App. C sizes).
    'pause'       — Pause-Checkpoint-Resume every ``interval_tokens``:
                    global stall while the WHOLE KV cache is flushed.
    """
    period = _token_period(c)
    base = c.num_requests / period
    if scheme == "none":
        return base
    seg = cm.kv_segment_bytes(d_model, n_heads, n_kv_heads) * c.num_layers
    bw = link_gbps / 8 * 1e9
    if scheme == "incremental":
        _, info = link_trace(c, d_model=d_model)
        if info["ckpt_fits_gap"]:
            return base * 0.999  # residual bookkeeping (<0.1%)
        excess = info["t_ckpt"] - info["t_attn"]
        return c.num_requests / (period + excess * c.num_layers)
    if scheme == "pause":
        # a global snapshot serializes through a barrier + host staging: no
        # pipelining with compute, no per-request overlap. Effective flush
        # bandwidth is ~1/8 of the streaming RDMA path (calibrated to the
        # paper's measured 2.15x degradation at interval=8).
        full_kv = seg * kv_tokens * c.num_requests
        t_flush = full_kv / (bw / 8) + 0.020  # + quiesce/resume latency
        eff_period = period + t_flush / interval_tokens
        return c.num_requests / eff_period
    raise ValueError(scheme)
