"""Expert Routing Table (ERT): decouple expert *identity* from *location*.

Paper §4.2: "The ERT maps each expert to one or more candidate EWs —
potentially including shadow experts — allowing immediate rerouting when an
EW fails". The JAX/TPU adaptation (DESIGN.md §1): expert compute happens in a
*physical slot space* of size P = E + n_shadow. Slots 0..E-1 are primaries
(slot e holds logical expert e); slots E..P-1 are shadow slots whose resident
expert is chosen by the orchestrator and can be re-pointed at runtime
(weights pushed host-side = "pre-loading a shadow expert").

The ERT itself is a pair of **device arrays** threaded through the jitted
step function:
    candidates [E, R] int32  — slot ids in priority order (-1 = none)
    ew_health  [num_ew] bool — liveness of each EW shard
Because both are data (not compile-time constants), a failover or a shadow
activation is a host->device array update — **no recompilation and no
collective-group rebuild**, the exact analogue of Tarragon's claim that
recovery is a table remap rather than a CCL reconfiguration.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp


@dataclass(frozen=True)
class ExpertPlacement:
    """Static geometry of the expert slot space (fixed at compile time).

    Primary slots are padded up to a multiple of ``num_ew`` so the slot axis
    always divides the expert-parallel mesh axis (e.g. 60 Qwen experts ->
    64 primary slots on 16 EWs; pad slots hold zero weights and never receive
    tokens). Shadow slots are likewise a multiple of ``num_ew``."""

    num_experts: int              # E logical experts
    num_ew: int                   # EW shards ("model" mesh axis size)
    num_shadow_slots: int         # extra slots for shadow replicas

    @property
    def primary_slots(self) -> int:
        return -(-self.num_experts // self.num_ew) * self.num_ew

    @property
    def num_slots(self) -> int:
        return self.primary_slots + self.num_shadow_slots

    @property
    def experts_per_ew(self) -> int:
        return self.primary_slots // self.num_ew

    def slot_owner(self) -> np.ndarray:
        """EW shard owning each slot. Primaries are blocked contiguously
        (expert-parallel layout); shadow slots are striped round-robin so a
        single EW's residual memory hosts ~n_shadow/num_ew shadows."""
        owner = np.empty((self.num_slots,), np.int32)
        owner[: self.primary_slots] = (
            np.arange(self.primary_slots) // self.experts_per_ew)
        owner[self.primary_slots:] = (
            np.arange(self.num_shadow_slots) % self.num_ew)
        return owner


def default_placement(num_experts: int, num_ew: int,
                      num_shadow_slots: int = -1) -> ExpertPlacement:
    if num_shadow_slots < 0:
        # default: one EW's worth of residual memory (paper §5.3: shadows
        # occupy residual GPU memory; a single-EW-failure's experts fit).
        # Shadow slots are striped over ALL EWs, so to guarantee every
        # protected expert a slot on a *different* EW than its primary we
        # oversize by num_ew/(num_ew-1), then round up to a multiple of
        # num_ew (sharding divisibility).
        e_per = -(-num_experts // max(1, num_ew))
        if num_ew > 1:
            base = -(-e_per * num_ew // (num_ew - 1))
            num_shadow_slots = -(-base // num_ew) * num_ew
        else:
            num_shadow_slots = e_per
    return ExpertPlacement(num_experts, num_ew, num_shadow_slots)


def initial_shadow_assignment(placement: ExpertPlacement,
                              protected_ew: int = 0) -> np.ndarray:
    """Which logical expert each shadow slot replicates (host decision).

    Default protects EW ``protected_ew``: its experts are pre-loaded as
    shadows on other EWs. The orchestrator re-points this after failures
    (background provisioning). Greedy matching: each protected expert first
    gets a slot on a *different* EW than its primary (a same-EW replica
    would die with it); leftover slots take duplicate replicas."""
    e_per = placement.experts_per_ew
    protected = [e for e in range(protected_ew * e_per,
                                  (protected_ew + 1) * e_per)
                 if e < placement.num_experts]
    if not protected:  # padded-only EW: protect round-robin instead
        protected = list(range(min(e_per, placement.num_experts)))
    owner = placement.slot_owner()
    s = placement.num_shadow_slots
    assign = np.full((s,), -1, np.int32)
    usable = [j for j in range(s)
              if owner[placement.primary_slots + j] != protected_ew]
    for i, e in enumerate(protected):
        if i < len(usable):
            assign[usable[i]] = e
    for j in range(s):
        if assign[j] < 0:
            assign[j] = protected[j % len(protected)]
    return assign


def build_candidates(placement: ExpertPlacement,
                     shadow_assignment: np.ndarray) -> np.ndarray:
    """ERT candidate table [E, 2]: (primary slot, shadow slot or -1).

    A shadow slot is only a valid candidate if it lives on a different EW
    than the primary (otherwise it would die with it)."""
    e = placement.num_experts
    owner = placement.slot_owner()
    cand = np.full((e, 2), -1, np.int32)
    cand[:, 0] = np.arange(e)
    for j, expert in enumerate(shadow_assignment):
        slot = placement.primary_slots + j
        if owner[slot] != owner[expert] and cand[expert, 1] < 0:
            cand[expert, 1] = slot
    return cand


def resolve_active_slots(candidates, ew_health, slot_owner):
    """Resolve each logical expert to its highest-priority *healthy* slot.

    candidates: [E, R] int32; ew_health: [num_ew] bool; slot_owner: [P] int32
    (-1 = parked slot: its EW left the pool, weights unreachable).
    Returns (active_slot [E] int32, expert_alive [E] bool). Runs inside jit —
    this is the REFE's per-dispatch ERT lookup.
    """
    candidates = jnp.asarray(candidates)
    slot_owner = jnp.asarray(slot_owner)
    valid = candidates >= 0
    safe = jnp.maximum(candidates, 0)
    owner = slot_owner[safe]
    healthy = valid & (owner >= 0) & ew_health[jnp.maximum(owner, 0)]
    # first healthy candidate in priority order
    first = jnp.argmax(healthy, axis=1)
    any_healthy = jnp.any(healthy, axis=1)
    active = jnp.take_along_axis(safe, first[:, None], axis=1)[:, 0]
    # if nothing healthy, fall back to primary (tokens will be masked out)
    active = jnp.where(any_healthy, active, candidates[:, 0])
    return active.astype(jnp.int32), any_healthy


def initial_slot_expert(placement: ExpertPlacement,
                        shadow_assignment: np.ndarray) -> np.ndarray:
    """Resident logical expert per physical slot (-1 = empty pad slot).

    The identity layout: primary slot e holds expert e, pad slots are empty,
    shadow slots hold the orchestrator's shadow assignment. Dynamic plans
    (core/placement.py) replace this array wholesale — the expert bank is
    always indexed *through* it, so any slot can host any expert."""
    se = np.full((placement.num_slots,), -1, np.int32)
    se[: placement.num_experts] = np.arange(placement.num_experts)
    se[placement.primary_slots:] = np.asarray(shadow_assignment, np.int32)
    return se


def ew_health_to_slot_health(ew_health, slot_owner):
    return ew_health[jnp.asarray(slot_owner)]
