"""Reconfigurable Forwarding Engine (REFE): the AW<->EW datapath.

Paper §4: each AW dispatches token embeddings to EWs through the REFE, which
resolves logical expert ids via the ERT and routes over point-to-point RDMA.
JAX/TPU adaptation: the dispatch/combine is expressed as capacity-based
one-hot contractions over the *physical slot space* (see core/ert.py). With
tokens sharded over the ``data`` axis (= AW shards) and slots sharded over the
``model`` axis (= EW shards), XLA lowers the two contractions into exactly the
asymmetric M2N scatter/gather the paper describes — and because the routing
tables/health masks are runtime arrays, a failover changes *where tokens
flow* without touching the compiled program.

Self-healing semantics carried in-band (paper §5):
  * AW-side (EW failure): ``resolve_active_slots`` never routes to a slot on
    a dead EW — tokens flow to the shadow/alternate slot in the same step
    ("immediate reroute + replay at the frontier").
  * EW-side (AW failure): tokens owned by dead AWs are masked out of the
    dispatch (gate weights zeroed) — expert batches proceed with the healthy
    subset instead of waiting ("sufficient subset" batching).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import ert as ert_lib


class RouteState(NamedTuple):
    """Runtime routing state threaded through the jitted step (all data,
    never compile-time constants).

    The slot-indirection pair (``slot_expert``, ``slot_owner``) is what makes
    the expert plane *elastic*: the expert bank is gathered through
    ``slot_expert`` and health is resolved through ``slot_owner``, so a
    placement change — rebalance, EW scale-out/in, shadow promotion — is a
    pure array update installed between steps, never a new jit trace."""

    candidates: jax.Array      # [E, R] int32 — ERT (priority order per expert)
    ew_health: jax.Array       # [max_ew] bool
    aw_health: jax.Array       # [num_aw] bool
    slot_expert: jax.Array     # [P] int32 — resident logical expert per slot
    #                            (-1 = empty slot; bank rows gather through it)
    slot_owner: jax.Array      # [P] int32 — EW owning each slot (-1 = parked)
    split_slot: jax.Array      # [E] int32 — load-bearing replica slot for
    #                            traffic splitting (-1 = no split); only used
    #                            while its owner is healthy

    @staticmethod
    def healthy(placement: ert_lib.ExpertPlacement, num_aw: int,
                shadow_assignment=None, num_ew: int = 0) -> "RouteState":
        """The static identity layout (primary slot e = expert e, shadows per
        ``shadow_assignment``). ``num_ew`` oversizes the EW-health axis for
        elastic pools (spare EW ids start unhealthy); 0 = exactly the
        placement's EW count."""
        if shadow_assignment is None:
            shadow_assignment = ert_lib.initial_shadow_assignment(placement)
        # host-side numpy: must stay concrete even under eval_shape tracing
        import numpy as np
        shadow_assignment = np.asarray(shadow_assignment)
        cand = ert_lib.build_candidates(placement, shadow_assignment)
        max_ew = max(num_ew, placement.num_ew)
        health = np.zeros((max_ew,), bool)
        health[: placement.num_ew] = True
        return RouteState(
            candidates=jnp.asarray(cand, jnp.int32),
            ew_health=jnp.asarray(health),
            aw_health=jnp.ones((num_aw,), bool),
            slot_expert=jnp.asarray(
                ert_lib.initial_slot_expert(placement, shadow_assignment),
                jnp.int32),
            slot_owner=jnp.asarray(placement.slot_owner(), jnp.int32),
            split_slot=jnp.full((placement.num_experts,), -1, jnp.int32),
        )


def token_aw_owner(num_tokens: int, num_aw: int, batch: int = 0):
    """AW shard owning each token (tokens are batch-major; batch rows are
    data-parallel over AWs, so ownership is contiguous row blocks)."""
    batch = batch or num_tokens
    seq = max(1, num_tokens // batch)
    row = jnp.arange(num_tokens) // seq
    return jnp.minimum(row * num_aw // batch, num_aw - 1)


# Above this token count the flat one-hot dispatch ([T, P, C] — cost
# O(T*P*C*D), catastrophic at 1M train tokens) switches to GShard-style
# GROUPED dispatch: tokens split into groups of GROUP_SIZE with per-group
# capacity, so the one-hot is [G, S_g, P, C_g] (S_g-bounded) and the
# dispatch einsum costs O(T * S_g * k * cf * D / 1) per token — ~20% of
# expert FLOPs at S_g=512 instead of ~30x. Groups ride the data axis; the
# expert dim rides the model axis, so expert compute is fully 2D-sharded
# with a single psum-combine per layer. See EXPERIMENTS.md §Perf iter 1.
ONEHOT_MAX_TOKENS = 2048
GROUP_SIZE = 512


def intra_slot_positions(slot_idx, valid, num_slots: int):
    """Rank of each (token, choice) within its target slot (order = flat
    (t, k) arrival order — the EW-side layer-wise batch fill order)."""
    t, k = slot_idx.shape
    flat_slot = slot_idx.reshape(t * k)
    flat_valid = valid.reshape(t * k)
    oh = jax.nn.one_hot(flat_slot, num_slots, dtype=jnp.int32)
    oh = oh * flat_valid.astype(jnp.int32)[:, None]
    pos = (jnp.cumsum(oh, axis=0) - oh)
    pos = jnp.take_along_axis(pos, flat_slot[:, None], axis=1)[:, 0]
    return pos.reshape(t, k)


def route(x, router_logits, route_state: RouteState,
          placement: ert_lib.ExpertPlacement, *, top_k: int,
          capacity_factor: float, capacity: Optional[int] = None,
          batch: int = 0, token_mask=None):
    """Full REFE routing decision for a flat token batch.

    x: [T, D]; router_logits: [T, E]. Returns routing metadata (slot ids,
    intra-slot positions, gate weights, aux loss); ``expert_io`` turns it
    into the AW->EW datapath.

    ``token_mask`` ([T] bool, optional) marks which tokens are real work:
    pad tokens (prefill length/row padding, inactive chunk rows) get
    ``False`` and are excluded from intra-slot ranking, so they never
    compete with real tokens for per-expert capacity cells.
    """
    t, e = router_logits.shape
    slot_owner = route_state.slot_owner      # [P] data, never a trace const

    active_slot, expert_alive = ert_lib.resolve_active_slots(
        route_state.candidates, route_state.ew_health, slot_owner)

    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    # dead experts (no healthy replica anywhere) are masked from selection
    probs = probs * expert_alive[None, :]
    gate_w, topk_idx = jax.lax.top_k(probs, top_k)           # [T, K]
    gate_w = gate_w / jnp.maximum(
        jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)

    slot_idx = active_slot[topk_idx]                          # [T, K]

    # load-bearing replicas (placement-manager decision): tokens of a split
    # expert alternate between its active slot and the replica slot by
    # (token, choice) parity — half the dispatch load moves off the primary
    # EW while the replica's owner stays healthy. Weights are identical, so
    # a kept token computes the same value either way; outputs are
    # bit-identical whenever capacity does not bind (splitting also doubles
    # the expert's effective capacity, so under a *tight* capacity factor
    # the kept-token set can only grow, which changes which drops occur).
    split = route_state.split_slot[topk_idx]                  # [T, K]
    sp_owner = slot_owner[jnp.maximum(split, 0)]
    sp_ok = (split >= 0) & (sp_owner >= 0) & \
        route_state.ew_health[jnp.maximum(sp_owner, 0)]
    parity = (jnp.arange(t)[:, None] + jnp.arange(top_k)[None, :]) % 2
    slot_idx = jnp.where(sp_ok & (parity == 1),
                         jnp.maximum(split, 0), slot_idx)

    # EW-side self-healing: drop tokens from failed AWs; pad-free dispatch:
    # drop pad tokens before they claim capacity ranks
    owner = token_aw_owner(t, route_state.aw_health.shape[0], batch=batch)
    token_valid = route_state.aw_health[owner]
    if token_mask is not None:
        token_valid = token_valid & token_mask

    grouped = t > ONEHOT_MAX_TOKENS
    if grouped:
        s_g = GROUP_SIZE
        while t % s_g:
            s_g //= 2
        g = t // s_g
    else:
        g, s_g = 1, t
    if capacity is None:
        capacity = int(max(1, round(capacity_factor * top_k * s_g / e)))

    valid = token_valid[:, None] & (gate_w > 0)
    # intra-slot rank per GROUP (per-group capacity)
    pos = jax.vmap(
        lambda si, va: intra_slot_positions(si, va, placement.num_slots)
    )(slot_idx.reshape(g, s_g, top_k), valid.reshape(g, s_g, top_k))
    pos = pos.reshape(t, top_k)
    keep = valid & (pos < capacity)

    # load-balance auxiliary loss (Switch-style), over logical experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topk_idx, e, dtype=jnp.float32), axis=1),
        axis=0) / top_k
    aux_loss = e * jnp.sum(me * ce)

    # per-slot dispatch load counter (tokens actually dispatched, after
    # health masks / capacity drops / replica splitting): a summed one-hot
    # collected device-side, drained into the ExpertPlacementManager's EMA
    # on the host — the telemetry behind load-aware rebalancing.
    slot_load = jnp.zeros((placement.num_slots,), jnp.float32).at[
        slot_idx.reshape(-1)].add(keep.reshape(-1).astype(jnp.float32))

    return {
        "capacity": capacity,
        "num_slots": placement.num_slots,
        "active_slot": active_slot,    # [E]
        "expert_alive": expert_alive,  # [E]
        "token_valid": token_valid,    # [T]
        "slot_idx": slot_idx,          # [T, K]
        "pos": pos,                    # [T, K]
        "keep": keep,                  # [T, K]
        "topk_idx": topk_idx,
        "gate_w": gate_w,
        "aux_loss": aux_loss,
        "slot_load": slot_load,        # [P] dispatched-token count per slot
        "grouped": grouped,
        "groups": g,
        "group_size": s_g,
    }


def routing_onehots(routing):
    """[T, P, C] dispatch/combine one-hots (small-T / test path)."""
    p, c = routing["num_slots"], routing["capacity"]
    slot_oh = jax.nn.one_hot(routing["slot_idx"], p, dtype=jnp.float32)
    slot_oh = slot_oh * routing["keep"].astype(jnp.float32)[..., None]
    pos_oh = jax.nn.one_hot(routing["pos"], c, dtype=jnp.float32)
    dispatch = jnp.einsum("tkp,tkc->tpc", slot_oh, pos_oh)
    combine = jnp.einsum("tkp,tkc->tpc",
                         slot_oh * routing["gate_w"][..., None], pos_oh)
    return dispatch, combine


def expert_io(x, routing, expert_fn):
    """The paper's ``expert_io(expert_id, layer_id, token_embeddings)`` API:
    scatter token embeddings to expert slots, run expert compute, gather.

    x: [T, D]; expert_fn: [P, ..., D] -> [P, ..., D] (ellipsis dims carried
    through the per-slot FFN). Returns y [T, D]. The dispatch/combine
    contractions are the M2N datapath (AW->EW and EW->AW hops).
    """
    t, d = x.shape
    p, c = routing["num_slots"], routing["capacity"]
    if not routing["grouped"]:
        dispatch, combine = routing_onehots(routing)
        expert_in = jnp.einsum("tpc,td->pcd", dispatch.astype(x.dtype), x)
        expert_out = expert_fn(expert_in)
        return jnp.einsum("tpc,pcd->td", combine.astype(expert_out.dtype),
                          expert_out)

    # GShard-style grouped dispatch: groups ride the data axis, slots the
    # model axis -> expert compute is 2D-sharded, combine psums over slots.
    g, s_g = routing["groups"], routing["group_size"]
    k = routing["slot_idx"].shape[1]
    slot_oh = jax.nn.one_hot(
        routing["slot_idx"].reshape(g, s_g, k), p, dtype=x.dtype)
    slot_oh = slot_oh * routing["keep"].reshape(
        g, s_g, k, 1).astype(x.dtype)
    pos_oh = jax.nn.one_hot(
        routing["pos"].reshape(g, s_g, k), c, dtype=x.dtype)
    dispatch = jnp.einsum("gskp,gskc->gspc", slot_oh, pos_oh)
    combine = jnp.einsum(
        "gskp,gskc->gspc",
        slot_oh * routing["gate_w"].reshape(g, s_g, k, 1).astype(x.dtype),
        pos_oh)
    xg = x.reshape(g, s_g, d)
    expert_in = jnp.einsum("gspc,gsd->pgcd", dispatch, xg)   # [P,G,C,D]
    expert_out = expert_fn(expert_in)
    y = jnp.einsum("gspc,pgcd->gsd", combine, expert_out)
    return y.reshape(t, d)
