"""Architecture config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.base import (LONG_CONTEXT_ARCHS, SHAPES, ModelConfig,
                                MoEConfig, ShapeConfig, SSMConfig,
                                supports_shape)  # noqa: F401

ARCH_IDS = (
    "qwen2_1_5b",
    "qwen2_moe_a2_7b",
    "h2o_danube_1_8b",
    "zamba2_7b",
    "chameleon_34b",
    "whisper_small",
    "xlstm_350m",
    "gemma2_2b",
    "granite_34b",
    "kimi_k2_1t_a32b",
    "mixtral_8x7b",   # the paper's own evaluation model
)


def _module_for(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_for(name)}")
    return mod.CONFIG


def all_configs(include_paper_model: bool = True):
    out = {}
    for mid in ARCH_IDS:
        if mid == "mixtral_8x7b" and not include_paper_model:
            continue
        cfg = get_config(mid)
        out[cfg.name] = cfg
    return out


ASSIGNED_ARCHS = tuple(a for a in ARCH_IDS if a != "mixtral_8x7b")
