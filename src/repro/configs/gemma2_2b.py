"""Gemma2-2B [arXiv:2408.00118]: alternating local(4096)/global attention,
logit softcap 30, attention softcap 50, head_dim 256."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", arch_type="dense", source="arXiv:2408.00118",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    d_ff=9216, vocab_size=256000, head_dim=256,
    attn_pattern=("local", "global"), sliding_window=4096,
    logit_softcap=30.0, attn_softcap=50.0,
    act="gelu", tie_embeddings=True,
)
