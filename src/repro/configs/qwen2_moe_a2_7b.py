"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 60 routed experts top-4
plus 4 shared experts (shared path hidden = 4x1408 = 5632)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", arch_type="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=151936,
    qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=True,
    moe=MoEConfig(num_experts=60, top_k=4, d_ff=1408,
                  num_shared_experts=4, shared_d_ff=5632),
)
