"""Config schema for models, shapes, meshes and Tarragon resilience knobs.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes as
``ShapeConfig``. ``reduced()`` produces the CPU-smoke variant mandated by the
brief (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    """Sparse-expert layer configuration (Tarragon's EW side)."""

    num_experts: int = 0            # routed (logical) experts
    top_k: int = 0
    d_ff: int = 0                   # per-expert FFN hidden dim
    num_shared_experts: int = 0     # always-on shared experts (qwen2-moe/kimi)
    shared_d_ff: int = 0            # total hidden dim of the shared path
    capacity_factor: float = 1.25
    first_k_dense: int = 0          # leading layers that use a dense FFN
    router_aux_coef: float = 0.01   # load-balance loss coefficient
    # Tarragon: number of shadow slots (replica capacity beyond primaries).
    # 0 means "one EW-shard's worth" chosen at build time.
    num_shadow_slots: int = -1

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style selective state space block configuration."""

    state_dim: int = 0
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64

    @property
    def enabled(self) -> bool:
        return self.state_dim > 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense|moe|hybrid|vlm|audio|ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    source: str = ""                # citation (paper / model card)

    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0         # 0 = full attention
    # repeating per-layer pattern, e.g. ("local", "global") for gemma2,
    # ("layer",) for plain stacks. Must divide evenly into num_layers.
    attn_pattern: Tuple[str, ...] = ("layer",)
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    qk_norm: bool = False

    # --- FFN ---------------------------------------------------------------
    act: str = "silu"               # silu | gelu
    mlp_gated: bool = True          # SwiGLU-style gate

    # --- MoE / SSM ---------------------------------------------------------
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # hybrid (zamba2): apply a shared attention block every N ssm blocks
    hybrid_attn_every: int = 0

    # --- xLSTM -------------------------------------------------------------
    # pattern of ("mlstm","slstm") blocks; used when arch_type == "ssm"
    xlstm_pattern: Tuple[str, ...] = ()

    # --- encoder-decoder (whisper) ------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0            # frames delivered by the (stubbed) frontend

    # --- embeddings / norm ---------------------------------------------------
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "float32"          # compute dtype ("bfloat16" for dry-run)
    remat: bool = False             # checkpoint scan bodies (training)

    # ------------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d = self.d_model
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim_
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.moe.enabled:
            ffn_moe = 3 * d * self.moe.d_ff * self.moe.num_experts
            ffn_moe += 3 * d * self.moe.shared_d_ff
            ffn_moe += d * self.moe.num_experts  # router
            dense_ffn = 3 * d * self.d_ff if self.d_ff else 3 * d * self.moe.d_ff
            n += self.moe.first_k_dense * (attn + dense_ffn)
            n += (self.num_layers - self.moe.first_k_dense) * (attn + ffn_moe)
        elif self.ssm.enabled and self.arch_type == "hybrid":
            d_in = self.ssm.expand * d
            mamba = 2 * d * d_in + d_in * d + d_in * (self.ssm.state_dim * 2)
            n += self.num_layers * mamba
            n_attn_apps = self.num_layers // max(1, self.hybrid_attn_every)
            n += attn + 3 * d * self.d_ff if n_attn_apps else 0
        elif self.xlstm_pattern:
            n += self.num_layers * (4 * d * d + 2 * d * 4 * d)
        else:
            mult = 3 if self.mlp_gated else 2
            n += self.num_layers * (attn + mult * d * self.d_ff)
        if self.encoder_layers:
            mult = 3 if self.mlp_gated else 2
            n += self.encoder_layers * (attn + mult * d * self.d_ff)
            n += self.num_layers * attn  # cross attention
        return int(n)

    @property
    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if not self.moe.enabled:
            return self.param_count
        d = self.d_model
        hd = self.head_dim_
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        ffn = 3 * d * self.moe.d_ff * self.moe.top_k + 3 * d * self.moe.shared_d_ff
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        n += self.num_layers * (attn + ffn + d * self.moe.num_experts)
        return int(n)

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant of the same family (brief: <=2 layers,
        d_model<=512, <=4 experts)."""
        d = min(self.d_model, 128)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        moe = self.moe
        if moe.enabled:
            moe = dataclasses.replace(
                moe, num_experts=4, top_k=min(moe.top_k, 2), d_ff=64,
                num_shared_experts=min(moe.num_shared_experts, 1),
                shared_d_ff=64 if moe.num_shared_experts else 0,
                first_k_dense=min(moe.first_k_dense, 1),
                num_shadow_slots=-1)
        ssm = self.ssm
        if ssm.enabled:
            ssm = dataclasses.replace(ssm, state_dim=16, head_dim=16, chunk=8)
        pattern_len = len(self.attn_pattern)
        nl = max(2, pattern_len)
        if self.hybrid_attn_every:
            nl = 2 * min(self.hybrid_attn_every, 2)
        if self.xlstm_pattern:
            nl = len(self.xlstm_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=nl,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d // heads,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            hybrid_attn_every=min(self.hybrid_attn_every, 2) if self.hybrid_attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            moe=moe,
            ssm=ssm,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# Architectures with a sub-quadratic long-context path (DESIGN.md §4).
LONG_CONTEXT_ARCHS = frozenset(
    {"h2o-danube-1.8b", "zamba2-7b", "xlstm-350m", "gemma2-2b"})


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.name in LONG_CONTEXT_ARCHS
    return True
