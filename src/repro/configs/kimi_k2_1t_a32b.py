"""Kimi K2 (1T total / 32B active) [arXiv:2501.kimi2]: 384 routed experts
top-8 + 1 shared, first layer dense — the paper-table trillion-param MoE and
the headline case for shadow-expert memory budgeting."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", arch_type="moe", source="arXiv:2501.kimi2",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    rope_theta=50_000.0, tie_embeddings=False,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff=2048,
                  num_shared_experts=1, shared_d_ff=2048,
                  first_k_dense=1),
)
