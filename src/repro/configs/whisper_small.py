"""Whisper-small [arXiv:2212.04356]: enc-dec; mel+conv frontend STUBBED —
input_specs provide 1500 precomputed frame embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", arch_type="audio", source="arXiv:2212.04356",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    encoder_layers=12, encoder_seq=1500,
    act="gelu", mlp_gated=False, tie_embeddings=True,
)
