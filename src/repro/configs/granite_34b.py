"""Granite-34B-Code [arXiv:2405.04324]: deep MQA (kv=1) code model,
GPT-BigCode-style ungated GeLU MLP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", arch_type="dense", source="arXiv:2405.04324",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    act="gelu", mlp_gated=False, tie_embeddings=True,
)
