"""xLSTM-350m [arXiv:2405.04517]: alternating mLSTM/sLSTM blocks,
constant-size recurrent state (d_ff=0: no separate FFN blocks)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", arch_type="ssm", source="arXiv:2405.04517",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm_pattern=("mlstm", "slstm"), tie_embeddings=True,
)
