"""H2O-Danube-1.8B [arXiv:2401.16818]: llama+mistral mix with sliding-window
attention (the all-layers-SWA dense arch; runs long_500k via ring KV)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", arch_type="dense", source="arXiv:2401.16818",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000,
    sliding_window=4096, rope_theta=10_000.0, tie_embeddings=False,
)
