"""Mixtral-8x7B [arXiv:2401.04088]: the paper's own evaluation model —
32 layers, 8 experts per MoE layer, top-2 (Tarragon §7.1)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe", source="arXiv:2401.04088",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=0, vocab_size=32000,
    rope_theta=1_000_000.0, tie_embeddings=False,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=14336),
)
