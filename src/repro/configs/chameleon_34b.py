"""Chameleon-34B [arXiv:2405.09818]: early-fusion mixed-modal decoder.
VQ image tokens live in the shared 65536 vocab, so the (stubbed) modality
frontend reduces to token ids; qk-norm per the paper."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", arch_type="vlm", source="arXiv:2405.09818",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, tie_embeddings=False,
)
