"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone with a shared attention
block applied every 6 Mamba blocks (81 = 13x6 + 3 trailing)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", arch_type="hybrid", source="arXiv:2411.15242",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    hybrid_attn_every=6, tie_embeddings=True,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk=64),
)
