from repro.training.train import (AdamWState, init_opt_state,  # noqa: F401
                                  make_train_step)
