"""Training substrate: cross-entropy LM loss + AdamW (bf16 moments).

The paper is inference-only, but the brief requires the ``train_4k`` shape
and an end-to-end training example; this is a complete, sharding-friendly
train step. Moments are kept in bf16 and sharded like the params (with
optional ZeRO over the pod axis, see launch/sharding.py) so the trillion-
parameter MoE config stays addressable per device.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: any
    nu: any
    step: jax.Array


def init_opt_state(params) -> AdamWState:
    zeros = lambda t: jax.tree_util.tree_map(  # noqa: E731
        lambda a: jnp.zeros(a.shape, jnp.bfloat16), t)
    return AdamWState(zeros(params), zeros(params),
                      jnp.zeros((), jnp.int32))


def cross_entropy(logits, labels):
    """logits: [B,S,V]; labels: [B,S] -> mean NLL (fp32)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_train_step(api, *, lr: float = 3e-4, beta1: float = 0.9,
                    beta2: float = 0.95, eps: float = 1e-8,
                    weight_decay: float = 0.1, aux_coef: float = 0.01,
                    clip: float = 1.0):
    def loss_fn(params, batch, route_state):
        logits, aux = api.forward_train(params, batch, route_state)
        return cross_entropy(logits, batch["labels"]) + aux_coef * aux

    def train_step(params, opt: AdamWState, batch, route_state):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, route_state)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
        step = opt.step + 1
        bc1 = 1.0 - beta1 ** step.astype(jnp.float32)
        bc2 = 1.0 - beta2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = beta1 * m.astype(jnp.float32) + (1 - beta1) * g
            v32 = beta2 * v.astype(jnp.float32) + (1 - beta2) * g * g
            mh = m32 / bc1
            vh = v32 / bc2
            delta = lr * (mh / (jnp.sqrt(vh) + eps) +
                          weight_decay * p.astype(jnp.float32))
            return ((p.astype(jnp.float32) - delta).astype(p.dtype),
                    m32.astype(jnp.bfloat16), v32.astype(jnp.bfloat16))

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(opt.mu)
        flat_v = jax.tree_util.tree_leaves(opt.nu)
        new = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(tdef, [n[0] for n in new])
        new_m = jax.tree_util.tree_unflatten(tdef, [n[1] for n in new])
        new_v = jax.tree_util.tree_unflatten(tdef, [n[2] for n in new])
        return new_p, AdamWState(new_m, new_v, step), loss

    return train_step
