"""Model/optimizer checkpoint I/O (npz-based, dependency-free).

Flattens a params/opt-state pytree to path-keyed arrays. Used by the
training launcher for periodic snapshots and by serving to load trained
weights. (KV-cache checkpointing — the paper's contribution — lives in
core/checkpoint.py; this is the ordinary weights substrate.)
"""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_params(path: str, params, step: int = 0):
    arrays = _flatten(params)
    arrays["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)  # atomic publish


def load_params(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype verified)."""
    with np.load(path) as data:
        step = int(data["__step__"]) if "__step__" in data else 0
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, leaf in flat:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in p)
            arr = data[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
