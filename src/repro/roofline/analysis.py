"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the brief:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16)
    memory     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
    collective = collective_bytes_per_device / link_bw       (~50 GB/s/link)

Sources: ``compiled.cost_analysis()`` (flops / bytes accessed — the compiled
module is the post-SPMD per-device program, so these are per-device numbers),
and the optimized HLO text for collective bytes (result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).

Also reports MODEL_FLOPS (6·N·D dense, 6·N_active·D MoE) and the useful-FLOP
ratio MODEL_FLOPS / (HLO_FLOPs · chips) that catches remat/shadow/capacity
waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|[a-z]+\d+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective opcode in optimized HLO."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.*?)\s+([a-z0-9-]+)\(", stripped)
        if not m:
            continue
        result_types, op = m.groups()
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        for dt, dims in _SHAPE_RE.findall(result_types):
            out[base] += _shape_bytes(dt, dims)
    return out


@dataclass
class RooflineReport:
    name: str
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_bytes: float           # per device
    coll_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float          # whole-step useful FLOPs (all devices)
    useful_ratio: float
    mem_per_device_bytes: Optional[float] = None
    raw_cost_flops: float = 0.0   # cost_analysis() as-is (loop bodies x1)
    raw_cost_bytes: float = 0.0

    def to_dict(self):
        return asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); D = tokens processed this step."""
    n = cfg.active_param_count
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens           # fwd+bwd
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per request


def analyze(name: str, compiled, cfg: ModelConfig, shape: ShapeConfig,
            chips: int) -> RooflineReport:
    """Loop-aware accounting: ``cost_analysis()`` counts while-loop bodies
    once (a ~num_layers x undercount for scan-over-layers models), so flops /
    bytes / collective bytes come from the call-graph walk in hlo_parse with
    ``known_trip_count`` multiplicities. Raw cost_analysis numbers are kept
    in the report for comparison."""
    from repro.roofline.hlo_parse import analyze_hlo
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)
    flops = float(hc.flops)
    bytes_accessed = float(hc.hbm_bytes)
    coll = {k: int(v) for k, v in hc.coll_breakdown.items()}
    coll_total = float(hc.coll_bytes)
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    useful = mf / (flops * chips) if flops else 0.0

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(ma.argument_size_in_bytes + ma.output_size_in_bytes -
                    ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    except Exception:
        pass

    return RooflineReport(
        name=name, chips=chips, hlo_flops=flops, hlo_bytes=bytes_accessed,
        coll_bytes=coll_total, coll_breakdown=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, useful_ratio=useful,
        mem_per_device_bytes=mem, raw_cost_flops=raw_flops,
        raw_cost_bytes=raw_bytes)
