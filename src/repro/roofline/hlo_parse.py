"""Lightweight optimized-HLO parser for roofline accounting.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which silently
under-reports every scan-over-layers model by ~num_layers x. This module
re-derives per-device costs by walking the HLO call graph and multiplying
each computation's costs by its effective execution count (product of
``known_trip_count`` along the path from ENTRY):

  * flops          — 2 * numel(result) * contracted_size for every dot
  * collective bytes — result-shape bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute
  * hbm bytes      — operand + result bytes of fusions, dots, copies,
    convert/dus/ds at computation top level (roofline-style traffic proxy)

The parser is intentionally tolerant: unknown constructs contribute zero
rather than raising.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(pred|[a-z]+\d+(?:[a-z0-9]*)?)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")
_OPCODE_RE = re.compile(r"(?:^|\s)([a-z][a-z0-9\-]*)\(")
_CALL_ATTR = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)="
    r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_MEM_OPS = {"fusion", "dot", "copy", "convert", "dynamic-slice",
            "dynamic-update-slice", "concatenate", "pad", "slice",
            "transpose", "reduce", "select-and-scatter", "scatter",
            "gather", "iota", "broadcast", "custom-call", "cholesky",
            "sort"}


def _shapes_of(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    result_shapes: list
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, list] = field(default_factory=dict)
    # (callee, trip_multiplier) pairs
    calls: List[Tuple[str, int]] = field(default_factory=list)


def parse_hlo(text: str) -> Dict[str, "Computation"]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        hdr = _COMP_HDR.match(stripped)
        if hdr and stripped.endswith("{"):
            is_entry, name, params = hdr.groups()
            cur = Computation(name)
            comps[name] = cur
            if is_entry:
                entry = name
            # parameter shapes into the symbol table (shapes contain commas,
            # so match the dtype[dims]{layout} form explicitly)
            for pm in re.finditer(
                    r"([\w.\-]+):\s*((?:pred|[a-z]+\d+[a-z0-9]*)"
                    r"\[[\d,]*\](?:\{[^}]*\})?)", params):
                pname, ptype = pm.groups()
                cur.symbols[pname] = _shapes_of(ptype)
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = re.match(r"^(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", stripped)
        if not m:
            continue
        _, name, rest = m.groups()
        op_m = _OPCODE_RE.search(rest)
        if not op_m:
            continue
        opcode = op_m.group(1)
        type_part = rest[: op_m.start()]
        result_shapes = _shapes_of(type_part)
        # operand refs inside the first paren group
        start = op_m.end() - 1  # position of "(" in rest
        depth, i = 0, start
        while i < len(rest):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        operand_text = rest[start + 1: i]
        attr_text = rest[i + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_text)
        instr = Instr(name, opcode, result_shapes, operands, stripped)
        cur.instrs.append(instr)
        cur.symbols[name] = result_shapes
        # call graph edges
        trip = 1
        if opcode == "while":
            t = _TRIP_RE.search(attr_text)
            trip = int(t.group(1)) if t else 1
        for cm in _CALL_ATTR.finditer(attr_text):
            group, single = cm.groups()
            names = re.findall(r"%?([\w.\-]+)", group) if group else [single]
            for cn in names:
                # condition computations run trip+1 times; close enough
                cur.calls.append((cn, trip if opcode == "while" else 1))
    comps["__entry__"] = comps.get(entry, Computation("none"))
    comps["__entry_name__"] = entry
    return comps


def computation_multiplicities(comps) -> Dict[str, float]:
    entry = comps["__entry_name__"]
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if depth > 64 or name not in comps or isinstance(comps[name], str):
            return
        mult[name] += m
        for callee, trip in comps[name].calls:
            visit(callee, m * trip, depth + 1)

    if entry:
        visit(entry, 1.0)
    return mult


def _operand_shapes(comp: Computation, instr: Instr):
    out = []
    for o in instr.operands:
        out.append(comp.symbols.get(o, []))
    return out


@dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = field(default_factory=dict)


def analyze_hlo(text: str) -> HloCosts:
    comps = parse_hlo(text)
    mult = computation_multiplicities(comps)
    costs = HloCosts(coll_breakdown={c: 0.0 for c in _COLLECTIVES})
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None or isinstance(comp, str) or m <= 0:
            continue
        for ins in comp.instrs:
            base = ins.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                b = _nbytes(ins.result_shapes) * m
                costs.coll_bytes += b
                costs.coll_breakdown[base] += b
            if ins.opcode == "dot":
                cd = _LHS_CDIMS.search(ins.line)
                lhs = _operand_shapes(comp, ins)
                contracted = 1
                if cd and lhs and lhs[0]:
                    dims = [int(x) for x in cd.group(1).split(",") if x]
                    shape = lhs[0][0][1]
                    for d in dims:
                        if d < len(shape):
                            contracted *= shape[d]
                numel = 0
                for _, dims in ins.result_shapes:
                    n = 1
                    for d in dims:
                        n *= d
                    numel += n
                costs.flops += 2.0 * numel * contracted * m
            if ins.opcode in _MEM_OPS:
                b = _nbytes(ins.result_shapes)
                for osh in _operand_shapes(comp, ins):
                    b += _nbytes(osh)
                costs.hbm_bytes += b * m
    return costs
