import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh; print memory_analysis / cost_analysis; emit roofline
terms (deliverables e + g).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape decode_32k [--multi-pod] [--no-tarragon] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST stay the first statement: jax locks the device
count on first init. Smoke tests and benchmarks never import this module.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import SHAPES, supports_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import ShardingPolicy
from repro.launch.specs import adapt_config, build_case
from repro.roofline import analysis


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             tarragon: bool = True, policy: ShardingPolicy = None,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    cfg = adapt_config(get_config(arch), shape)
    if not supports_shape(cfg, shape):
        return {"name": f"{arch}:{shape_name}", "status": "skipped",
                "reason": "no sub-quadratic long-context path (DESIGN.md)"}
    t0 = time.time()
    case = build_case(arch, shape_name, mesh, policy=policy,
                      tarragon=tarragon)
    jitted = jax.jit(case.step_fn,
                     in_shardings=case.in_shardings,
                     out_shardings=case.out_shardings,
                     donate_argnums=case.donate_argnums)
    with mesh:
        lowered = jitted.lower(*case.args)
        compiled = lowered.compile()
    t1 = time.time()

    chips = mesh.devices.size
    rep = analysis.analyze(case.name, compiled, cfg, shape, chips)
    result = rep.to_dict()
    result.update({
        "status": "ok",
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
        "tarragon": tarragon,
        "compile_s": round(t1 - t0, 1),
    })
    if verbose:
        ma = compiled.memory_analysis()
        print(f"== {case.name} mesh={result['mesh']} "
              f"(compile {result['compile_s']}s)")
        print(f"   memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"alias={ma.alias_size_in_bytes/2**30:.2f}GiB")
        print(f"   cost_analysis: flops/dev={rep.hlo_flops:.3e} "
              f"bytes/dev={rep.hlo_bytes:.3e}")
        print(f"   roofline: compute={rep.compute_s*1e3:.3f}ms "
              f"memory={rep.memory_s*1e3:.3f}ms "
              f"collective={rep.collective_s*1e3:.3f}ms "
              f"-> {rep.dominant}-bound, useful={rep.useful_ratio:.3f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-paper-model", action="store_true",
                    help="also sweep mixtral-8x7b (the paper's own model)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-tarragon", action="store_true",
                    help="MegaScale-style static binding baseline")
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args()

    cases = []
    if args.all:
        archs = list(ASSIGNED_ARCHS)
        if args.include_paper_model:
            archs.append("mixtral_8x7b")
        for arch in archs:
            arch_name = get_config(arch).name
            for shape_name in SHAPES:
                cases.append((arch_name, shape_name))
    else:
        assert args.arch and args.shape
        cases.append((args.arch, args.shape))

    results = []
    for arch, shape_name in cases:
        try:
            results.append(run_case(arch, shape_name,
                                    multi_pod=args.multi_pod,
                                    tarragon=not args.no_tarragon))
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            results.append({"name": f"{arch}:{shape_name}",
                            "status": "error", "error": str(e)})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = len(results) - n_ok - n_skip
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
