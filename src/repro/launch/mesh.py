"""Production mesh definitions (TPU v5e target).

Single pod: 16x16 = 256 chips -> ("data", "model").
Multi-pod:  2x16x16 = 512 chips -> ("pod", "data", "model").

AW/EW mapping (DESIGN.md): the ``data`` axis carries data-parallel attention
workers (disjoint request slots), the ``model`` axis carries the
expert-parallel / tensor-parallel group (EWs for MoE archs). ``pod`` extends
data parallelism across pods.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for CPU tests (1 device)."""
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """Axes that carry batch (data parallel) sharding."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mp_axis(mesh) -> str:
    return "model"


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s
