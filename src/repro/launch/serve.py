"""Serving launcher: run the Tarragon engine against a workload on a chosen
mesh/scale.

CPU-functional mode (default — this container):
    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
        --workload random --rps 4 --duration 2 [--fail ew:0@0.5] \
        [--scale add_ew@1.0] [--scale drain_ew:2@3.0] [--max-ew 4] \
        [--ew-policy promote] [--rebalance]

The reduced model runs for real; failures are injected and recovered, and
the EW pool is elastic: scale events, load-aware rebalancing, and shadow
promotion are versioned placement-plan installs (core/placement.py). On a
real TPU cluster the same engine/step functions run with the production
mesh shardings from launch/sharding.py (see launch/dryrun.py for the exact
jit configuration per architecture x shape).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.configs import get_config
from repro.core.orchestrator import Orchestrator
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import FailurePlan, ScalePlan, run_serving
from repro.serving.telemetry import pct


def parse_failure(s: str) -> FailurePlan:
    kindid, t = s.split("@")
    kind, wid = kindid.split(":")
    return FailurePlan(float(t), kind, int(wid))


def parse_scale(s: str) -> ScalePlan:
    """add_ew@T | drain_ew:ID@T | rebalance@T"""
    kindid, t = s.split("@")
    kind, _, wid = kindid.partition(":")
    if kind not in ("add_ew", "drain_ew", "rebalance"):
        raise ValueError(f"unknown scale kind {kind!r} in --scale {s!r} "
                         "(add_ew@T | drain_ew:ID@T | rebalance@T)")
    return ScalePlan(float(t), kind, int(wid) if wid else -1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x7b")
    ap.add_argument("--workload",
                    choices=("random", "sharegpt", "skewed_expert_load",
                             "mixed_slo", "multi_turn_chat"),
                    default="random")
    ap.add_argument("--rps", type=float, default=4.0)
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--num-aw", type=int, default=2)
    ap.add_argument("--num-ew", type=int, default=2)
    ap.add_argument("--max-ew", type=int, default=0,
                    help="elastic EW pool ceiling (spares the orchestrator "
                         "can scale out into; 0 = num_ew)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--placement", default="least_loaded",
                    choices=("least_loaded", "round_robin",
                             "session_affinity"),
                    help="Gateway AW placement policy")
    ap.add_argument("--no-tarragon", action="store_true")
    ap.add_argument("--fail", type=str, action="append", default=[],
                    help="kind:worker@time, e.g. ew:0@0.5")
    ap.add_argument("--scale", type=str, action="append", default=[],
                    help="add_ew@T | drain_ew:ID@T | rebalance@T")
    ap.add_argument("--ew-policy", choices=("revive", "promote"),
                    default="revive",
                    help="EW failure handling: background revival, or "
                         "permanent shadow promotion (pool shrinks)")
    ap.add_argument("--rebalance", action="store_true",
                    help="auto-rebalance expert placement under load skew")
    ap.add_argument("--controller", action="store_true",
                    help="SLO-driven closed-loop control plane: EW "
                         "autoscaling, trajectory-triggered rebalance with "
                         "weighted splits, adaptive chunk budget, and "
                         "deadline-aware preemption (serving/controller.py)")
    ap.add_argument("--no-ctl-autoscale", action="store_true",
                    help="with --controller: disable the autoscale policy")
    ap.add_argument("--no-ctl-rebalance", action="store_true",
                    help="with --controller: disable the rebalance policy")
    ap.add_argument("--no-ctl-budget", action="store_true",
                    help="with --controller: disable the adaptive "
                         "chunk budget policy")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable preempt-and-requeue (blocked interactive "
                         "requests wait instead of evicting batch victims)")
    ap.add_argument("--prefix-slots", type=int, default=0,
                    help="per-AW prefix-cache slot budget (0 = plane off; "
                         "enables chunked prefill implicitly)")
    ap.add_argument("--chunk-budget", type=int, default=0,
                    help="chunked-prefill token budget per tick "
                         "(0 = whole-prompt prefill)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable the telemetry plane (output is "
                         "bit-identical either way)")
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto/Chrome trace_event JSON here")
    ap.add_argument("--postmortem", default="", metavar="PATH",
                    help="dump the flight-recorder postmortem bundle "
                         "(repro.postmortem.v1 JSON, replayable with "
                         "python -m repro.launch.replay) here at exit")
    ap.add_argument("--watchdogs", action="store_true",
                    help="continuous health watchdogs: leak/stall "
                         "regression detectors + invariant probes "
                         "(prints the health summary at exit)")
    ap.add_argument("--metrics-out", default="",
                    help="write the JSON metrics snapshot here")
    ap.add_argument("--prom-out", default="",
                    help="write the Prometheus text exposition here")
    args = ap.parse_args()
    if args.prefix_slots and not args.chunk_budget:
        args.chunk_budget = 16

    cfg = get_config(args.arch).reduced()
    if cfg.moe.enabled:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    if args.workload == "multi_turn_chat" and \
            args.placement == "least_loaded":
        args.placement = "session_affinity"
    ecfg = EngineConfig(max_batch=args.max_batch, max_seq=96,
                        num_aw=args.num_aw, num_ew=args.num_ew,
                        max_ew=args.max_ew,
                        tarragon=not args.no_tarragon,
                        placement=args.placement,
                        preempt=not args.no_preempt,
                        chunk_token_budget=args.chunk_budget,
                        prefill_token_cap=8 * args.chunk_budget,
                        prefix_cache_slots=args.prefix_slots,
                        telemetry=not args.no_telemetry,
                        trace_export_path=args.trace_out,
                        controller="on" if args.controller else "off",
                        ctl_autoscale=not args.no_ctl_autoscale,
                        ctl_rebalance=not args.no_ctl_rebalance,
                        ctl_chunk_budget=not args.no_ctl_budget,
                        victim_policy="controller" if args.controller and
                        not args.no_preempt else "remaining_work",
                        watchdogs=args.watchdogs)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(args.seed))
    orch = Orchestrator(eng, worker_init_time=1.0, weight_push_time=0.25,
                        ew_policy=args.ew_policy,
                        auto_rebalance=args.rebalance)

    wl = make_workload(args.workload, args.rps, args.duration,
                       seed=args.seed, max_prompt=16, max_new=24)
    failures = [parse_failure(f) for f in args.fail]
    scales = [parse_scale(s) for s in args.scale]
    m = run_serving(eng, wl, duration=600.0, orchestrator=orch,
                    failures=failures, scale_events=scales, step_time=0.05)

    tbt = m.tbt_values()
    print(f"[serve] {cfg.name} tarragon={not args.no_tarragon} "
          f"AW={args.num_aw} EW={args.num_ew} placement={args.placement}")
    print(f"  requests finished: {len(m.finished)}/{len(wl)}")
    print(f"  tokens: {len(m.token_log)}  "
          f"throughput: {m.throughput():.1f} tok/s")
    if tbt.size:
        print(f"  TBT p50={pct(tbt, 50)*1e3:.1f}ms "
              f"p95={pct(tbt, 95)*1e3:.1f}ms "
              f"max_stall={m.max_stall()*1e3:.1f}ms")
    qd = m.queue_delay_values()
    if qd.size:
        print(f"  queue delay p50={pct(qd, 50)*1e3:.1f}ms "
              f"p99={pct(qd, 99)*1e3:.1f}ms")
    if m.prefill:
        print(f"  prefill: {m.prefill['calls']} calls / "
              f"{m.prefill['requests']} reqs "
              f"occupancy={m.prefill['occupancy']:.2f}")
    if eng.placement_mgr is not None:
        mgr = eng.placement_mgr
        print(f"  expert plane: gen={mgr.plan.generation} "
              f"pool={sorted(eng.live_ews)} "
              f"imbalance={mgr.imbalance():.2f}")
    pf = m.gateway.get("prefix", {})
    if pf.get("hits") or pf.get("misses"):
        print(f"  prefix cache: {pf['hits']} hits, "
              f"{pf['hit_tokens']} tokens adopted, "
              f"{pf['restored']} restored, {pf['repins']} repins")
    if m.gateway.get("by_class"):
        print(f"  request plane: preemptions={m.gateway['preemptions']}")
        for cls, counts in sorted(m.gateway["by_class"].items()):
            ttft = m.ttft_values(cls)
            extra = f" ttft_p50={pct(ttft, 50)*1e3:.0f}ms" \
                if ttft.size else ""
            print(f"    {cls}: {counts}{extra}")
    for e in orch.events:
        print(f"  [orch t={e.t:.2f}] {e.kind} {e.worker} {e.detail}")
    if eng.controller is not None:
        for d in eng.controller.decisions:
            print(f"  [ctl t={d['t']:.2f}] {d['kind']} {d['detail']}")
    if m.telemetry is not None:
        for st in m.telemetry.stall_report():
            comps = ", ".join(f"{k}={v*1e3:.0f}ms"
                              for k, v in sorted(st["components"].items())
                              if v > 1e-6)
            print(f"  [stall {st['rid']} {st['kind']} "
                  f"{st['gap']*1e3:.0f}ms] {comps}")
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                json.dump(m.telemetry.snapshot(), f, indent=1)
            print(f"  metrics snapshot -> {args.metrics_out}")
        if args.prom_out:
            with open(args.prom_out, "w") as f:
                f.write(m.telemetry.prometheus_text())
            print(f"  prometheus text -> {args.prom_out}")
        if args.trace_out:
            print(f"  perfetto trace -> {args.trace_out} "
                  f"(open at ui.perfetto.dev)")
    fr = eng.flightrec
    if fr is not None and fr.watchdogs is not None:
        hs = fr.watchdogs.summary()
        print(f"  health: {hs['trips']} watchdog trip(s) over "
              f"{hs['intervals']} interval(s) {dict(hs['by_kind'])}")
        for t in hs["last_trips"]:
            print(f"    [health t={t['t']:.2f}] {t['kind']} "
                  f"{t['what']}: {t['detail']}")
    if args.postmortem and fr is not None:
        fr.dump(args.postmortem, reason="postmortem on demand (--postmortem)")
        print(f"  postmortem bundle -> {args.postmortem} "
              f"(replay: python -m repro.launch.replay {args.postmortem})")


if __name__ == "__main__":
    main()
