"""Sharding rules: map every param / cache / activation leaf to a
PartitionSpec on the production mesh.

Baseline (paper-faithful MegaScale/DEP mapping):
  * attention params  — tensor-parallel over ``model`` (heads / d_ff split),
    batch over ``pod``+``data``  (AW group = data-parallel attention)
  * MoE expert banks  — expert axis over ``model`` (EW group = expert
    parallel); optionally the per-expert FF dim over ``data`` for weights
    that exceed HBM otherwise (kimi-k2)
  * shadow banks      — like experts when the slot count divides, else
    replicated (they are one EW's worth of memory)
  * KV caches         — batch over dp; KV heads over ``model`` when they
    divide, else the sequence axis (long_500k / few-KV-head archs)

Everything is divisibility-guarded: a dim is only sharded if the axis size
divides it, so every (arch x shape x mesh) combination lowers.
``ShardingPolicy`` carries the per-arch/hillclimb overrides.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import dp_axes


@dataclass(frozen=True)
class ShardingPolicy:
    expert_ff_over_data: bool = False    # kimi-k2: shard expert FF over data
    vocab_over_model: bool = True
    seq_shard_long: bool = True          # batch-1 decode: shard KV seq
    # ZeRO-style weight sharding over the pod axis (train memory relief)
    zero_over_pod: bool = False
    # §Perf iteration 3: only seq-shard a KV cache when replicating it
    # would actually cost memory — a ring-buffered sliding-window cache is
    # small, and sharding its sequence axis makes every decode layer pay
    # gather/permute collectives for nothing.
    cache_replicate_max_bytes: int = 256 * 2**20


def _div(n: int, size: int) -> bool:
    return size > 1 and n % size == 0 and n >= size


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[a] for a in name]))
    return mesh.shape[name]


class Sharder:
    def __init__(self, cfg: ModelConfig, mesh: Mesh,
                 policy: ShardingPolicy = ShardingPolicy()):
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy
        self.dp = dp_axes(mesh)
        self.dp = self.dp[0] if len(self.dp) == 1 else self.dp
        self.mp = "model"
        self.mp_size = mesh.shape["model"]
        self.dp_size = _axis_size(mesh, self.dp)
        self.data_size = mesh.shape["data"]

    # ------------------------------------------------------------------
    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def _spec_nd(self, ndim: int, placed: Dict[int, Any]) -> P:
        dims = [None] * ndim
        for ax, name in placed.items():
            dims[ax] = name
        return P(*dims)

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def param_spec(self, path: str, shape) -> P:
        nd = len(shape)
        mp, dp = self.mp, "data"
        pol = self.policy

        def last_over_mp():
            return {nd - 1: mp} if _div(shape[-1], self.mp_size) else {}

        def penult_over_mp():
            return {nd - 2: mp} if _div(shape[-2], self.mp_size) else {}

        placed: Dict[int, Any] = {}
        if re.search(r"(experts|shadow)/(wg|wu)$", path):
            # [..., E, D, F]
            if _div(shape[nd - 3], self.mp_size):
                placed[nd - 3] = mp
            if pol.expert_ff_over_data and _div(shape[-1], self.data_size):
                placed[nd - 1] = dp
        elif re.search(r"(experts|shadow)/wd$", path):
            # [..., E, F, D]
            if _div(shape[nd - 3], self.mp_size):
                placed[nd - 3] = mp
            if pol.expert_ff_over_data and _div(shape[-2], self.data_size):
                placed[nd - 2] = dp
        elif re.search(r"router$", path):
            placed = {}
        elif re.search(r"(embed|unembed)$", path):
            if pol.vocab_over_model and _div(shape[-2], self.mp_size):
                placed[nd - 2] = mp
        elif re.search(r"/(wq|wk|wv|w_up|w_gate|in_proj|wi|wf|wz|wo_gate|"
                       r"ri|rf|rz|ro)$", path):
            placed = last_over_mp()
        elif re.search(r"/(wo|w_down|out_proj)$", path):
            placed = penult_over_mp()
        elif re.search(r"/(bq|bk|bv)$", path):
            placed = last_over_mp()
        elif re.search(r"/conv_w$", path):
            placed = last_over_mp()
        else:
            placed = {}

        if pol.zero_over_pod and "pod" in self.mesh.axis_names:
            # FSDP/ZeRO: additionally shard the largest unplaced dim over pod
            pod = self.mesh.shape["pod"]
            free = [i for i in range(nd) if i not in placed]
            free.sort(key=lambda i: -shape[i])
            for i in free:
                if _div(shape[i], pod):
                    placed[i] = "pod"
                    break
        return self._spec_nd(nd, placed)

    def shard_params(self, params_shapes):
        flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
        out = []
        for path, leaf in flat:
            p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
            out.append(self.named(self.param_spec(p, leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    # cache
    # ------------------------------------------------------------------
    def cache_spec(self, kind: str, shape, batch_axis: int) -> P:
        nd = len(shape)
        placed: Dict[int, Any] = {}
        b = shape[batch_axis]
        if _div(b, self.dp_size):
            placed[batch_axis] = self.dp
        elif _div(b, self.data_size):
            placed[batch_axis] = "data"
        if kind in ("attn_k", "attn_v"):
            h_ax, s_ax = batch_axis + 2, batch_axis + 1
            leaf_bytes = int(np.prod(shape)) * 2  # bf16
            if batch_axis in placed:
                leaf_bytes //= self.dp_size
            if _div(shape[h_ax], self.mp_size):
                placed[h_ax] = self.mp
            elif self.policy.seq_shard_long and _div(shape[s_ax],
                                                     self.mp_size) and \
                    leaf_bytes > self.policy.cache_replicate_max_bytes:
                placed[s_ax] = self.mp
        elif kind == "state":
            # shard the first post-batch dim divisible by model axis
            for ax in range(batch_axis + 1, nd):
                if _div(shape[ax], self.mp_size):
                    placed[ax] = self.mp
                    break
        return self._spec_nd(nd, placed)

    def shard_cache(self, layout, cache_shapes):
        leaves, treedef = jax.tree_util.tree_flatten(cache_shapes)
        out = []
        for leaf, ax, kind in zip(leaves, layout.batch_axis,
                                  layout.leaf_kind):
            out.append(self.named(self.cache_spec(kind, leaf.shape, ax)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    # activations / batch inputs
    # ------------------------------------------------------------------
    def batch_spec(self, shape) -> P:
        nd = len(shape)
        if nd == 0:
            return P()
        if _div(shape[0], self.dp_size):
            return self._spec_nd(nd, {0: self.dp})
        if _div(shape[0], self.data_size):
            return self._spec_nd(nd, {0: "data"})
        return self._spec_nd(nd, {})

    def shard_batch(self, tree):
        return jax.tree_util.tree_map(
            lambda l: self.named(self.batch_spec(l.shape)), tree)

    def replicated(self, tree):
        return jax.tree_util.tree_map(lambda _: self.named(P()), tree)
