"""Training launcher: train a reduced model for N steps on synthetic LM
data (the paper is inference-focused; this exercises the training substrate
required by the train_4k shape).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b \
        --steps 50 --batch 4 --seq 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.workloads import lm_batches
from repro.models import get_model
from repro.training import init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = get_model(cfg, num_aw=1, num_ew=2)
    params = api.init_params(jax.random.PRNGKey(0))
    rs = api.init_route_state()
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(api, lr=args.lr))

    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")

    losses = []
    t0 = time.time()
    for i, batch in enumerate(lm_batches(cfg.vocab_size, args.batch,
                                         args.seq, args.steps, seed=1)):
        if cfg.is_encdec:
            batch["frames"] = np.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), np.float32)
        params, opt, loss = step_fn(params, opt, batch, rs)
        losses.append(float(loss))
        if (i + 1) % args.log_every == 0:
            print(f"  step {i+1:4d}  loss {losses[-1]:.4f}  "
                  f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)")
    print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")


if __name__ == "__main__":
    main()
