"""ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
shardable, zero allocation — the dry-run lowers against these.

``build_case`` assembles everything one (arch x shape x mesh) combination
needs: the step function, arg structs and shardings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig, SHAPES, ShapeConfig
from repro.launch.mesh import dp_axes, dp_size
from repro.launch.sharding import Sharder, ShardingPolicy
from repro.models import get_model
from repro.serving.kvcache import CacheLayout
from repro.training import init_opt_state, make_train_step

KEY_STRUCT = jax.ShapeDtypeStruct((2,), jnp.uint32)


# Long-context variants (DESIGN.md §4): archs whose full-attention layers
# get a sliding window for the 500k decode shape.
LONG_VARIANT_WINDOW = {"zamba2-7b": 8192, "gemma2-2b": 4096}


def adapt_config(cfg: ModelConfig, shape: ShapeConfig,
                 dtype: str = "bfloat16") -> ModelConfig:
    cfg = dataclasses.replace(cfg, dtype=dtype)
    if shape.kind == "train":
        cfg = dataclasses.replace(cfg, remat=True)
    if shape.name == "long_500k" and cfg.name in LONG_VARIANT_WINDOW:
        win = LONG_VARIANT_WINDOW[cfg.name]
        if cfg.sliding_window == 0:
            cfg = dataclasses.replace(cfg, sliding_window=win)
    return cfg


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_structs(cfg: ModelConfig, shape: ShapeConfig, *,
                  with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _struct((b, s), jnp.int32)}
    if with_labels:
        batch["labels"] = _struct((b, s), jnp.int32)
    if cfg.is_encdec:
        batch["frames"] = _struct((b, cfg.encoder_seq, cfg.d_model),
                                  cfg.jnp_dtype)
    return batch


@dataclass
class DryrunCase:
    name: str
    step_fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def build_case(arch: str, shape_name: str, mesh,
               policy: Optional[ShardingPolicy] = None,
               tarragon: bool = True, dtype: str = "bfloat16") -> DryrunCase:
    shape = SHAPES[shape_name]
    cfg = adapt_config(get_config(arch), shape, dtype)
    if policy is None:
        policy = ShardingPolicy(
            expert_ff_over_data=(cfg.name == "kimi-k2-1t-a32b"),
            zero_over_pod=(shape.kind == "train"))
    num_aw = mesh.shape["data"]
    num_ew = mesh.shape["model"]
    api = get_model(cfg, num_aw=num_aw, num_ew=num_ew, tarragon=tarragon)
    sharder = Sharder(cfg, mesh, policy)

    params_s = jax.eval_shape(api.init_params, KEY_STRUCT)
    params_sh = sharder.shard_params(params_s)
    rs_s = jax.eval_shape(api.init_route_state)
    rs_sh = sharder.replicated(rs_s)

    if shape.kind == "train":
        batch_s = batch_structs(cfg, shape, with_labels=True)
        batch_sh = sharder.shard_batch(batch_s)
        opt_s = jax.eval_shape(init_opt_state, params_s)
        opt_sh = type(opt_s)(params_sh, params_sh,
                             sharder.named(jax.sharding.PartitionSpec()))
        train_step = make_train_step(api)
        return DryrunCase(
            name=f"{arch}:{shape_name}:train",
            step_fn=train_step,
            args=(params_s, opt_s, batch_s, rs_s),
            in_shardings=(params_sh, opt_sh, batch_sh, rs_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        batch_s = batch_structs(cfg, shape, with_labels=False)
        batch_sh = sharder.shard_batch(batch_s)
        step = partial(api.prefill, max_seq=shape.seq_len)
        return DryrunCase(
            name=f"{arch}:{shape_name}:prefill",
            step_fn=step,
            args=(params_s, batch_s, rs_s),
            in_shardings=(params_sh, batch_sh, rs_sh),
            out_shardings=None,
        )

    # decode: ONE new token against a seq_len KV cache
    b, s = shape.global_batch, shape.seq_len
    cache_s = jax.eval_shape(lambda: api.init_cache(b, s))
    layout = CacheLayout(api.init_cache)
    cache_sh = sharder.shard_cache(layout, cache_s)
    tokens_s = _struct((b,), jnp.int32)
    pos_s = _struct((b,), jnp.int32)
    tok_sh = sharder.named(sharder.batch_spec((b,)))
    logits_sh = None
    return DryrunCase(
        name=f"{arch}:{shape_name}:decode",
        step_fn=api.decode,
        args=(params_s, tokens_s, pos_s, cache_s, rs_s),
        in_shardings=(params_sh, tok_sh, tok_sh, cache_sh, rs_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(3,),
    )
