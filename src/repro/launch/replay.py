"""Deterministic incident replay from a flight-recorder postmortem bundle.

``serving/flightrec.py`` captures everything a serving incident *was*:
the engine configuration (and init key), every external submission with
its prompt tokens, the fault/scale injection schedule, the orchestrator's
timing parameters, the serving loop's clock parameters, and — when the
control plane was on — the controller's full decision history. Because
the stack is deterministic on the virtual clock (counter-based device
sampling, seeded workloads, fixed step time), that record is sufficient
to re-run the incident bit-for-bit:

  $ python -m repro.launch.replay incident.postmortem.json

builds a fresh engine from the bundle, re-injects the same faults at the
same virtual times, replays the same arrivals, and asserts the replay's
request outputs are token-identical to the recorded ones — turning any
captured incident into a runnable regression test.

Two modes, mirroring PR 9's controller-replay result:

  * ``exact``  (default) — rebuild the engine exactly as recorded
    (controller state included). A controller="on" engine re-decides
    identically because it sees identical signals.
  * ``script`` — rebuild with the controller OFF and replay its recorded
    decisions as ScalePlans + a scripted chunk-budget timeline. This is
    the stronger forensic claim: the *decisions*, not the decider,
    determined the outcome.

Refuses (rather than silently mis-replays) bundles that are not
self-contained: truncated submission/output rings, wall-clock step
timing, or multiple recorded serving loops.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional

import numpy as np

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.core.costmodel import TarragonProfile
from repro.core.orchestrator import Orchestrator
from repro.serving import flightrec
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import FailurePlan, ScalePlan, run_serving


class BundleError(ValueError):
    """The bundle cannot be replayed faithfully; the message says why."""


def load_bundle(path: str) -> dict:
    with open(path) as f:
        bundle = json.load(f)
    if bundle.get("schema") != flightrec.SCHEMA:
        raise BundleError(
            f"unsupported bundle schema {bundle.get('schema')!r} "
            f"(this tool reads {flightrec.SCHEMA})")
    return bundle


@dataclasses.dataclass(frozen=True)
class ReplayRequest:
    """A recorded submission, shaped like ``data.workloads.Request`` for
    ``run_serving`` — but carrying the captured prompt verbatim instead
    of regenerating from a seed."""
    request_id: str
    arrival: float
    max_new_tokens: int
    prompt: np.ndarray
    slo_class: str = "standard"
    deadline: float = -1.0
    session: str = ""

    def prompt_tokens(self, vocab: int) -> np.ndarray:
        return self.prompt


def _filter_fields(cls, d: dict) -> dict:
    names = {f.name for f in dataclasses.fields(cls)}
    return {k: v for k, v in d.items() if k in names}


def rebuild_model_config(d: dict) -> ModelConfig:
    d = dict(d)
    moe = MoEConfig(**_filter_fields(MoEConfig, d.pop("moe", {}) or {}))
    ssm = SSMConfig(**_filter_fields(SSMConfig, d.pop("ssm", {}) or {}))
    kw = _filter_fields(ModelConfig, d)
    for k, v in kw.items():     # JSON round-trips tuples as lists
        if isinstance(v, list):
            kw[k] = tuple(v)
    return ModelConfig(moe=moe, ssm=ssm, **kw)


def rebuild_engine_config(d: dict, mode: str) -> EngineConfig:
    kw = _filter_fields(EngineConfig, dict(d))
    # neutralize the output-path knobs: a replay must not overwrite the
    # incident's own bundle or trace (both are hash-excluded, so the
    # config-hash handshake still holds)
    kw["flight_autodump"] = ""
    kw["trace_export_path"] = ""
    if mode == "script":
        if kw.get("victim_policy") == "controller":
            raise BundleError(
                'script-mode replay cannot run victim_policy="controller" '
                "(preemption victims are chosen inside the controller, "
                "not recorded as decisions) — use --mode exact")
        kw["controller"] = "off"
    return EngineConfig(**kw)


def _rebuild_requests(bundle: dict) -> List[ReplayRequest]:
    reqs = []
    for s in bundle["submissions"]:
        if s.get("sampling") is not None or \
                s.get("completion_deadline") is not None:
            raise BundleError(
                f"submission {s['rid']!r} carries client-API fields "
                "(sampling/completion_deadline) the serving-loop replay "
                "cannot inject")
        reqs.append(ReplayRequest(
            request_id=s["rid"], arrival=float(s["t"]),
            max_new_tokens=int(s["max_new"]),
            prompt=np.asarray(s["prompt"], np.int32),
            slo_class=s.get("slo_class") or "standard",
            deadline=-1.0 if s.get("deadline") is None
            else float(s["deadline"]),
            session=s.get("session") or ""))
    return sorted(reqs, key=lambda r: (r.arrival, r.request_id))


def _validate(bundle: dict):
    tr = bundle.get("truncated", {})
    if tr.get("submissions") or tr.get("outputs"):
        raise BundleError(
            f"bundle rings truncated (submissions dropped="
            f"{tr.get('submissions')}, outputs dropped="
            f"{tr.get('outputs')}): the workload is incomplete — raise "
            "flight_capacity on the recording engine")
    loops = bundle.get("loops", [])
    if len(loops) != 1:
        raise BundleError(
            f"bundle records {len(loops)} serving loops; replay needs "
            "exactly one (multi-run engines are not replayable as a unit)")
    loop = loops[0]
    if loop["step_time"] is None:
        raise BundleError(
            "recorded loop ran on wall-clock step time; only virtual-clock "
            "runs (step_time=...) replay deterministically")
    if bundle["injections"]["failures"] and bundle.get("orchestrator") \
            is None:
        raise BundleError(
            "bundle records failure injections but no orchestrator "
            "parameters — cannot reconstruct detection/recovery timing")


def replay_bundle(bundle: dict, mode: str = "exact") -> dict:
    """Re-run the recorded incident; return a comparison report.

    ``report["ok"]`` is True iff every recorded finished request is
    reproduced token-identically (and nothing recorded went missing).
    """
    assert mode in ("exact", "script"), mode
    _validate(bundle)
    import jax.numpy as jnp
    cfg = rebuild_model_config(bundle["config"]["model"])
    ecfg = rebuild_engine_config(bundle["config"]["engine"], mode)
    key = jnp.asarray(np.asarray(bundle["config"]["key"], np.uint32))
    eng = InferenceEngine(cfg, ecfg, key)

    hash_ok = True
    if mode == "exact" and eng.flightrec is not None:
        hash_ok = eng.flightrec.config_hash == bundle["config"]["hash"]

    orch: Optional[Orchestrator] = None
    od = bundle.get("orchestrator")
    if od is not None:
        profile = dataclasses.replace(
            TarragonProfile(), detect=od["profile_detect"],
            detect_retries=od["profile_detect_retries"])
        orch = Orchestrator(eng, profile=profile,
                            worker_init_time=od["worker_init_time"],
                            weight_push_time=od["weight_push_time"],
                            ew_policy=od["ew_policy"],
                            auto_rebalance=od["auto_rebalance"],
                            rebalance_cooldown=od["rebalance_cooldown"])

    failures = [FailurePlan(f["t"], f["kind"], f["worker_id"])
                for f in bundle["injections"]["failures"]]
    scales = [ScalePlan(s["t"], s["kind"], s["worker_id"])
              for s in bundle["injections"]["scales"]]

    if mode == "script" and bundle.get("controller"):
        # PR 9 script replay: the recorded decisions become ScalePlans +
        # a scripted budget timeline on a controller-off engine
        decisions = bundle["controller"]["decisions"]
        kind_map = {"scale_out": "add_ew", "scale_in": "drain_ew",
                    "rebalance": "rebalance"}
        scales = scales + [
            ScalePlan(d["t"], kind_map[d["kind"]], d.get("ew", -1))
            for d in decisions if d["kind"] in kind_map]
        if eng.placement_mgr is not None:
            # the controller flips the replica packer to weighted splits
            # at construction; the scripted twin must plan identically
            eng.placement_mgr.split_mode = "weighted"
        budget_script = sorted((d["t"], d["budget"]) for d in decisions
                               if d["kind"] == "budget")
        orig_step = eng.step

        def scripted_step(now=None):
            while budget_script and now is not None and \
                    now >= budget_script[0][0]:
                eng.chunked.set_budget(budget_script.pop(0)[1])
            return orig_step(now=now)
        eng.step = scripted_step

    loop = bundle["loops"][0]
    workload = _rebuild_requests(bundle)
    m = run_serving(eng, workload, loop["duration"], orchestrator=orch,
                    failures=failures, scale_events=scales,
                    step_time=loop["step_time"],
                    prefill_token_time=loop["prefill_token_time"],
                    max_steps=loop["max_steps"])

    recorded = bundle["outputs"]
    mismatched, missing = [], []
    for rid, toks in sorted(recorded.items()):
        got = m.outputs.get(rid)
        if got is None:
            missing.append(rid)
        elif list(got) != list(toks):
            mismatched.append(rid)
    extra = sorted(set(m.outputs) - set(recorded))
    report = {
        "mode": mode,
        "reason": bundle.get("reason"),
        "config_hash": bundle["config"]["hash"],
        "config_hash_ok": hash_ok,
        "requests_recorded": len(recorded),
        "requests_replayed": len(m.outputs),
        "matched": len(recorded) - len(mismatched) - len(missing),
        "mismatched": mismatched,
        "missing": missing,
        "extra_finished": extra,
        "failures_injected": len(failures),
        "scale_events": len(scales),
        "ok": hash_ok and not mismatched and not missing,
    }
    return report


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="Deterministically replay a flight-recorder "
                    "postmortem bundle and verify bit-identical outputs")
    p.add_argument("bundle", help="path to a repro.postmortem.v1 JSON")
    p.add_argument("--mode", choices=("exact", "script"), default="exact",
                   help="exact: rebuild the engine as recorded; script: "
                        "controller off, decisions replayed as a script")
    p.add_argument("--out", default="",
                   help="write the comparison report JSON here")
    args = p.parse_args(argv)

    bundle = load_bundle(args.bundle)
    try:
        report = replay_bundle(bundle, mode=args.mode)
    except BundleError as e:
        print(f"replay refused: {e}", file=sys.stderr)
        return 2
    print(f"replay[{report['mode']}] of {args.bundle} "
          f"(dumped: {report['reason']!r})")
    print(f"  config hash {report['config_hash']} "
          f"{'ok' if report['config_hash_ok'] else 'MISMATCH'}")
    print(f"  recorded finished: {report['requests_recorded']}  "
          f"replayed finished: {report['requests_replayed']}")
    print(f"  matched: {report['matched']}  "
          f"mismatched: {len(report['mismatched'])}  "
          f"missing: {len(report['missing'])}")
    if report["mismatched"]:
        print(f"  token-mismatched rids: {report['mismatched'][:10]}")
    if report["missing"]:
        print(f"  missing rids: {report['missing'][:10]}")
    verdict = "BIT-IDENTICAL" if report["ok"] else "DIVERGED"
    print(f"  verdict: {verdict}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
