"""GQA attention: blockwise (flash-style) full attention for train/prefill and
single-token decode against a (optionally ring-buffered sliding-window) KV
cache.

Cache layout per attention layer:
    {"k": [B, Sc, Hkv, Dh], "v": [B, Sc, Hkv, Dh], "pos": [B, Sc] int32}
``pos`` holds the absolute position stored in each slot (-1 = empty). For
sliding-window layers Sc == window and slots are used as a ring buffer, which
is what makes ``long_500k`` memory-feasible for SWA architectures.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init, apply_rope

NEG_INF = -1e30

# Cached (prefill/chunk) attention pins the KV block size of the online
# softmax: a fully-masked KV block is an exact no-op (m/l/acc unchanged),
# so with a common block size the accumulation order — and therefore the
# float result — is identical whether a token's prefix is scanned inside a
# bucket-padded whole-prompt prefill or inside a full-cache chunk call.
# This is what makes chunked prefill bit-identical to whole-prompt prefill.
PREFILL_BLOCK_K = 16


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, cross: bool = False):
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh),
        "wk": dense_init(ks[1], d, hkv * dh),
        "wv": dense_init(ks[2], d, hkv * dh),
        "wo": dense_init(ks[3], h * dh, d),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh)
        p["k_norm"] = rmsnorm_init(dh)
    return p


def _project_q(cfg, params, x):
    b, s, _ = x.shape
    q = x @ params["wq"]
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim_)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
    return q


def _project_kv(cfg, params, x):
    b, s, _ = x.shape
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bk" in params:
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim_)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim_)
    if "k_norm" in params:
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    return k, v


def _softcap_scores(s, cap: float):
    if cap:
        s = jnp.tanh(s / cap) * cap
    return s


# --------------------------------------------------------------------------
# blockwise full attention (flash-style, pure JAX — ref for the Pallas kernel)
# --------------------------------------------------------------------------

def _pick_block(s: int, target: int = 512) -> int:
    b = min(target, s)
    while s % b:
        b //= 2
    return max(b, 1)


def blockwise_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                        softcap: float = 0.0, causal: bool = True,
                        block_q: int = 0, block_k: int = 0):
    """q: [B,Sq,H,Dh]; k,v: [B,Sk,Hkv,Dh]; *_pos: [B,Sq]/[B,Sk] (-1 = invalid).

    Online-softmax over KV blocks; O(Sq * block_k) live memory per block pair.
    """
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bq = block_q or _pick_block(sq)
    bk = block_k or _pick_block(sk)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    qs = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32) * scale

    def q_block_body(qi):
        qb = jax.lax.dynamic_slice_in_dim(qs, qi * bq, bq, axis=1)
        qpb = jax.lax.dynamic_slice_in_dim(q_pos, qi * bq, bq, axis=1)

        def kv_block_body(carry, ki):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, axis=1)
            kpb = jax.lax.dynamic_slice_in_dim(k_pos, ki * bk, bk, axis=1)
            # scores: [B, bq, Hkv, G, bk]
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qb, kb.astype(jnp.float32))
            s = _softcap_scores(s, softcap)
            mask = kpb[:, None, :] >= 0
            if causal:
                mask &= kpb[:, None, :] <= qpb[:, :, None]
            if window:
                mask &= kpb[:, None, :] > qpb[:, :, None] - window
            s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, bq, hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, bq, hkv, g), jnp.float32)
        a0 = jnp.zeros((b, bq, hkv, g, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block_body, (m0, l0, a0), jnp.arange(sk // bk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # fully-masked rows (invalid q) -> zero
        out = jnp.where((l > 0)[..., None], out, 0.0)
        return out.reshape(b, bq, h, dh)

    blocks = jax.lax.map(q_block_body, jnp.arange(sq // bq))
    # [nq, B, bq, H, Dh] -> [B, Sq, H, Dh]
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, sq, h, dh)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# cache management
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, window: int = 0,
               dtype=None):
    sc = min(window, max_seq) if window else max_seq
    dh, hkv = cfg.head_dim_, cfg.num_kv_heads
    dt = dtype or cfg.jnp_dtype
    return {
        "k": jnp.zeros((batch, sc, hkv, dh), dt),
        "v": jnp.zeros((batch, sc, hkv, dh), dt),
        "pos": jnp.full((batch, sc), -1, jnp.int32),
    }


def cache_write_prefill(cache, k, v, positions):
    """Write prefill K/V [B,S,...] into cache (keeping last Sc if S > Sc)."""
    sc = cache["k"].shape[1]
    s = k.shape[1]
    if s <= sc:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1)
        cp = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions.astype(jnp.int32), 0, 1)
        return {"k": ck, "v": cv, "pos": cp}
    # sliding window: ring-place the last sc entries at slot = pos % sc
    k, v, positions = k[:, -sc:], v[:, -sc:], positions[:, -sc:]
    slots = positions % sc
    bidx = jnp.arange(k.shape[0])[:, None]
    ck = cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype))
    cp = cache["pos"].at[bidx, slots].set(positions.astype(jnp.int32))
    return {"k": ck, "v": cv, "pos": cp}


def cache_write_chunk(cache, k, v, positions):
    """Write a prefill chunk's K/V [B,C,...] at absolute ``positions``
    [B,C] into an existing cache. Entries with position -1 (chunk padding
    or rows not participating in this chunk call) are left untouched, so
    the same call can extend some rows' prompts while other rows hold live
    decode state."""
    sc = cache["k"].shape[1]
    valid = positions >= 0
    # invalid entries scatter out of bounds and are dropped, so they can
    # never collide with a real write targeting the same slot
    slots = jnp.where(valid, positions % sc, sc)
    bidx = jnp.arange(k.shape[0])[:, None]
    ck = cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype),
                                        mode="drop")
    cv = cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype),
                                        mode="drop")
    cp = cache["pos"].at[bidx, slots].set(positions.astype(jnp.int32),
                                          mode="drop")
    return {"k": ck, "v": cv, "pos": cp}


def cache_write_token(cache, k1, v1, pos, window: int = 0):
    """Write one token's K/V [B,1,...] at absolute position pos [B].
    Rows with pos < 0 (slots not decoding this step — empty, or still
    mid-chunked-prefill) scatter out of bounds and are dropped, so a
    shared decode step never scribbles into a slot it does not own."""
    sc = cache["k"].shape[1]
    slot = (pos % sc) if window else jnp.minimum(pos, sc - 1)
    slot = jnp.where(pos >= 0, slot, sc)
    bidx = jnp.arange(k1.shape[0])
    ck = cache["k"].at[bidx, slot].set(k1[:, 0].astype(cache["k"].dtype),
                                       mode="drop")
    cv = cache["v"].at[bidx, slot].set(v1[:, 0].astype(cache["v"].dtype),
                                       mode="drop")
    cp = cache["pos"].at[bidx, slot].set(pos.astype(jnp.int32), mode="drop")
    return {"k": ck, "v": cv, "pos": cp}


# --------------------------------------------------------------------------
# paged cache (block tables over a physical page pool)
# --------------------------------------------------------------------------
#
# Paged layer cache: {"k": [P, pt, Hkv, Dh], "v": [P, pt, Hkv, Dh],
# "pos": [P, pt] int32} — P physical pages of pt tokens each — plus one
# block table ``bt`` [B, nblk] int32 shared by all layers mapping logical
# block j of slot b to a physical page. Page 0 is reserved as the null
# page: never allocated, its ``pos`` stays -1 forever, and every unmapped
# block-table entry points at it, so gathers always read a valid page and
# unmapped regions are masked exactly like an empty contiguous cache.
# With nblk * pt == Sc the gathered view reproduces the contiguous layout
# element-for-element, which is what makes the paged engine bit-identical.


def paged_view(cache, bt):
    """Gather the contiguous [B, nblk*pt, ...] view of a paged layer cache
    through the block table. Stale K/V under pos==-1 entries (recycled or
    null pages) is harmless: masked scores are the constant NEG_INF before
    any value is read, same as a zeroed contiguous cache."""
    b, nblk = bt.shape
    pt = cache["k"].shape[1]
    flat = bt.reshape(-1)
    k = cache["k"][flat].reshape(b, nblk * pt, *cache["k"].shape[2:])
    v = cache["v"][flat].reshape(b, nblk * pt, *cache["v"].shape[2:])
    pos = cache["pos"][flat].reshape(b, nblk * pt)
    return {"k": k, "v": v, "pos": pos}


def paged_write_chunk(cache, bt, k, v, positions):
    """Paged twin of cache_write_chunk: scatter chunk K/V [B,C,...] at
    absolute ``positions`` [B,C] into physical pages via the block table.
    Invalid entries (-1 padding) and entries whose block is unmapped
    (page 0 — only possible if the host failed to pre-allocate) scatter
    out of bounds and are dropped."""
    p, pt = cache["k"].shape[0], cache["k"].shape[1]
    nblk = bt.shape[1]
    valid = positions >= 0
    spos = positions % (nblk * pt)
    blk = jnp.where(valid, spos // pt, 0)
    page = jnp.take_along_axis(bt, blk, axis=1)
    page = jnp.where(valid & (page > 0), page, p)
    off = spos % pt
    ck = cache["k"].at[page, off].set(k.astype(cache["k"].dtype),
                                      mode="drop")
    cv = cache["v"].at[page, off].set(v.astype(cache["v"].dtype),
                                      mode="drop")
    cp = cache["pos"].at[page, off].set(positions.astype(jnp.int32),
                                        mode="drop")
    return {"k": ck, "v": cv, "pos": cp}


def paged_write_token(cache, bt, k1, v1, pos):
    """Paged twin of cache_write_token (full-attention layers only): write
    one token's K/V [B,1,...] at absolute position pos [B] through the
    block table. Rows with pos < 0 drop, mirroring the contiguous path."""
    p, pt = cache["k"].shape[0], cache["k"].shape[1]
    s = bt.shape[1] * pt
    spos = jnp.minimum(jnp.maximum(pos, 0), s - 1)
    blk = spos // pt
    page = bt[jnp.arange(bt.shape[0]), blk]
    page = jnp.where((pos >= 0) & (page > 0), page, p)
    off = spos % pt
    ck = cache["k"].at[page, off].set(k1[:, 0].astype(cache["k"].dtype),
                                      mode="drop")
    cv = cache["v"].at[page, off].set(v1[:, 0].astype(cache["v"].dtype),
                                      mode="drop")
    cp = cache["pos"].at[page, off].set(pos.astype(jnp.int32), mode="drop")
    return {"k": ck, "v": cv, "pos": cp}


# --------------------------------------------------------------------------
# layer-level apply
# --------------------------------------------------------------------------

def attn_full(cfg: ModelConfig, params, x, positions, *, window: int = 0,
              causal: bool = True, cache: Optional[dict] = None):
    """Train / prefill path. Returns (out [B,S,D], updated cache or None).

    Prefill (cache is not None) pins the KV block size so its accumulation
    order matches the chunked path exactly; train keeps the auto-sized
    blocks."""
    q = _project_q(cfg, params, x)
    k, v = _project_kv(cfg, params, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    from repro.kernels import ops as kops
    bk = _pick_block(k.shape[1], PREFILL_BLOCK_K) if cache is not None else 0
    out = kops.full_attention(
        q, k, v, positions, positions, window=window,
        softcap=cfg.attn_softcap, causal=causal, block_k=bk)
    out = out.reshape(*x.shape[:2], -1) @ params["wo"]
    new_cache = None
    if cache is not None:
        new_cache = cache_write_prefill(cache, k, v, positions)
    return out, new_cache


def attn_chunk(cfg: ModelConfig, params, x, cache, positions, *,
               window: int = 0):
    """Chunked-prefill path: x [B,C,D] extends each row's sequence at
    absolute ``positions`` [B,C] (-1 = chunk padding / row not in this
    chunk). The chunk's K/V are written into the cache first, then the
    chunk queries attend over the whole updated cache — causal masking by
    stored position covers both the committed prefix and the chunk itself.
    Returns (out [B,C,D], new_cache)."""
    q = _project_q(cfg, params, x)
    k, v = _project_kv(cfg, params, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = cache_write_chunk(cache, k, v, positions)
    from repro.kernels import ops as kops
    out = kops.full_attention(
        q, new_cache["k"], new_cache["v"], positions, new_cache["pos"],
        window=window, softcap=cfg.attn_softcap, causal=True,
        block_k=_pick_block(new_cache["k"].shape[1], PREFILL_BLOCK_K))
    out = out.reshape(*x.shape[:2], -1) @ params["wo"]
    return out, new_cache


def attn_chunk_paged(cfg: ModelConfig, params, x, cache, bt, positions):
    """Chunked-prefill over a paged layer cache: write the chunk's K/V
    through the block table, then attend over the gathered contiguous
    view. Same pinned KV block size as attn_chunk, so the accumulation
    order — and the float result — matches the contiguous engine exactly.
    Paged mode is full-attention only (window == 0)."""
    q = _project_q(cfg, params, x)
    k, v = _project_kv(cfg, params, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = paged_write_chunk(cache, bt, k, v, positions)
    view = paged_view(new_cache, bt)
    from repro.kernels import ops as kops
    out = kops.full_attention(
        q, view["k"], view["v"], positions, view["pos"],
        window=0, softcap=cfg.attn_softcap, causal=True,
        block_k=_pick_block(view["k"].shape[1], PREFILL_BLOCK_K))
    out = out.reshape(*x.shape[:2], -1) @ params["wo"]
    return out, new_cache


def attn_decode_paged(cfg: ModelConfig, params, x, cache, bt, pos):
    """Single-token decode over a paged layer cache. The attention itself
    gathers K/V pages through the block table (Pallas kernel on TPU, a
    gather + the contiguous reference path elsewhere), then the new
    token's K/V is written through the table."""
    b = x.shape[0]
    q = _project_q(cfg, params, x)
    k1, v1 = _project_kv(cfg, params, x)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k1 = apply_rope(k1, pos[:, None], cfg.rope_theta)
    from repro.kernels import ops as kops
    out = kops.decode_attention_paged(
        q[:, 0], cache["k"], cache["v"], cache["pos"], bt,
        k1[:, 0], v1[:, 0], pos, softcap=cfg.attn_softcap)
    out = out.reshape(b, 1, -1) @ params["wo"]
    new_cache = paged_write_token(cache, bt, k1, v1, pos)
    return out, new_cache


def attn_decode(cfg: ModelConfig, params, x, cache, pos, *, window: int = 0):
    """Single-token decode. x: [B,1,D]; pos: [B] absolute position of x.

    Attends over the cache plus the current token, then writes the token
    into the cache. Returns (out [B,1,D], new_cache).
    """
    b = x.shape[0]
    q = _project_q(cfg, params, x)                     # [B,1,H,Dh]
    k1, v1 = _project_kv(cfg, params, x)               # [B,1,Hkv,Dh]
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k1 = apply_rope(k1, pos[:, None], cfg.rope_theta)

    from repro.kernels import ops as kops
    out = kops.decode_attention(
        q[:, 0], cache["k"], cache["v"], cache["pos"],
        k1[:, 0], v1[:, 0], pos,
        window=window, softcap=cfg.attn_softcap)
    out = out.reshape(b, 1, -1) @ params["wo"]
    new_cache = cache_write_token(cache, k1, v1, pos, window=window)
    return out, new_cache


def attn_cross(cfg: ModelConfig, params, x, cross_kv):
    """Cross-attention (whisper decoder): full attention over encoder K/V."""
    b, s, _ = x.shape
    q = _project_q(cfg, params, x)
    k, v = cross_kv["k"], cross_kv["v"]
    sk = k.shape[1]
    q_pos = jnp.zeros((b, s), jnp.int32)
    k_pos = jnp.zeros((b, sk), jnp.int32)
    out = blockwise_attention(q, k, v, q_pos, k_pos, causal=False,
                              softcap=cfg.attn_softcap)
    return out.reshape(b, s, -1) @ params["wo"]


def cross_kv_init(cfg: ModelConfig, params, enc_out):
    """Precompute decoder cross-attention K/V from encoder output."""
    k, v = _project_kv(cfg, params, enc_out)
    return {"k": k, "v": v}
