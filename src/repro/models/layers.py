"""Shared low-level layers: norms, RoPE, dense MLP, embeddings.

Pure-functional: params are plain dict pytrees; every function takes
``cfg: ModelConfig`` explicitly. Initializers return float32 and are cast to
``cfg.jnp_dtype`` at the top level (keeps smoke tests exact, dry-run bf16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, scale: float = 1.0):
    std = scale / jnp.sqrt(jnp.asarray(in_dim, jnp.float32))
    return jax.random.normal(key, (in_dim, out_dim), jnp.float32) * std


def embed_init(key, vocab: int, d: int):
    return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # [head_dim/2]


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    angles = angles[..., None, :]                       # [..., S, 1, Dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# dense MLP (SwiGLU or plain)
# --------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, d_ff), "w_down": dense_init(ks[1], d_ff, d)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d, d_ff)
    return p


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp(params, x, act: str = "silu"):
    up = x @ params["w_up"]
    if "w_gate" in params:
        up = act_fn(act)(x @ params["w_gate"]) * up
    else:
        up = act_fn(act)(up)
    return up @ params["w_down"]


# --------------------------------------------------------------------------
# embeddings / unembedding with optional logit softcap (gemma2)
# --------------------------------------------------------------------------

def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def unembed(cfg: ModelConfig, params, h):
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = h @ w.T.astype(h.dtype)
    return softcap(logits, cfg.logit_softcap)


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        tree)
