"""xLSTM-350m stack builder: alternating mLSTM/sLSTM blocks per
``cfg.xlstm_pattern``, scanned over repeated units [arXiv:2405.04517].
Constant-size recurrent state per request (no KV cache)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import refe
from repro.models import xlstm as xl
from repro.models.layers import (cast_tree, embed_init, rmsnorm,
                                 rmsnorm_init, unembed)
from repro.models.transformer import ModelApi

_INIT = {"mlstm": xl.mlstm_init, "slstm": xl.slstm_init}
_FWD = {"mlstm": xl.mlstm_forward, "slstm": xl.slstm_forward}
_STEP = {"mlstm": xl.mlstm_decode_step, "slstm": xl.slstm_decode_step}
_STATE = {"mlstm": xl.mlstm_state, "slstm": xl.slstm_state}


def build_xlstm(cfg: ModelConfig, *, num_aw: int = 1, num_ew: int = 1,
                tarragon: bool = True) -> ModelApi:
    pattern = cfg.xlstm_pattern
    u = len(pattern)
    assert cfg.num_layers % u == 0
    r = cfg.num_layers // u
    dtype = cfg.jnp_dtype

    def init_params(key):
        ks = jax.random.split(key, 2)
        params = {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
            "final_norm": rmsnorm_init(cfg.d_model),
        }

        def unit_init(k):
            lk = jax.random.split(k, u)
            return tuple(
                {"ln": rmsnorm_init(cfg.d_model),
                 "cell": _INIT[pattern[i]](lk[i], cfg)}
                for i in range(u))

        params["blocks"] = jax.vmap(unit_init)(jax.random.split(ks[1], r))
        return cast_tree(params, dtype)

    def init_cache(batch: int, max_seq: int = 0):
        def one(kind):
            st = _STATE[kind](cfg, batch)
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (r,) + a.shape), st)

        return tuple(one(k) for k in pattern)

    def _run(params, x, mode, cache=None):
        track = cache is not None

        def unit_body(carry, xs):
            h = carry
            unit_params, unit_states = xs
            new_states = []
            for i, kind in enumerate(pattern):
                bp = unit_params[i]
                st = unit_states[i] if track else None
                hn = rmsnorm(bp["ln"], h, cfg.norm_eps)
                fn = _STEP[kind] if mode == "decode" else _FWD[kind]
                y, st_new = fn(cfg, bp["cell"], hn, st)
                h = h + y
                new_states.append(st_new)
            return h, (tuple(new_states) if track else None)

        body = jax.checkpoint(unit_body) if cfg.remat else unit_body
        if track:
            x, new_cache = jax.lax.scan(body, x,
                                        (params["blocks"], cache))
        else:
            x, _ = jax.lax.scan(
                lambda c, p: body(c, (p, None)), x, params["blocks"])
            new_cache = None
        return rmsnorm(params["final_norm"], x, cfg.norm_eps), new_cache

    def _embed(params, tokens):
        return params["embed"].astype(dtype)[tokens]

    def forward_train(params, batch, route_state):
        x, _ = _run(params, _embed(params, batch["tokens"]), "train")
        return unembed(cfg, params, x), jnp.zeros((), jnp.float32)

    def prefill(params, batch, route_state, max_seq: int = 0):
        b = batch["tokens"].shape[0]
        cache = init_cache(b)
        x, cache = _run(params, _embed(params, batch["tokens"]), "prefill",
                        cache=cache)
        return unembed(cfg, params, x[:, -1]), cache

    def decode(params, tokens, pos, cache, route_state, capacity=None):
        x = _embed(params, tokens[:, None])
        x, cache = _run(params, x, "decode", cache=cache)
        return unembed(cfg, params, x[:, 0]), cache

    def init_route_state():
        return refe.RouteState(
            candidates=jnp.zeros((0, 2), jnp.int32),
            ew_health=jnp.ones((num_ew,), bool),
            aw_health=jnp.ones((num_aw,), bool),
            slot_expert=jnp.zeros((0,), jnp.int32),
            slot_owner=jnp.zeros((0,), jnp.int32),
            split_slot=jnp.zeros((0,), jnp.int32))

    return ModelApi(cfg, None, num_aw, num_ew, init_params, init_cache,
                    forward_train, prefill, decode, init_route_state)
