from repro.models.registry import get_model, ModelApi  # noqa: F401
