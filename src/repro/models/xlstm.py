"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory with hidden-state recurrence) for xlstm-350m.

Per-request state is constant-size (no KV growth) — like Mamba, the
degenerate-cheap case for Tarragon's incremental checkpointing.

Exponential gating is stabilized with the max-state m (paper eq. 15-17).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, d),
        "wk": dense_init(ks[1], d, d),
        "wv": dense_init(ks[2], d, d),
        "wi": dense_init(ks[3], d, h),   # input gate (exp)
        "wf": dense_init(ks[4], d, h),   # forget gate (exp/sigmoid)
        "wo_gate": dense_init(ks[5], d, d),
        "wo": dense_init(jax.random.fold_in(key, 7), d, d),
        "norm": rmsnorm_init(dh),
    }


def mlstm_state(cfg: ModelConfig, batch: int):
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


def _mlstm_cell(state, qkvif):
    """One time step. q,k,v: [B,H,Dh]; i,f: [B,H]."""
    q, k, v, ig, fg = qkvif
    c, n, m = state["c"], state["n"], state["m"]
    m_new = jnp.maximum(fg + m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(fg + m - m_new)
    c_new = f_p[..., None, None] * c + \
        i_p[..., None, None] * (v[..., :, None] * k[..., None, :])
    n_new = f_p[..., None] * n + i_p[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)), 1.0)
    h_t = jnp.einsum("bhvd,bhd->bhv", c_new, q) / denom[..., None]
    return {"c": c_new, "n": n_new, "m": m_new}, h_t


def _mlstm_projections(cfg, params, x):
    b, s, d = x.shape
    h = cfg.num_heads
    dh = d // h
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    def heads(w):
        return (x @ w.astype(x.dtype)).reshape(b, s, h, dh).astype(jnp.float32)

    q = heads(params["wq"]) * scale
    k = heads(params["wk"]) * scale
    v = heads(params["wv"])
    ig = (x @ params["wi"].astype(x.dtype)).astype(jnp.float32)  # [B,S,H]
    fg = jax.nn.log_sigmoid(
        (x @ params["wf"].astype(x.dtype)).astype(jnp.float32))
    return q, k, v, ig, fg


def _mlstm_recurrent(q, k, v, ig, fg, st0):
    """Sequential reference: scan _mlstm_cell over time."""
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, ig, fg))
    stf, hs = jax.lax.scan(_mlstm_cell, st0, xs)
    return jnp.moveaxis(hs, 0, 1), stf                 # [B,S,H,Dh]


def _mlstm_chunked(q, k, v, ig, fg, st0, chunk: int = 64):
    """Chunkwise-parallel mLSTM (§Perf iteration 4).

    The per-step recurrence carries the [B,H,Dh,Dh] matrix memory through
    every timestep (HBM traffic ~ S * Dh^2); the chunkwise form (xLSTM
    paper App. parallel formulation + chunk boundaries) computes intra-
    chunk contributions as stabilized [T,T] attention-like matmuls and
    carries (C, n, m) once per chunk. Exact, incl. the max-stabilizer.
    """
    bsz, s, h, dh = q.shape
    t = min(chunk, s)
    while s % t:
        t //= 2
    nc = s // t

    def rs(a):  # [B,S,...] -> [B,NC,T,...]
        return a.reshape(bsz, nc, t, *a.shape[2:])

    qc, kc, vc, igc, fgc = map(rs, (q, k, v, ig, fg))
    cumf = jnp.cumsum(fgc, axis=2)                      # [B,NC,T,H]
    # intra-chunk log-weights b[t,j] = cumf_t - cumf_j + ig_j (j <= t)
    ii = jnp.arange(t)
    causal = ii[:, None] >= ii[None, :]
    blog = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + \
        igc[:, :, None, :, :]                           # [B,NC,Ti,Tj,H]
    blog = jnp.where(causal[None, None, :, :, None], blog, -jnp.inf)
    m_intra = jnp.max(blog, axis=3)                     # [B,NC,T,H]
    scores = jnp.einsum("bgihd,bgjhd->bgijh", qc, kc)   # [B,NC,Ti,Tj,H]
    # end-of-chunk carry log-weights
    b_end = cumf[:, :, -1:, :] - cumf + igc             # [B,NC,T,H]
    m_end_intra = jnp.max(b_end, axis=2)                # [B,NC,H]

    def chunk_body(carry, xs_g):
        c_in, n_in, m_in = carry
        qg, kg, vg, cumf_g, blog_g, m_intra_g, sc_g, bend_g, mendi_g = xs_g
        m_carry = m_in[:, None, :] + cumf_g             # [B,T,H]
        m_t = jnp.maximum(m_intra_g, m_carry)           # [B,T,H]
        d_mat = jnp.exp(blog_g - m_t[:, :, None, :])    # [B,Ti,Tj,H]
        w = sc_g * d_mat
        num = jnp.einsum("bijh,bjhd->bihd", w, vg)
        den = jnp.sum(w, axis=2)                        # [B,Ti,H]
        # carried-state contribution
        scale = jnp.exp(m_carry - m_t)                  # [B,T,H]
        num = num + scale[..., None] * \
            jnp.einsum("bhvd,bihd->bihv", c_in, qg)
        den = den + scale * jnp.einsum("bhd,bihd->bih", n_in, qg)
        # stabilized-form clamp: matches max(|n~.q|, 1) of _mlstm_cell
        h_t = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # chunk-end state update
        m_carry_end = m_in + cumf_g[:, -1]              # [B,H]
        m_out = jnp.maximum(m_carry_end, mendi_g)
        w_end = jnp.exp(bend_g - m_out[:, None, :])     # [B,T,H]
        c_out = jnp.exp(m_carry_end - m_out)[..., None, None] * c_in + \
            jnp.einsum("bjh,bjhv,bjhd->bhvd", w_end, vg, kg)
        n_out = jnp.exp(m_carry_end - m_out)[..., None] * n_in + \
            jnp.einsum("bjh,bjhd->bhd", w_end, kg)
        return (c_out, n_out, m_out), h_t

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in
               (qc, kc, vc, cumf, blog, m_intra, scores, b_end,
                m_end_intra))
    (cf, nf, mf), hs = jax.lax.scan(
        chunk_body, (st0["c"], st0["n"], st0["m"]), xs)
    hseq = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, h, dh)
    return hseq, {"c": cf, "n": nf, "m": mf}


def mlstm_forward(cfg: ModelConfig, params, x, state=None, chunk: int = 64):
    """x: [B,S,D] -> (y, final state). Chunkwise-parallel for sequences,
    recurrent for single steps."""
    b, s, d = x.shape
    q, k, v, ig, fg = _mlstm_projections(cfg, params, x)
    st0 = state if state is not None else mlstm_state(cfg, b)
    if s > 1:
        hseq, stf = _mlstm_chunked(q, k, v, ig, fg, st0, chunk=chunk)
    else:
        hseq, stf = _mlstm_recurrent(q, k, v, ig, fg, st0)
    hseq = rmsnorm(params["norm"], hseq, cfg.norm_eps).astype(x.dtype)
    hseq = hseq.reshape(b, s, d)
    gate = jax.nn.silu(x @ params["wo_gate"].astype(x.dtype))
    out = (hseq * gate) @ params["wo"].astype(x.dtype)
    return out, stf


def mlstm_decode_step(cfg: ModelConfig, params, x, state):
    y, stf = mlstm_forward(cfg, params, x, state)
    return y, stf


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    p = {}
    for name, kk in zip(("i", "f", "z", "o"), ks[:4]):
        p[f"w{name}"] = dense_init(kk, d, d)
        p[f"r{name}"] = dense_init(ks[4 + "ifzo".index(name)], d, d, 0.5)
    p["wo"] = dense_init(ks[8], d, d)
    p["norm"] = rmsnorm_init(d)
    return p


def slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}


def _slstm_cell(params, state, xt):
    """xt: [B,D] float32."""
    hp = state["h"]

    def gate(name):
        return xt @ params[f"w{name}"] + hp @ params[f"r{name}"]

    ig, fg = gate("i"), jax.nn.log_sigmoid(gate("f"))
    zt = jnp.tanh(gate("z"))
    ot = jax.nn.sigmoid(gate("o"))
    m_new = jnp.maximum(fg + state["m"], ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(fg + state["m"] - m_new)
    c_new = f_p * state["c"] + i_p * zt
    n_new = f_p * state["n"] + i_p
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_forward(cfg: ModelConfig, params, x, state=None):
    b, s, d = x.shape
    st0 = state if state is not None else slstm_state(cfg, b)
    p32 = {k: v.astype(jnp.float32) if hasattr(v, "astype") else v
           for k, v in params.items() if k != "norm"}
    p32["norm"] = params["norm"]

    def step(st, xt):
        st = _slstm_cell(p32, st, xt)
        return st, st["h"]

    stf, hs = jax.lax.scan(step, st0,
                           jnp.moveaxis(x.astype(jnp.float32), 1, 0))
    hseq = jnp.moveaxis(hs, 0, 1)
    hseq = rmsnorm(params["norm"], hseq, cfg.norm_eps).astype(x.dtype)
    out = hseq @ params["wo"].astype(x.dtype)
    return out, stf


def slstm_decode_step(cfg: ModelConfig, params, x, state):
    y, stf = slstm_forward(cfg, params, x, state)
    return y, stf
