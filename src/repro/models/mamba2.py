"""Mamba2 (SSD) block — the recurrent substrate for zamba2-7b.

Per-request state is a compact (h [B,H,P,N], conv [B,W-1,Di]) pair rather
than a growing KV cache — the favourable case for Tarragon's checkpointing
(DESIGN.md §4): an incremental "segment" is one state snapshot of fixed size.

Full-sequence path uses the chunked SSD scan (kernels/ssm_scan.py on TPU,
sequential ref on CPU); decode is a single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm.head_dim
    return d_inner, n_heads


def mamba_init(key, cfg: ModelConfig):
    d = cfg.d_model
    n = cfg.ssm.state_dim
    di, nh = mamba_dims(cfg)
    ks = jax.random.split(key, 4)
    # fused in_proj -> [z, x, B, C, dt]
    proj_out = 2 * di + 2 * n + nh
    p = {
        "in_proj": dense_init(ks[0], d, proj_out),
        "out_proj": dense_init(ks[1], di, d),
        "conv_w": jax.random.normal(ks[2], (cfg.ssm.conv_width, di),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": rmsnorm_init(di),
    }
    return p


def init_state(cfg: ModelConfig, batch: int, dtype=None):
    n = cfg.ssm.state_dim
    di, nh = mamba_dims(cfg)
    w = cfg.ssm.conv_width
    dt = dtype or cfg.jnp_dtype
    return {
        "h": jnp.zeros((batch, nh, cfg.ssm.head_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, w - 1, di), dt),
    }


def _split_proj(cfg, proj):
    di, nh = mamba_dims(cfg)
    n = cfg.ssm.state_dim
    z, xin, b, c, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xin, b, c, dt


def _causal_conv(params, xin, conv_state=None):
    """Depthwise causal conv over time. xin: [B,S,Di]."""
    w = params["conv_w"]                        # [W, Di]
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xin.shape[0], width - 1, xin.shape[-1]), xin.dtype)
    else:
        pad = conv_state.astype(xin.dtype)
    xp = jnp.concatenate([pad, xin], axis=1)    # [B, S+W-1, Di]
    out = sum(xp[:, i:i + xin.shape[1]] * w[i].astype(xin.dtype)
              for i in range(width))
    out = out + params["conv_b"].astype(xin.dtype)
    new_state = xp[:, -(width - 1):]
    return jax.nn.silu(out), new_state


def mamba_forward(cfg: ModelConfig, params, x, state=None):
    """Full-sequence SSD. x: [B,S,D] -> (y [B,S,D], new_state or None).

    Note: the chunked kernel assumes zero initial state (train/prefill from
    scratch); a non-zero carried state is only used in decode.
    """
    bsz, s, _ = x.shape
    di, nh = mamba_dims(cfg)
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xin, b, c, dt_raw = _split_proj(cfg, proj)
    conv_state = state["conv"] if state is not None else None
    xin, new_conv = _causal_conv(params, xin, conv_state)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"])                   # [B,S,H]
    a = -jnp.exp(params["a_log"])                             # [H]
    xh = xin.reshape(bsz, s, nh, cfg.ssm.head_dim)
    y, hf = kops.ssm_scan(xh, dt, a, b.astype(jnp.float32),
                          c.astype(jnp.float32), chunk=cfg.ssm.chunk)
    y = y + xh * params["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, s, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(y.dtype)
    new_state = {"h": hf, "conv": new_conv} if state is not None else None
    return out, new_state


def mamba_decode_step(cfg: ModelConfig, params, x, state):
    """Single-token recurrence. x: [B,1,D] -> (y [B,1,D], new_state)."""
    bsz = x.shape[0]
    di, nh = mamba_dims(cfg)
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xin, b, c, dt_raw = _split_proj(cfg, proj)
    xin, new_conv = _causal_conv(params, xin, state["conv"])

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                         params["dt_bias"])                   # [B,H]
    a = -jnp.exp(params["a_log"])
    xh = xin.reshape(bsz, nh, cfg.ssm.head_dim).astype(jnp.float32)
    decay = jnp.exp(dt * a)                                   # [B,H]
    dbx = jnp.einsum("bh,bhp,bn->bhpn", dt, xh,
                     b[:, 0].astype(jnp.float32))
    h = state["h"] * decay[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", h, c[:, 0].astype(jnp.float32))
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(y.dtype)
    return out, {"h": h, "conv": new_conv}
