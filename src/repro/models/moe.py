"""Sparse MoE layer wired through the Tarragon REFE datapath.

Covers the assigned MoE architectures:
  * qwen2-moe-a2.7b — 60 routed top-4 + 4 shared experts
  * kimi-k2-1t-a32b — 384 routed top-8 + 1 shared expert
and the paper's own Mixtral-8x7B (8 routed top-2).

Two routing modes:
  * tarragon=True  — ERT/slot-space routing with shadow slots and health
    masks (the paper's system).
  * tarragon=False — static expert->EW binding (MegaScale-Infer baseline):
    no shadow slots, no ERT indirection.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import ert as ert_lib
from repro.core import refe
from repro.core import shadow as shadow_lib
from repro.kernels import ops as kops
from repro.models.layers import dense_init, mlp, mlp_init


def moe_placement(cfg: ModelConfig, num_ew: int,
                  tarragon: bool = True) -> ert_lib.ExpertPlacement:
    n_shadow = cfg.moe.num_shadow_slots if tarragon else 0
    return ert_lib.default_placement(cfg.moe.num_experts, num_ew, n_shadow)


def moe_init(key, cfg: ModelConfig, placement: ert_lib.ExpertPlacement):
    """One MoE layer's params.

    The stored bank holds one row per *logical* expert, padded to
    ``placement.primary_slots`` (a multiple of num_ew) so the expert axis
    always divides the EW mesh axis — e.g. Qwen's 60 experts are stored as
    64 rows on 16 EWs. The physical slot bank (primaries, shadows, and any
    replicas a placement plan creates) is gathered from these rows through
    ``RouteState.slot_expert`` at apply time, so there is no separate
    shadow bank to keep in sync with the placement."""
    e, d, f = cfg.moe.num_experts, cfg.d_model, cfg.moe.d_ff
    e_store = placement.primary_slots
    ks = jax.random.split(key, 5)
    std = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    experts = {
        "wg": jax.random.normal(ks[0], (e_store, d, f), jnp.float32) * std,
        "wu": jax.random.normal(ks[1], (e_store, d, f), jnp.float32) * std,
        "wd": jax.random.normal(ks[2], (e_store, f, d), jnp.float32) *
        (1.0 / jnp.sqrt(jnp.asarray(f, jnp.float32))),
    }
    p = {"router": dense_init(ks[3], d, e), "experts": experts}
    if cfg.moe.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.moe.shared_d_ff, gated=True)
    return p


def moe_apply(cfg: ModelConfig, params, x, route_state: refe.RouteState,
              placement: ert_lib.ExpertPlacement,
              capacity: Optional[int] = None, token_mask=None):
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar, slot_load [P]).

    The flattened [T, D] token batch is what flows over the AW->EW datapath;
    B is data-parallel over AWs, the slot dim over EWs. ``token_mask``
    ([B, S] bool, optional) flags real tokens; pads are excluded from
    expert-capacity competition (pad-free dispatch). ``slot_load`` is the
    device-side dispatch counter the placement manager's EMA drains.
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = xt @ params["router"].astype(xt.dtype)

    routing = refe.route(
        xt, logits, route_state, placement,
        top_k=cfg.moe.top_k, capacity_factor=cfg.moe.capacity_factor,
        capacity=capacity, batch=b,
        token_mask=None if token_mask is None
        else token_mask.reshape(b * s))

    # physical slot bank, gathered through the plan's slot indirection: any
    # slot (primary, shadow, replica) serves its resident expert's rows —
    # a placement change re-points this without touching the trace
    bank = shadow_lib.resident_slot_bank(params["experts"],
                                         route_state.slot_expert)

    def expert_fn(expert_in):
        return kops.expert_ffn(expert_in, bank["wg"].astype(x.dtype),
                               bank["wu"].astype(x.dtype),
                               bank["wd"].astype(x.dtype), act=cfg.act)

    y = refe.expert_io(xt, routing, expert_fn)

    if "shared" in params:
        y = y + mlp(params["shared"], xt, cfg.act)

    return y.reshape(b, s, d), routing["aux_loss"], routing["slot_load"]
