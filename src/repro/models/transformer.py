"""Generic decoder stack builder covering the dense / MoE / VLM families
(qwen2, qwen2-moe, h2o-danube, chameleon, gemma2, granite, kimi-k2, mixtral).

Layers are stacked with ``lax.scan`` over repeated *units* (one unit =
``len(cfg.attn_pattern)`` layers, e.g. gemma2's (local, global) pair) so HLO
size and compile time stay flat for 26-88 layer configs. MoE layers route
through the Tarragon REFE datapath (models/moe.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import ert as ert_lib
from repro.core import refe
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (cast_tree, embed_init, mlp, mlp_init,
                                 rmsnorm, rmsnorm_init, unembed)


class ModelApi(NamedTuple):
    cfg: ModelConfig
    placement: Optional[ert_lib.ExpertPlacement]
    num_aw: int
    num_ew: int
    init_params: Callable[..., Any]
    init_cache: Callable[..., Any]
    forward_train: Callable[..., Any]   # (params, batch, rs) -> (logits, aux)
    prefill: Callable[..., Any]         # -> (last_logits, cache)
    decode: Callable[..., Any]          # -> (logits, cache)
    init_route_state: Callable[..., refe.RouteState]
    # chunked prefill: (params, tokens [B,C], positions [B,C], caches, rs)
    # -> caches. None for families without a resumable prefill path
    # (recurrent state / ring buffers / enc-dec).
    prefill_chunk: Optional[Callable[..., Any]] = None
    # True when prefill/decode/prefill_chunk accept a static ``with_load``
    # flag appending the accumulated per-slot dispatch-load counter [P] to
    # their returns (the placement manager's telemetry).
    reports_load: bool = False
    # True when ``decode`` may be scanned into multi-token device segments
    # (serving/decode_loop.py): requires a pure positional cache (pos -1
    # rows drop their writes) so a row finishing mid-segment is a no-op.
    # Recurrent-state families keep per-step dispatch.
    supports_decode_segments: bool = False


# --------------------------------------------------------------------------
# unit geometry
# --------------------------------------------------------------------------

def _unit_windows(cfg: ModelConfig):
    """Sliding window per unit position (0 = full attention)."""
    wins = []
    for kind in cfg.attn_pattern:
        if kind == "global":
            wins.append(0)
        elif kind == "local":
            wins.append(cfg.sliding_window)
        else:  # "layer"
            wins.append(cfg.sliding_window)
    return tuple(wins)


def _num_units(cfg: ModelConfig):
    u = len(cfg.attn_pattern)
    n_moe_first = cfg.moe.first_k_dense if cfg.moe.enabled else 0
    scan_layers = cfg.num_layers - n_moe_first
    assert scan_layers % u == 0, (
        f"{cfg.name}: {scan_layers} scanned layers not divisible by "
        f"pattern {cfg.attn_pattern}")
    return scan_layers // u


# --------------------------------------------------------------------------
# single layer (attn + ffn) init / apply
# --------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, use_moe: bool, placement):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": rmsnorm_init(cfg.d_model),
        "attn": attn.attn_init(ks[0], cfg),
        "ln2": rmsnorm_init(cfg.d_model),
    }
    if use_moe:
        p["moe"] = moe_mod.moe_init(ks[1], cfg, placement)
    else:
        d_ff = cfg.d_ff or cfg.moe.d_ff
        p["mlp"] = mlp_init(ks[1], cfg.d_model, d_ff, cfg.mlp_gated)
    return p


def _layer_apply(cfg: ModelConfig, p, x, *, window: int, mode: str,
                 positions=None, pos=None, cache=None, route_state=None,
                 placement=None, capacity=None, token_mask=None, bt=None):
    """mode: 'train' | 'prefill' | 'chunk' | 'decode'. ``bt`` is the
    [B, nblk] block table of a paged cache (None = contiguous layout);
    when set, ``cache`` holds physical page pools instead of per-slot
    rows and the paged attention twins are used."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if mode == "decode" and bt is not None:
        a, new_cache = attn.attn_decode_paged(cfg, p["attn"], h, cache, bt,
                                              pos)
    elif mode == "decode":
        a, new_cache = attn.attn_decode(cfg, p["attn"], h, cache, pos,
                                        window=window)
    elif mode == "chunk" and bt is not None:
        a, new_cache = attn.attn_chunk_paged(cfg, p["attn"], h, cache, bt,
                                             positions)
    elif mode == "chunk":
        a, new_cache = attn.attn_chunk(cfg, p["attn"], h, cache, positions,
                                       window=window)
    else:
        a, new_cache = attn.attn_full(cfg, p["attn"], h, positions,
                                      window=window, cache=cache)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    n_slots = placement.num_slots if placement is not None else 0
    load = jnp.zeros((n_slots,), jnp.float32)
    if "moe" in p:
        f, aux, load = moe_mod.moe_apply(cfg, p["moe"], h, route_state,
                                         placement, capacity=capacity,
                                         token_mask=token_mask)
    else:
        f = mlp(p["mlp"], h, cfg.act)
    return x + f, new_cache, aux, load


# --------------------------------------------------------------------------
# builder
# --------------------------------------------------------------------------

def build_decoder(cfg: ModelConfig, *, num_aw: int = 1, num_ew: int = 1,
                  tarragon: bool = True) -> ModelApi:
    windows = _unit_windows(cfg)
    u = len(windows)
    r = _num_units(cfg)
    n_first = cfg.moe.first_k_dense if cfg.moe.enabled else 0
    placement = (moe_mod.moe_placement(cfg, num_ew, tarragon)
                 if cfg.moe.enabled else None)
    dtype = cfg.jnp_dtype

    # ---- init ------------------------------------------------------------
    def init_params(key):
        keys = jax.random.split(key, 3 + n_first)
        params = {
            "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(keys[1], cfg.vocab_size,
                                           cfg.d_model)
        for i in range(n_first):
            params[f"dense{i}"] = _layer_init(keys[2 + i], cfg, False,
                                              placement)
        unit_keys = jax.random.split(keys[-1], r)

        def unit_init(k):
            lk = jax.random.split(k, u)
            return tuple(
                _layer_init(lk[i], cfg, cfg.moe.enabled, placement)
                for i in range(u))

        params["blocks"] = jax.vmap(unit_init)(unit_keys)
        return cast_tree(params, dtype)

    # ---- caches ------------------------------------------------------------
    def init_cache(batch: int, max_seq: int):
        caches = {}
        for i in range(n_first):
            caches[f"dense{i}"] = attn.init_cache(cfg, batch, max_seq,
                                                  window=windows[0])

        def one(win):
            c = attn.init_cache(cfg, batch, max_seq, window=win)
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (r,) + a.shape), c)

        caches["blocks"] = tuple(one(w) for w in windows)
        return caches

    # ---- forward ------------------------------------------------------------
    def _embed(params, tokens):
        return params["embed"].astype(dtype)[tokens]

    n_slots = placement.num_slots if placement is not None else 0

    def _run_stack(params, x, mode, positions=None, pos=None, caches=None,
                   route_state=None, capacity=None, token_mask=None):
        aux_total = jnp.zeros((), jnp.float32)
        load_total = jnp.zeros((n_slots,), jnp.float32)
        new_caches = {} if caches is not None else None
        # paged engines carry one block table at the top of the cache dict;
        # it is threaded to every attention layer and returned unchanged.
        # The branch is python-level: an engine is paged or contiguous for
        # life, so each jitted entry point still traces exactly once.
        bt = caches.get("bt") if caches is not None else None
        for i in range(n_first):
            c = caches[f"dense{i}"] if caches is not None else None
            x, nc, aux, load = _layer_apply(
                cfg, params[f"dense{i}"], x, window=windows[0], mode=mode,
                positions=positions, pos=pos, cache=c,
                route_state=route_state, placement=placement,
                capacity=capacity, token_mask=token_mask, bt=bt)
            aux_total += aux
            load_total += load
            if caches is not None:
                new_caches[f"dense{i}"] = nc

        def unit_body(carry, xs):
            h, auxc, loadc = carry
            unit_params, unit_caches = xs
            ncs = []
            for i in range(u):
                c = unit_caches[i] if unit_caches is not None else None
                h, nc, aux, load = _layer_apply(
                    cfg, unit_params[i], h, window=windows[i], mode=mode,
                    positions=positions, pos=pos, cache=c,
                    route_state=route_state, placement=placement,
                    capacity=capacity, token_mask=token_mask, bt=bt)
                auxc += aux
                loadc += load
                ncs.append(nc)
            ncs = tuple(ncs) if caches is not None else None
            return (h, auxc, loadc), ncs

        body = jax.checkpoint(unit_body) if cfg.remat else unit_body
        if caches is None:
            (x, aux_total, load_total), _ = jax.lax.scan(
                lambda c, p: body(c, (p, None)), (x, aux_total, load_total),
                params["blocks"])
        else:
            (x, aux_total, load_total), nb = jax.lax.scan(
                unit_body, (x, aux_total, load_total),
                (params["blocks"], caches["blocks"]))
            new_caches["blocks"] = nb
            if bt is not None:
                new_caches["bt"] = bt
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, new_caches, aux_total, load_total

    def forward_train(params, batch, route_state):
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = _embed(params, tokens)
        x, _, aux, _ = _run_stack(params, x, "train", positions=positions,
                                  route_state=route_state)
        return unembed(cfg, params, x), aux

    def prefill(params, batch, route_state, max_seq: int, capacity=None,
                with_load: bool = False):
        """batch may carry a ``mask`` ([B, S] bool) flagging real tokens;
        pads then never compete for expert capacity (pad-free dispatch).
        ``with_load`` (static) appends the summed per-slot dispatch-load
        counter to the returns (placement-manager telemetry)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        caches = init_cache(b, max_seq)
        x = _embed(params, tokens)
        x, caches, _, load = _run_stack(
            params, x, "prefill", positions=positions, caches=caches,
            route_state=route_state, capacity=capacity,
            token_mask=batch.get("mask"))
        logits = unembed(cfg, params, x[:, -1])
        return (logits, caches, load) if with_load else (logits, caches)

    def prefill_chunk(params, tokens, positions, caches, route_state,
                      capacity=None, with_load: bool = False):
        """One budgeted prefill chunk over the shared slot-partitioned
        cache. tokens: [B, C] int32; positions: [B, C] absolute prompt
        positions (-1 = chunk padding or a row not in this chunk call —
        such rows, including live decode slots, are untouched). Returns
        the updated caches; logits are not needed mid-prompt (the first
        generated token rides the decode step, like the padded scheme)."""
        x = _embed(params, tokens)
        mask = positions >= 0
        x, caches, _, load = _run_stack(
            params, x, "chunk", positions=positions, caches=caches,
            route_state=route_state, capacity=capacity, token_mask=mask)
        return (caches, load) if with_load else caches

    def decode(params, tokens, pos, caches, route_state, capacity=None,
               with_load: bool = False):
        """tokens: [B] int32; pos: [B] absolute positions. Rows not decoding
        this step carry pos -1: they are masked out of expert-capacity
        competition (and out of the dispatch-load telemetry) exactly like
        prefill pads."""
        x = _embed(params, tokens[:, None])
        x, caches, _, load = _run_stack(params, x, "decode", pos=pos,
                                        caches=caches,
                                        route_state=route_state,
                                        capacity=capacity,
                                        token_mask=(pos >= 0)[:, None])
        logits = unembed(cfg, params, x[:, 0])
        return (logits, caches, load) if with_load else (logits, caches)

    def init_route_state():
        if placement is None:
            return refe.RouteState(
                candidates=jnp.zeros((0, 2), jnp.int32),
                ew_health=jnp.ones((num_ew,), bool),
                aw_health=jnp.ones((num_aw,), bool),
                slot_expert=jnp.zeros((0,), jnp.int32),
                slot_owner=jnp.zeros((0,), jnp.int32),
                split_slot=jnp.zeros((0,), jnp.int32))
        return refe.RouteState.healthy(placement, num_aw)

    return ModelApi(cfg, placement, num_aw, num_ew, init_params, init_cache,
                    forward_train, prefill, decode, init_route_state,
                    prefill_chunk=prefill_chunk, reports_load=True,
                    supports_decode_segments=True)
