"""Zamba2-style hybrid stack: Mamba2 blocks with a *shared* transformer
(attention+MLP) block applied every ``hybrid_attn_every`` Mamba blocks
[arXiv:2411.15242].

The shared block's weights are closed over (not stacked) — the defining
Zamba2 trick — but each occurrence keeps its own KV cache. Scan runs over
super-units of (``every`` mamba blocks + 1 shared-attn application); trailing
mamba blocks that don't fill a unit are scanned separately.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import refe
from repro.models import attention as attn
from repro.models import mamba2
from repro.models.layers import (cast_tree, embed_init, mlp, mlp_init,
                                 rmsnorm, rmsnorm_init, unembed)
from repro.models.transformer import ModelApi


def _geometry(cfg: ModelConfig):
    every = cfg.hybrid_attn_every
    r = cfg.num_layers // every
    trailing = cfg.num_layers - r * every
    return every, r, trailing


def _mamba_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln": rmsnorm_init(cfg.d_model), "mamba": mamba2.mamba_init(k2, cfg)}


def build_hybrid(cfg: ModelConfig, *, num_aw: int = 1, num_ew: int = 1,
                 tarragon: bool = True) -> ModelApi:
    every, r, trailing = _geometry(cfg)
    dtype = cfg.jnp_dtype
    window = cfg.sliding_window  # 0 except the long_500k variant

    def init_params(key):
        ks = jax.random.split(key, 6)
        params = {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
            "final_norm": rmsnorm_init(cfg.d_model),
            "shared": {
                "ln1": rmsnorm_init(cfg.d_model),
                "attn": attn.attn_init(ks[1], cfg),
                "ln2": rmsnorm_init(cfg.d_model),
                "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_gated),
            },
        }

        def unit_init(k):
            return jax.vmap(lambda kk: _mamba_block_init(kk, cfg))(
                jax.random.split(k, every))

        params["units"] = jax.vmap(unit_init)(jax.random.split(ks[3], r))
        if trailing:
            params["trailing"] = jax.vmap(
                lambda kk: _mamba_block_init(kk, cfg))(
                jax.random.split(ks[4], trailing))
        return cast_tree(params, dtype)

    def init_cache(batch: int, max_seq: int):
        kv = attn.init_cache(cfg, batch, max_seq, window=window)
        kv = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (r,) + a.shape), kv)
        st = mamba2.init_state(cfg, batch, dtype)
        units = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (r, every) + a.shape), st)
        cache = {"kv": kv, "units": units}
        if trailing:
            cache["trailing"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (trailing,) + a.shape), st)
        return cache

    def _shared_attn(params, x, mode, positions, pos, kv):
        p = params["shared"]
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        if mode == "decode":
            a, kv = attn.attn_decode(cfg, p["attn"], h, kv, pos,
                                     window=window)
        else:
            a, kv = attn.attn_full(cfg, p["attn"], h, positions,
                                   window=window, cache=kv)
        x = x + a
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        return x + mlp(p["mlp"], h, cfg.act), kv

    def _mamba_apply(bp, x, st, mode):
        h = rmsnorm(bp["ln"], x, cfg.norm_eps)
        if mode == "decode":
            y, st = mamba2.mamba_decode_step(cfg, bp["mamba"], h, st)
        else:
            y, st = mamba2.mamba_forward(cfg, bp["mamba"], h, st)
        return x + y, st

    def _run(params, x, mode, positions=None, pos=None, cache=None):
        track = cache is not None

        def unit_body(carry, xs):
            h = carry
            unit_params, unit_cache = xs
            states = unit_cache["states"] if track else None

            def mamba_body(hc, mxs):
                bp, st = mxs
                hc, st_new = _mamba_apply(bp, hc, st, mode)
                return hc, st_new

            if track:
                h, new_states = jax.lax.scan(
                    mamba_body, h, (unit_params, states))
            else:
                h, _ = jax.lax.scan(
                    lambda hc, bp: mamba_body(hc, (bp, None)), h,
                    unit_params)
                new_states = None
            kv = unit_cache["kv"] if track else None
            h, kv_new = _shared_attn(params, h, mode, positions, pos, kv)
            ys = {"states": new_states, "kv": kv_new} if track else None
            return h, ys

        body = jax.checkpoint(unit_body) if cfg.remat else unit_body
        if track:
            xs = (params["units"],
                  {"states": cache["units"], "kv": cache["kv"]})
            x, ys = jax.lax.scan(body, x, xs)
            new_cache = {"units": ys["states"], "kv": ys["kv"]}
        else:
            x, _ = jax.lax.scan(
                lambda c, p: body(c, (p, {})), x, params["units"])
            new_cache = None

        if trailing:
            def tbody(hc, txs):
                if track:
                    bp, st = txs
                else:
                    bp, st = txs, None
                hc, st_new = _mamba_apply(bp, hc, st, mode)
                return hc, st_new

            if track:
                x, new_tr = jax.lax.scan(
                    tbody, x, (params["trailing"], cache["trailing"]))
                new_cache["trailing"] = new_tr
            else:
                x, _ = jax.lax.scan(tbody, x, params["trailing"])

        return rmsnorm(params["final_norm"], x, cfg.norm_eps), new_cache

    def _embed(params, tokens):
        return params["embed"].astype(dtype)[tokens]

    def forward_train(params, batch, route_state):
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, _ = _run(params, _embed(params, tokens), "train",
                    positions=positions)
        return unembed(cfg, params, x), jnp.zeros((), jnp.float32)

    def prefill(params, batch, route_state, max_seq: int):
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        cache = init_cache(b, max_seq)
        x, cache = _run(params, _embed(params, tokens), "prefill",
                        positions=positions, cache=cache)
        return unembed(cfg, params, x[:, -1]), cache

    def decode(params, tokens, pos, cache, route_state, capacity=None):
        x = _embed(params, tokens[:, None])
        x, cache = _run(params, x, "decode", pos=pos, cache=cache)
        return unembed(cfg, params, x[:, 0]), cache

    def init_route_state():
        return refe.RouteState(
            candidates=jnp.zeros((0, 2), jnp.int32),
            ew_health=jnp.ones((num_ew,), bool),
            aw_health=jnp.ones((num_aw,), bool),
            slot_expert=jnp.zeros((0,), jnp.int32),
            slot_owner=jnp.zeros((0,), jnp.int32),
            split_slot=jnp.zeros((0,), jnp.int32))

    return ModelApi(cfg, None, num_aw, num_ew, init_params, init_cache,
                    forward_train, prefill, decode, init_route_state)
