"""Whisper-small encoder-decoder backbone [arXiv:2212.04356].

Per the brief's carve-out, the mel-spectrogram + conv frontend is a STUB:
``batch["frames"]`` carries precomputed frame embeddings [B, T_enc, D].
The encoder is stateless (Tarragon-wise it behaves like an EW: pure replay);
the decoder holds self-attention KV plus cross-attention KV computed once at
prefill — both are covered by per-request restoration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import refe
from repro.models import attention as attn
from repro.models.layers import (cast_tree, embed_init, mlp, mlp_init,
                                 rmsnorm, rmsnorm_init, unembed)
from repro.models.transformer import ModelApi


def build_encdec(cfg: ModelConfig, *, num_aw: int = 1, num_ew: int = 1,
                 tarragon: bool = True) -> ModelApi:
    dtype = cfg.jnp_dtype
    r_enc, r_dec = cfg.encoder_layers, cfg.num_layers

    def _enc_layer_init(key):
        ks = jax.random.split(key, 2)
        return {
            "ln1": rmsnorm_init(cfg.d_model),
            "attn": attn.attn_init(ks[0], cfg),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated),
        }

    def _dec_layer_init(key):
        ks = jax.random.split(key, 3)
        return {
            "ln1": rmsnorm_init(cfg.d_model),
            "self_attn": attn.attn_init(ks[0], cfg),
            "ln_x": rmsnorm_init(cfg.d_model),
            "cross_attn": attn.attn_init(ks[1], cfg, cross=True),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_gated),
        }

    def init_params(key):
        ks = jax.random.split(key, 4)
        params = {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
            "final_norm": rmsnorm_init(cfg.d_model),
            "enc_final_norm": rmsnorm_init(cfg.d_model),
            "enc": jax.vmap(_enc_layer_init)(jax.random.split(ks[1], r_enc)),
            "dec": jax.vmap(_dec_layer_init)(jax.random.split(ks[2], r_dec)),
        }
        return cast_tree(params, dtype)

    # ---- encoder -----------------------------------------------------------
    def encode(params, frames):
        b, t, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

        def body(h, lp):
            a, _ = attn.attn_full(cfg, lp["attn"],
                                  rmsnorm(lp["ln1"], h, cfg.norm_eps),
                                  positions, causal=False)
            h = h + a
            h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps),
                        cfg.act)
            return h, None

        body = jax.checkpoint(body) if cfg.remat else body
        h, _ = jax.lax.scan(body, frames.astype(dtype), params["enc"])
        return rmsnorm(params["enc_final_norm"], h, cfg.norm_eps)

    # ---- decoder -----------------------------------------------------------
    def init_cache(batch: int, max_seq: int):
        kv = attn.init_cache(cfg, batch, max_seq)
        kv = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (r_dec,) + a.shape), kv)
        t_enc = cfg.encoder_seq
        cross = {
            "k": jnp.zeros((r_dec, batch, t_enc, cfg.num_kv_heads,
                            cfg.head_dim_), dtype),
            "v": jnp.zeros((r_dec, batch, t_enc, cfg.num_kv_heads,
                            cfg.head_dim_), dtype),
        }
        return {"kv": kv, "cross": cross}

    def _dec_layer(lp, h, mode, positions, pos, kv, cross_kv):
        a, kv = (attn.attn_decode(cfg, lp["self_attn"],
                                  rmsnorm(lp["ln1"], h, cfg.norm_eps),
                                  kv, pos)
                 if mode == "decode" else
                 attn.attn_full(cfg, lp["self_attn"],
                                rmsnorm(lp["ln1"], h, cfg.norm_eps),
                                positions, cache=kv))
        h = h + a
        c = attn.attn_cross(cfg, lp["cross_attn"],
                            rmsnorm(lp["ln_x"], h, cfg.norm_eps), cross_kv)
        h = h + c
        h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps), cfg.act)
        return h, kv

    def _run_decoder(params, x, mode, positions=None, pos=None, cache=None):
        def body(h, xs):
            lp, kv, cross = xs
            h, kv_new = _dec_layer(lp, h, mode, positions, pos, kv, cross)
            return h, kv_new

        body = jax.checkpoint(body) if cfg.remat else body
        x, new_kv = jax.lax.scan(
            body, x, (params["dec"], cache["kv"], cache["cross"]))
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, {"kv": new_kv, "cross": cache["cross"]}

    def _embed(params, tokens):
        return params["embed"].astype(dtype)[tokens]

    def _fill_cross(params, cache, enc_out):
        def body(_, lp):
            ckv = attn.cross_kv_init(cfg, lp["cross_attn"], enc_out)
            return None, ckv

        _, cross = jax.lax.scan(body, None, params["dec"])
        return {"kv": cache["kv"], "cross": cross}

    def forward_train(params, batch, route_state):
        tokens = batch["tokens"]
        b, s = tokens.shape
        enc_out = encode(params, batch["frames"])
        cache = init_cache(b, s)
        cache = _fill_cross(params, cache, enc_out)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, _ = _run_decoder(params, _embed(params, tokens), "train",
                            positions=positions, cache=cache)
        return unembed(cfg, params, x), jnp.zeros((), jnp.float32)

    def prefill(params, batch, route_state, max_seq: int):
        tokens = batch["tokens"]
        b, s = tokens.shape
        enc_out = encode(params, batch["frames"])
        cache = init_cache(b, max_seq)
        cache = _fill_cross(params, cache, enc_out)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, cache = _run_decoder(params, _embed(params, tokens), "prefill",
                                positions=positions, cache=cache)
        return unembed(cfg, params, x[:, -1]), cache

    def decode(params, tokens, pos, cache, route_state, capacity=None):
        x = _embed(params, tokens[:, None])
        x, cache = _run_decoder(params, x, "decode", pos=pos, cache=cache)
        return unembed(cfg, params, x[:, 0]), cache

    def init_route_state():
        return refe.RouteState(
            candidates=jnp.zeros((0, 2), jnp.int32),
            ew_health=jnp.ones((num_ew,), bool),
            aw_health=jnp.ones((num_aw,), bool),
            slot_expert=jnp.zeros((0,), jnp.int32),
            slot_owner=jnp.zeros((0,), jnp.int32),
            split_slot=jnp.zeros((0,), jnp.int32))

    return ModelApi(cfg, None, num_aw, num_ew, init_params, init_cache,
                    forward_train, prefill, decode, init_route_state)
