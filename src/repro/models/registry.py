"""Model family dispatch: config -> ModelApi."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.transformer import ModelApi, build_decoder


def get_model(cfg: ModelConfig, *, num_aw: int = 1, num_ew: int = 1,
              tarragon: bool = True) -> ModelApi:
    kw = dict(num_aw=num_aw, num_ew=num_ew, tarragon=tarragon)
    if cfg.is_encdec:
        from repro.models.whisper import build_encdec
        return build_encdec(cfg, **kw)
    if cfg.xlstm_pattern:
        from repro.models.xlstm_model import build_xlstm
        return build_xlstm(cfg, **kw)
    if cfg.ssm.enabled and cfg.hybrid_attn_every:
        from repro.models.hybrid import build_hybrid
        return build_hybrid(cfg, **kw)
    return build_decoder(cfg, **kw)
