"""Workload generation (paper §7.1).

* ``sharegpt``  — ShareGPT-like: naturally varying prompt/completion lengths
  (log-normal mixture fitted to the published ShareGPT length statistics;
  the dataset itself is not redistributable offline).
* ``random``    — the paper's synthetic decode-heavy workload: fixed
  10-token prompts, 128 generated tokens.
* ``long_prompt_burst`` — the chunked-prefill stress case: bimodal prompt
  lengths (mostly short chat turns, a long-document minority) arriving in
  Poisson *bursts*, so several long prompts can land on the same tick and
  stall co-resident decodes unless prefill is budgeted.
* ``skewed_expert_load`` — the expert-rebalancer stress case: prompt tokens
  are drawn from a Zipf distribution over the vocabulary, so a few dominant
  tokens (and therefore the experts they route to) carry most of the
  dispatch load — static expert placement concentrates that load on a few
  EWs, which is exactly what load-aware rebalancing exists to fix.
* ``mixed_slo`` — the SLO-class stress case for the multi-class admission
  plane: a Poisson stream of short *interactive* requests (tight
  first-token deadlines) over periodic bulk waves of long *batch* requests
  that saturate every slot — without preempt-and-requeue, interactive TTFT
  degenerates to the batch residency time.
* ``multi_turn_chat`` — the prefix-cache stress case: sessions of
  ``chat_turns`` requests where every turn's prompt replays the whole
  conversation so far (turn t = turn chunks 0..t, deterministic per
  session), so successive turns share a growing exact token prefix —
  without prefix reuse, the hottest KV in the system is recomputed every
  turn.
* Arrivals follow a Poisson process of configurable rate.

Also provides a token-stream iterator for the training example (synthetic
LM data, deterministic given seed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List

import numpy as np


@dataclass(frozen=True)
class Request:
    request_id: str
    arrival: float            # seconds since epoch 0
    prompt_len: int
    max_new_tokens: int
    seed: int
    token_dist: str = "uniform"   # "uniform" | "zipf" | "chat"
    zipf_a: float = 1.3           # Zipf exponent (smaller = heavier skew)
    slo_class: str = "standard"   # interactive | standard | batch
    deadline: float = -1.0        # absolute first-token deadline on the
    #                               virtual clock (-1 = none)
    session: str = ""             # affinity key (multi-turn conversations)
    turn: int = 0                 # conversation turn index ("chat" dist)

    def prompt_tokens(self, vocab: int) -> np.ndarray:
        if self.token_dist == "chat":
            # conversation replay: turn t's prompt is the concatenation of
            # turn chunks 0..t — successive turns of a session share the
            # exact token prefix (what the prefix-cache plane exploits);
            # seed is the *session* seed, shared by all its turns
            return chat_history_tokens(self.seed, self.turn, vocab)
        rng = np.random.default_rng(self.seed)
        if self.token_dist == "zipf":
            # heavy-tailed token ids: a handful of dominant tokens -> a
            # handful of dominant experts (token->expert affinity is fixed
            # by the router weights)
            toks = rng.zipf(self.zipf_a, size=(self.prompt_len,)) - 1
            return (toks % vocab).astype(np.int32)
        return rng.integers(0, vocab, size=(self.prompt_len,),
                            dtype=np.int32)


def _chat_turn_rng(session_seed: int, k: int) -> np.random.Generator:
    return np.random.default_rng(session_seed + 7919 * k)


def chat_turn_len(session_seed: int, k: int) -> int:
    """Length of one turn chunk — MUST mirror the first draw inside
    ``chat_history_tokens`` so ``Request.prompt_len`` metadata matches
    the actual prompt."""
    return int(_chat_turn_rng(session_seed, k).integers(4, 10))


def chat_history_tokens(session_seed: int, turn: int,
                        vocab: int) -> np.ndarray:
    """Deterministic conversation history: per-(session, turn) token
    chunks, concatenated. ``chat_history_tokens(s, t)`` is a strict prefix
    of ``chat_history_tokens(s, t+1)``."""
    parts = []
    for k in range(turn + 1):
        rng = _chat_turn_rng(session_seed, k)
        n = int(rng.integers(4, 10))
        parts.append(rng.integers(0, vocab, size=(n,), dtype=np.int32))
    return np.concatenate(parts)


def poisson_arrivals(rate_rps: float, duration: float,
                     rng: np.random.Generator) -> np.ndarray:
    n = rng.poisson(rate_rps * duration)
    return np.sort(rng.uniform(0.0, duration, size=n))


def burst_arrivals(rate_rps: float, duration: float,
                   rng: np.random.Generator, burst_size: int = 3,
                   burst_spread: float = 0.02) -> np.ndarray:
    """Poisson process over burst *centers* (rate preserved overall): each
    center spawns ``burst_size`` arrivals jittered by ``burst_spread``."""
    centers = poisson_arrivals(rate_rps / burst_size, duration, rng)
    ts = (centers[:, None] +
          rng.uniform(0.0, burst_spread, size=(len(centers), burst_size)))
    return np.sort(np.clip(ts.reshape(-1), 0.0, duration))


def make_workload(kind: str, rate_rps: float, duration: float,
                  seed: int = 0, max_prompt: int = 1024,
                  max_new: int = 256, long_frac: float = 0.3,
                  zipf_a: float = 1.3,
                  interactive_deadline: float = 0.5,
                  batch_wave: int = 8, batch_every: float = 2.0,
                  chat_turns: int = 4, chat_turn_gap: float = 0.6,
                  chat_max_new: int = 4) -> \
        List[Request]:
    rng = np.random.default_rng(seed)
    if kind == "multi_turn_chat":
        # the prefix-cache stress case: sessions replay their whole
        # conversation every turn (turn t's prompt = turns 0..t of the
        # history), so all but the newest turn chunk is KV the serving
        # stack already computed. Session starts are Poisson; turns are
        # spaced ``chat_turn_gap`` apart (think time), enough for the
        # previous turn to finish and its slot to be adopted by the cache.
        reqs = []
        starts = poisson_arrivals(max(rate_rps, 1e-6) / chat_turns,
                                  duration, rng)
        for s, t0 in enumerate(starts):
            sseed = seed * 100003 + 6151 * (s + 1)
            for t in range(chat_turns):
                plen = sum(chat_turn_len(sseed, k) for k in range(t + 1))
                reqs.append(Request(
                    f"chat-s{s}-t{t}", float(t0 + t * chat_turn_gap),
                    plen, chat_max_new, sseed, token_dist="chat",
                    session=f"chat-s{s}", turn=t))
        return sorted(reqs, key=lambda r: (r.arrival, r.request_id))
    if kind == "mixed_slo":
        # interactive Poisson stream: short prompts, short outputs, a
        # first-token deadline ``interactive_deadline`` after arrival
        reqs = []
        for i, t in enumerate(poisson_arrivals(rate_rps, duration, rng)):
            reqs.append(Request(
                f"mixed_slo-i{i}", float(t),
                int(rng.integers(4, 10)),
                int(np.clip(rng.integers(4, 10), 1, max_new)),
                seed * 100003 + i, slo_class="interactive",
                deadline=float(t) + interactive_deadline))
        # batch bulk arrivals: every ``batch_every`` seconds a wave of
        # ``batch_wave`` long-running requests lands at once (enough to
        # saturate a typical slot pool between waves)
        w = 0
        t_wave = 0.0
        while t_wave < duration:
            for j in range(batch_wave):
                reqs.append(Request(
                    f"mixed_slo-b{w}-{j}", float(t_wave),
                    int(rng.integers(6, 14)), max_new,
                    seed * 100003 + 50021 * (w + 1) + j,
                    slo_class="batch"))
            w += 1
            t_wave += batch_every
        return sorted(reqs, key=lambda r: (r.arrival, r.request_id))
    if kind == "long_prompt_burst":
        arrivals = burst_arrivals(rate_rps, duration, rng)
    else:
        arrivals = poisson_arrivals(rate_rps, duration, rng)
    reqs = []
    for i, t in enumerate(arrivals):
        token_dist = "uniform"
        if kind == "random":
            p_len, n_new = 10, 128
        elif kind == "skewed_expert_load":
            # decode-heavy like "random", but Zipf-distributed token ids so
            # per-expert dispatch load is heavily imbalanced
            p_len = int(np.clip(rng.integers(8, 17), 4, max_prompt))
            n_new = min(64, max_new)
            token_dist = "zipf"
        elif kind == "sharegpt":
            # log-normal prompt (~median 160 tok) and completion (~median 90)
            p_len = int(np.clip(rng.lognormal(5.0, 1.0), 4, max_prompt))
            n_new = int(np.clip(rng.lognormal(4.5, 0.8), 4, max_new))
        elif kind == "long_prompt_burst":
            # bimodal: short chat turns vs long documents near max_prompt
            if rng.uniform() < long_frac:
                p_len = int(rng.integers(max(5, max_prompt // 2),
                                         max_prompt + 1))
            else:
                p_len = int(rng.integers(4, max(5, max_prompt // 8)))
            n_new = int(np.clip(rng.lognormal(3.0, 0.6), 4, max_new))
        else:
            raise ValueError(kind)
        reqs.append(Request(f"{kind}-{i}", float(t), p_len, n_new,
                            seed * 100003 + i, token_dist=token_dist,
                            zipf_a=zipf_a))
    return reqs


def lm_batches(vocab: int, batch: int, seq: int, steps: int,
               seed: int = 0, learnable: bool = True) -> Iterator[dict]:
    """Synthetic LM training stream: returns {tokens, labels} per step.

    ``learnable=True`` generates affine-progression sequences
    (x[t+1] = (a*x[t] + b) mod V with fixed a,b) — a next-token function the
    model can actually learn, so training loss decreases below the uniform
    entropy floor. ``learnable=False`` gives uniform noise (floor = ln V).
    """
    rng = np.random.default_rng(seed)
    a = int(rng.integers(2, 7)) * 2 + 1  # odd -> bijective mod 2^k vocabs
    b = int(rng.integers(1, vocab))
    for _ in range(steps):
        if learnable:
            x0 = rng.integers(0, vocab, size=(batch, 1))
            toks = np.empty((batch, seq + 1), np.int64)
            toks[:, :1] = x0
            for t in range(seq):
                toks[:, t + 1] = (a * toks[:, t] + b) % vocab
            toks = toks.astype(np.int32)
        else:
            toks = rng.integers(0, vocab, size=(batch, seq + 1),
                                dtype=np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
