"""Workload generation (paper §7.1).

* ``sharegpt``  — ShareGPT-like: naturally varying prompt/completion lengths
  (log-normal mixture fitted to the published ShareGPT length statistics;
  the dataset itself is not redistributable offline).
* ``random``    — the paper's synthetic decode-heavy workload: fixed
  10-token prompts, 128 generated tokens.
* ``long_prompt_burst`` — the chunked-prefill stress case: bimodal prompt
  lengths (mostly short chat turns, a long-document minority) arriving in
  Poisson *bursts*, so several long prompts can land on the same tick and
  stall co-resident decodes unless prefill is budgeted.
* ``skewed_expert_load`` — the expert-rebalancer stress case: prompt tokens
  are drawn from a Zipf distribution over the vocabulary, so a few dominant
  tokens (and therefore the experts they route to) carry most of the
  dispatch load — static expert placement concentrates that load on a few
  EWs, which is exactly what load-aware rebalancing exists to fix.
* ``mixed_slo`` — the SLO-class stress case for the multi-class admission
  plane: a Poisson stream of short *interactive* requests (tight
  first-token deadlines) over periodic bulk waves of long *batch* requests
  that saturate every slot — without preempt-and-requeue, interactive TTFT
  degenerates to the batch residency time.
* Arrivals follow a Poisson process of configurable rate.

Also provides a token-stream iterator for the training example (synthetic
LM data, deterministic given seed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List

import numpy as np


@dataclass(frozen=True)
class Request:
    request_id: str
    arrival: float            # seconds since epoch 0
    prompt_len: int
    max_new_tokens: int
    seed: int
    token_dist: str = "uniform"   # "uniform" | "zipf" (token->expert skew)
    zipf_a: float = 1.3           # Zipf exponent (smaller = heavier skew)
    slo_class: str = "standard"   # interactive | standard | batch
    deadline: float = -1.0        # absolute first-token deadline on the
    #                               virtual clock (-1 = none)

    def prompt_tokens(self, vocab: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        if self.token_dist == "zipf":
            # heavy-tailed token ids: a handful of dominant tokens -> a
            # handful of dominant experts (token->expert affinity is fixed
            # by the router weights)
            toks = rng.zipf(self.zipf_a, size=(self.prompt_len,)) - 1
            return (toks % vocab).astype(np.int32)
        return rng.integers(0, vocab, size=(self.prompt_len,),
                            dtype=np.int32)


def poisson_arrivals(rate_rps: float, duration: float,
                     rng: np.random.Generator) -> np.ndarray:
    n = rng.poisson(rate_rps * duration)
    return np.sort(rng.uniform(0.0, duration, size=n))


def burst_arrivals(rate_rps: float, duration: float,
                   rng: np.random.Generator, burst_size: int = 3,
                   burst_spread: float = 0.02) -> np.ndarray:
    """Poisson process over burst *centers* (rate preserved overall): each
    center spawns ``burst_size`` arrivals jittered by ``burst_spread``."""
    centers = poisson_arrivals(rate_rps / burst_size, duration, rng)
    ts = (centers[:, None] +
          rng.uniform(0.0, burst_spread, size=(len(centers), burst_size)))
    return np.sort(np.clip(ts.reshape(-1), 0.0, duration))


def make_workload(kind: str, rate_rps: float, duration: float,
                  seed: int = 0, max_prompt: int = 1024,
                  max_new: int = 256, long_frac: float = 0.3,
                  zipf_a: float = 1.3,
                  interactive_deadline: float = 0.5,
                  batch_wave: int = 8, batch_every: float = 2.0) -> \
        List[Request]:
    rng = np.random.default_rng(seed)
    if kind == "mixed_slo":
        # interactive Poisson stream: short prompts, short outputs, a
        # first-token deadline ``interactive_deadline`` after arrival
        reqs = []
        for i, t in enumerate(poisson_arrivals(rate_rps, duration, rng)):
            reqs.append(Request(
                f"mixed_slo-i{i}", float(t),
                int(rng.integers(4, 10)),
                int(np.clip(rng.integers(4, 10), 1, max_new)),
                seed * 100003 + i, slo_class="interactive",
                deadline=float(t) + interactive_deadline))
        # batch bulk arrivals: every ``batch_every`` seconds a wave of
        # ``batch_wave`` long-running requests lands at once (enough to
        # saturate a typical slot pool between waves)
        w = 0
        t_wave = 0.0
        while t_wave < duration:
            for j in range(batch_wave):
                reqs.append(Request(
                    f"mixed_slo-b{w}-{j}", float(t_wave),
                    int(rng.integers(6, 14)), max_new,
                    seed * 100003 + 50021 * (w + 1) + j,
                    slo_class="batch"))
            w += 1
            t_wave += batch_every
        return sorted(reqs, key=lambda r: (r.arrival, r.request_id))
    if kind == "long_prompt_burst":
        arrivals = burst_arrivals(rate_rps, duration, rng)
    else:
        arrivals = poisson_arrivals(rate_rps, duration, rng)
    reqs = []
    for i, t in enumerate(arrivals):
        token_dist = "uniform"
        if kind == "random":
            p_len, n_new = 10, 128
        elif kind == "skewed_expert_load":
            # decode-heavy like "random", but Zipf-distributed token ids so
            # per-expert dispatch load is heavily imbalanced
            p_len = int(np.clip(rng.integers(8, 17), 4, max_prompt))
            n_new = min(64, max_new)
            token_dist = "zipf"
        elif kind == "sharegpt":
            # log-normal prompt (~median 160 tok) and completion (~median 90)
            p_len = int(np.clip(rng.lognormal(5.0, 1.0), 4, max_prompt))
            n_new = int(np.clip(rng.lognormal(4.5, 0.8), 4, max_new))
        elif kind == "long_prompt_burst":
            # bimodal: short chat turns vs long documents near max_prompt
            if rng.uniform() < long_frac:
                p_len = int(rng.integers(max(5, max_prompt // 2),
                                         max_prompt + 1))
            else:
                p_len = int(rng.integers(4, max(5, max_prompt // 8)))
            n_new = int(np.clip(rng.lognormal(3.0, 0.6), 4, max_new))
        else:
            raise ValueError(kind)
        reqs.append(Request(f"{kind}-{i}", float(t), p_len, n_new,
                            seed * 100003 + i, token_dist=token_dist,
                            zipf_a=zipf_a))
    return reqs


def lm_batches(vocab: int, batch: int, seq: int, steps: int,
               seed: int = 0, learnable: bool = True) -> Iterator[dict]:
    """Synthetic LM training stream: returns {tokens, labels} per step.

    ``learnable=True`` generates affine-progression sequences
    (x[t+1] = (a*x[t] + b) mod V with fixed a,b) — a next-token function the
    model can actually learn, so training loss decreases below the uniform
    entropy floor. ``learnable=False`` gives uniform noise (floor = ln V).
    """
    rng = np.random.default_rng(seed)
    a = int(rng.integers(2, 7)) * 2 + 1  # odd -> bijective mod 2^k vocabs
    b = int(rng.integers(1, vocab))
    for _ in range(steps):
        if learnable:
            x0 = rng.integers(0, vocab, size=(batch, 1))
            toks = np.empty((batch, seq + 1), np.int64)
            toks[:, :1] = x0
            for t in range(seq):
                toks[:, t + 1] = (a * toks[:, t] + b) % vocab
            toks = toks.astype(np.int32)
        else:
            toks = rng.integers(0, vocab, size=(batch, seq + 1),
                                dtype=np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
