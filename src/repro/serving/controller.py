"""SLO-driven closed-loop control plane.

Every knob the serving stack grew over PRs 1-8 is actuated here from one
place: a single ``ServingController`` runs one decision pass per engine
tick, reading only signals the stack already produces and acting only
through mechanisms that already exist. The loop it closes:

  signals                      decisions                  actuators
  -------                      ---------                  ---------
  gateway queue depths   --->  EW autoscaling       --->  Orchestrator
  per-EW load EMAs             (debounced watermarks)     request_scale_out/in
  imbalance trajectory   --->  rebalance trigger    --->  Orchestrator
  (EMA slope + predicted       (fires on the predicted    request_rebalance +
   threshold crossing)          crossing, not after it)   weighted split plans
  deadline headroom +    --->  adaptive chunk       --->  ChunkedPrefillPlane
  interactive TBT p99          budget (Sarathi-style      set_budget
                               prefill:decode ratio)
  head deadline risk +   --->  preemption gate +    --->  victim_policy=
  victim KV value              victim pricing             "controller"

Why this is free by construction: the controller is host-side bookkeeping
only — no jax calls, no device arrays. Its actions are the SAME actions an
operator (or a benchmark script) could have issued: placement plans install
as pure RouteState array updates, the chunk budget is a host int the
planner reads each tick, and preemption rides the §6.1/§6.2 checkpoint
path. Controller on vs off with identical decisions replayed as a script
is therefore bit-identical, with zero new jit traces (asserted in
tests/test_controller.py).

Every decision emits a structured ``WorkerEvent`` (kind
``controller_<decision>``, detail = the triggering signal values) through
``engine._note_request_event`` — so it lands in the orchestrator audit
timeline, the EventBus, the telemetry counters (``events.controller_*``),
and the Perfetto export (instants on the ``req:controller`` track) without
any new plumbing.

Debounce/hysteresis (policy 1) is T_push-aware: the dwell between scale
decisions defaults to ``T_w + 2*T_push`` of the attached orchestrator, so
one load transient can never pay the provisioning cost twice — the first
decision's worker has joined (and pushed its weights) before the signal is
trusted again. Watermarks read a queue-depth EMA, not the instantaneous
depth, and scale-out/scale-in watermarks are separated, so an oscillating
trace straddling one watermark cannot flap the pool.
"""
from __future__ import annotations

import math
from typing import List, Optional

from repro.serving.api import INTERACTIVE


class ServingController:
    """One decision pass per engine tick over four coordinated policies,
    behind one fitness signal (per-class TTFT/TBT percentiles)."""

    def __init__(self, engine):
        self.engine = engine
        ecfg = engine.ecfg
        self.autoscale_on = ecfg.ctl_autoscale
        self.rebalance_on = ecfg.ctl_rebalance
        self.budget_on = ecfg.ctl_chunk_budget
        self.orch = None               # attached by Orchestrator.__init__
        # -- policy 1 state: queue-depth EMA + scale debounce ---------------
        self._q_ema = 0.0
        self._q_decay = 0.7
        self._last_scale = -1e30
        # -- policy 2 state: imbalance trajectory ----------------------------
        self._imb_hist: List[tuple] = []   # (t, imbalance) ring, newest last
        self._imb_window = 8
        self._last_rebalance = -1e30
        # -- policy 3 state ---------------------------------------------------
        self._budget_base = ecfg.chunk_token_budget
        # -- audit -----------------------------------------------------------
        self.decisions: List[dict] = []
        self.counts = {"scale_out": 0, "scale_in": 0, "rebalance": 0,
                       "budget": 0, "preempt": 0, "preempt_denied": 0}
        if self.rebalance_on and engine.placement_mgr is not None:
            # weighted split replicas: the packer sizes each split against
            # the measured per-EW deficit instead of hottest-first parity
            engine.placement_mgr.split_mode = "weighted"

    # ------------------------------------------------------------------
    def attach_orchestrator(self, orch):
        """Bind the elasticity actuator (the Orchestrator constructs
        itself around an engine, so attachment flows that way too)."""
        self.orch = orch

    # ------------------------------------------------------------------
    # decision audit: one structured event + counter per decision
    # ------------------------------------------------------------------
    def _decide(self, kind: str, now: float, detail: str, **fields):
        self.counts[kind] = self.counts.get(kind, 0) + 1
        # fields carry the decision's machine-readable arguments (drain
        # target, new budget, ...) so a recorded history can be replayed
        # as a script — the bit-identity test's contract
        self.decisions.append({"t": now, "kind": kind, "detail": detail,
                               **fields})
        # rides the existing request-event plumbing: orchestrator audit
        # log + EventBus + telemetry counter (events.controller_<kind>) +
        # a Perfetto instant on the req:controller track
        self.engine._note_request_event(f"controller_{kind}", "controller",
                                        now, detail)

    # ------------------------------------------------------------------
    # the per-tick decision pass (called at the top of scheduler.step)
    # ------------------------------------------------------------------
    def tick(self, now: float):
        eng = self.engine
        if self.autoscale_on and self.orch is not None and \
                eng.placement_mgr is not None:
            self._autoscale(now)
        if self.rebalance_on and self.orch is not None and \
                eng.placement_mgr is not None:
            self._rebalance(now)
        if self.budget_on and eng.chunked is not None:
            self._chunk_budget(now)

    # ------------------------------------------------------------------
    # policy 1: EW autoscaling (queue depth + per-EW load EMAs,
    # T_push-aware debounce, watermark hysteresis)
    # ------------------------------------------------------------------
    def _scale_dwell(self) -> float:
        d = self.engine.ecfg.ctl_scale_dwell
        if d > 0:
            return d
        # the provisioning cost of the previous decision must have landed
        # (T_w join + T_push weight push, plus one more T_push of settling)
        # before the signal is trusted again
        return self.orch.T_w + 2.0 * self.orch.T_push

    def _autoscale(self, now: float):
        eng, orch, ecfg = self.engine, self.orch, self.engine.ecfg
        mgr = eng.placement_mgr
        depth = eng.gateway.depth()
        self._q_ema = self._q_decay * self._q_ema + \
            (1.0 - self._q_decay) * depth
        if any(s.kind in ("add_ew", "drain_ew") for s in orch._scales):
            return                      # provisioning in flight: never
        #                                 pay for the same transient twice
        if now - self._last_scale < self._scale_dwell():
            return                      # debounce window
        if eng.failed_ews:
            return                      # let recovery settle first
        loads = mgr.per_ew_load()
        if self._q_ema >= ecfg.ctl_queue_high and mgr.can_scale_out():
            self._last_scale = now
            orch.request_scale_out(now)
            self._decide(
                "scale_out", now,
                f"q_ema={self._q_ema:.2f}>={ecfg.ctl_queue_high:g} "
                f"depth={depth} "
                f"interactive={eng.gateway.class_depth(INTERACTIVE)} "
                f"pool={sorted(mgr.members)}")
        elif self._q_ema <= ecfg.ctl_queue_low and \
                len(mgr.members) > ecfg.num_ew and \
                not eng.active_requests() and \
                not eng.prefilling_requests():
            # idle pool above its boot size: drain the lightest member
            target = min(mgr.members, key=lambda m: (loads.get(m, 0.0), m))
            if len(mgr.members) > 1:
                self._last_scale = now
                orch.request_scale_in(target, now)
                self._decide(
                    "scale_in", now,
                    f"q_ema={self._q_ema:.2f}<={ecfg.ctl_queue_low:g} "
                    f"idle, drain ew{target} "
                    f"(load_ema={loads.get(target, 0.0):.1f})",
                    ew=target)

    # ------------------------------------------------------------------
    # policy 2: learned rebalance trigger (EMA trajectory: slope +
    # predicted threshold crossing, instead of a fixed instantaneous
    # threshold)
    # ------------------------------------------------------------------
    def _imb_slope(self) -> float:
        """Least-squares slope of the recent imbalance samples (per
        virtual second); 0 when the window is too short."""
        h = self._imb_hist[-self._imb_window:]
        if len(h) < 4:
            return 0.0
        n = len(h)
        t0 = h[0][0]
        ts = [t - t0 for t, _ in h]
        ys = [y for _, y in h]
        tm = sum(ts) / n
        ym = sum(ys) / n
        den = sum((t - tm) ** 2 for t in ts)
        if den <= 1e-12:
            return 0.0
        return sum((t - tm) * (y - ym) for t, y in zip(ts, ys)) / den

    def _rebalance(self, now: float):
        eng, orch = self.engine, self.orch
        mgr = eng.placement_mgr
        imb = mgr.imbalance()
        if not self._imb_hist or self._imb_hist[-1][0] < now:
            self._imb_hist.append((now, imb))
            del self._imb_hist[:-self._imb_window]
        if any(s.kind == "rebalance" for s in orch._scales):
            return
        if eng.failed_ews:
            return
        if len(mgr.members) <= 1 or \
                mgr._owned_slots() < mgr.geom.num_experts or \
                mgr.load.total_recorded < mgr.min_load_signal:
            return
        # the fixed-threshold policy needs a long cooldown because it
        # re-fires whenever the instantaneous value sits above the
        # threshold; this trigger is trajectory-gated (a re-fire needs a
        # genuine re-crossing) and already refuses while a plan is in
        # flight, so its dwell only has to cover plan landing plus one
        # EMA refresh window
        dwell = max(2.0 * orch.T_push, 1e-3)
        if now - self._last_rebalance < dwell:
            return
        thr = mgr.rebalance_threshold
        slope = self._imb_slope()
        # predict the imbalance at the moment a plan requested now would
        # actually land (T_push later): fire on the predicted crossing,
        # not after the fixed threshold is already breached
        horizon = orch.T_push + dwell
        predicted = imb + slope * horizon
        if imb > thr or (slope > 1e-6 and predicted > thr):
            self._last_rebalance = now
            orch.request_rebalance(now)
            self._decide(
                "rebalance", now,
                f"imb={imb:.3f} slope={slope:+.4f}/s "
                f"pred@+{horizon:.2f}s={predicted:.3f} thr={thr:g}")

    # ------------------------------------------------------------------
    # policy 3: adaptive chunk budget (Sarathi-style dynamic
    # prefill:decode ratio from the decode batch's SLO headroom)
    # ------------------------------------------------------------------
    def _interactive_headroom(self, now: float) -> float:
        """Smallest first-token deadline headroom over interactive work
        that has not produced a first token yet (queued entries AND
        resident prefilling/placed requests). +inf when none carries a
        deadline."""
        eng = self.engine
        head = math.inf
        qdl = eng.gateway.min_queued_deadline(INTERACTIVE)
        if qdl is not None:
            head = qdl - now
        for r in eng.requests.values():
            if r.slo_class == INTERACTIVE and not r.done and \
                    not r.cancelled and r.deadline is not None and \
                    r.t_first_token < 0:
                head = min(head, r.deadline - now)
        return head

    def _interactive_tbt_thin(self) -> bool:
        """Streamed interactive TBT p99 against the headroom target — the
        per-token half of the fitness signal (PR 7's registry; absent or
        empty histogram = not thin)."""
        tel = self.engine.telemetry
        if tel is None:
            return False
        h = tel.registry.hists.get(f"tbt.{INTERACTIVE}")
        if h is None or getattr(h, "count", 0) < 8:
            return False
        return h.quantile(0.99) > self.engine.ecfg.ctl_headroom

    def _chunk_budget(self, now: float):
        eng, ecfg = self.engine, self.engine.ecfg
        plane = eng.chunked
        base = self._budget_base
        lo = ecfg.ctl_budget_min or max(plane.min_chunk,
                                        max(1, base // 4))
        hi = ecfg.ctl_budget_max or base * 4
        headroom = self._interactive_headroom(now)
        interactive_decoding = any(
            r.slo_class == INTERACTIVE for r in eng.active_requests())
        interactive_waiting = \
            eng.gateway.class_depth(INTERACTIVE) > 0 or any(
                r.slo_class == INTERACTIVE and not r.done and
                not r.cancelled and r.t_first_token < 0
                for r in eng.requests.values())
        # two SLO regimes pull the budget opposite ways. TBT: every extra
        # prefill token in a tick is stall added to each streamed token, so
        # while an interactive request is DECODING the budget must never
        # exceed the tuned base (and drops to lo once streamed TBT p99
        # thins). TTFT: a waiting request's first token arrives only after
        # the FIFO prefill backlog ahead of it drains, and draining is
        # dominated by per-tick fixed cost — so while interactive work is
        # WAITING (and nothing interactive is streaming) a LARGER budget is
        # strictly better: race the backlog to the first token.
        if interactive_decoding:
            if self._interactive_tbt_thin():
                target, why = lo, "interactive TBT p99 thin"
            else:
                target, why = base, "interactive decoding, nominal"
        elif interactive_waiting and headroom <= ecfg.ctl_headroom:
            target, why = hi, f"race to first token, " \
                f"headroom={headroom:.3f}s<={ecfg.ctl_headroom:g}"
        elif interactive_waiting:
            target, why = base, "interactive waiting, nominal"
        elif plane.jobs:
            # decode idle w.r.t. the SLO signal: drain prefill backlog fast
            target, why = hi, f"decode idle, {len(plane.jobs)} streams"
        else:
            target, why = base, "idle"
        target = max(lo, min(hi, target))
        if target != plane.budget:
            old = plane.budget
            plane.set_budget(target)
            self._decide("budget", now, f"{old}->{target} ({why})",
                         budget=target)

    # ------------------------------------------------------------------
    # policy 4: deadline- and prefix-aware preemption
    # (engine._choose_victim delegates here under
    #  victim_policy="controller")
    # ------------------------------------------------------------------
    def _victim_kv_value(self, r) -> int:
        """Tokens of committed/cached state the eviction would tear down
        and later have to restore: exclusive pages on a paged engine
        (shared pages survive the eviction by refcount), else the
        resident token extent, plus the adopted prefix hit."""
        eng = self.engine
        resident = r.prefill_cursor if r.prefilling else max(0, r.pos)
        if eng.pages is not None:
            pool = eng.pages
            excl = sum(1 for pid in pool.slot_pages(r.slot)
                       if pool.ref[pid] == 1)
            resident = excl * pool.page_tokens
        return resident + r.prefix_hit

    def deadline_at_risk(self, head, now: float) -> bool:
        """The preemption gate: batch work is evicted only when the
        blocked interactive head's first-token deadline is actually at
        risk — already breached, or within ``ctl_deadline_risk`` of
        breaching. An undeadlined head is at risk once it has waited
        longer than the risk margin (it has no deadline to defend, but
        unbounded waiting is its own SLO failure)."""
        margin = self.engine.ecfg.ctl_deadline_risk
        if head.deadline is not None:
            return head.deadline - now <= margin
        return now - head.t_enqueue >= margin

    def choose_victim(self, cands, head, now: float):
        """Among preemptible candidates, evict the one wasting the least:
        maximal remaining work (it has invested the least) MINUS the
        priced-in value of its resident KV (committed pages/prefix the
        restore path would have to rebuild), weighted by
        ``ctl_kv_weight``."""
        if head is not None and not self.deadline_at_risk(head, now):
            self.counts["preempt_denied"] += 1
            if self.engine.telemetry is not None:
                self.engine.telemetry.registry.inc(
                    "controller.preempt_denied")
            return None
        w = self.engine.ecfg.ctl_kv_weight
        victim = max(cands, key=lambda r: (
            self.engine._remaining_work(r) - w * self._victim_kv_value(r),
            -r.preemptions, r.rid))
        self._decide(
            "preempt", now,
            f"victim={victim.rid} remaining="
            f"{self.engine._remaining_work(victim)} "
            f"kv_value={self._victim_kv_value(victim)} "
            f"head={getattr(head, 'rid', '?')}")
        return victim

    # ------------------------------------------------------------------
    # audit / telemetry surface
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Flat counter/gauge mirror for MetricsRegistry.sync (the
        ``controller.*`` section of the snapshot)."""
        out = {f"decisions.{k}": v for k, v in self.counts.items()}
        out["decisions.total"] = sum(
            v for k, v in self.counts.items() if k != "preempt_denied")
        out["q_ema"] = round(self._q_ema, 4)
        if self.engine.chunked is not None:
            out["chunk_budget"] = self.engine.chunked.budget
        if self._imb_hist:
            out["imbalance_slope"] = round(self._imb_slope(), 6)
        return out

    def snapshot(self) -> dict:
        """Full decision history + counters (ServeMetrics.controller)."""
        return {"counts": dict(self.counts),
                "decisions": [dict(d) for d in self.decisions]}
