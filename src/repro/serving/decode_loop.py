"""Device-resident decode loop: jitted sampling + multi-token segments.

Before this plane, every decode tick round-tripped through the host: the
jitted decode step produced logits, the scheduler pulled them to the host
(``np.asarray``), and ``engine.sample_token`` ran numpy argmax / partition /
``np.random`` per row before the next dispatch. That device->host sync per
token is the decode path's dominant fixed cost — resilience machinery only
matters if the failure-free fast path is device-bound (FailSafe's point,
and the ROADMAP's top open item).

The plane owns three pieces of device state:

  * **Per-slot sampling arrays** — ``greedy``/``temperature``/``top_k``/
    ``seed`` indexed by slot, mirroring the ``RouteState`` pattern: every
    request install/recovery is a pure array write, so per-request
    ``SamplingParams`` never mint a jit trace. Sampling itself is
    counter-based — the PRNG key is ``fold_in(fold_in(base, seed), pos)``
    where ``seed`` derives from the request id (stable across slot moves),
    so a token at (request, pos) is reproducible regardless of batch
    composition, co-residents, preemption, or which slot the request
    landed on after recovery.
  * **A token ring** — decode *segments* of ``decode_segment_len`` inner
    steps run as one ``lax.scan`` dispatch; sampled tokens accumulate in a
    device ring ([seg_len, B], -1 = row inactive that step) drained to the
    host once per segment instead of once per token.
  * **A stop-condition mask** — emitted-count vs ``max_new`` (and the
    ``max_seq`` ceiling) per slot, evaluated inside the scan: a row that
    finishes mid-segment drops out of cache writes and expert-capacity
    competition (its ``pos`` flips to -1) exactly as it would between
    host-driven steps, which is what keeps segmented decode bit-identical
    to per-step decode.

Segment boundaries align with chunk-boundary checkpointing: the scheduler
drains the ring, appends the tokens, and streams the whole segment's KV
through ``KVCheckpointer.checkpoint_range`` (the §6.1 bulk path), so a
failure mid-segment rewinds at most ``decode_segment_len`` tokens through
the ordinary §6.2 restore.

``decode_segment_len=1`` (the default) keeps today's per-step cadence but
still samples on device — the host-RNG path is gone entirely.
"""
from __future__ import annotations

import zlib
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _sample_tokens(key_base, logits, pos, greedy, temperature, top_k, seed):
    """Counter-based device sampling head. logits [B,V] (any float dtype),
    pos/greedy/temperature/top_k/seed [B]. Greedy rows take the plain
    argmax (first-max tie-break, matching ``np.argmax``); stochastic rows
    take a gumbel-max draw over the temperature-scaled, top-k-masked
    logits. The key depends only on (engine seed, request seed, pos) — not
    on the slot or the co-resident set."""
    lg = logits.astype(jnp.float32)
    v = lg.shape[-1]
    gre = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = lg / t
    # per-row dynamic top-k: the kth-largest value is the mask threshold;
    # ties at the threshold are kept — the historical host semantics
    # (`logits < kth` masked, >= kept). The usual k is small, and a full
    # [B, V] sort is the single most expensive op in the head, so take a
    # static top-64 slice and fall back to the sort only when some row
    # asks for a deeper k (lax.cond runs one branch; the kth *value* is
    # identical from either, so the draw is branch-independent).
    k = jnp.clip(top_k, 0, v)
    kc = min(v, 64)

    def _kth_topk(_):
        vals = jax.lax.top_k(scaled, kc)[0]
        return jnp.take_along_axis(vals, jnp.clip(k - 1, 0, kc - 1)[:, None],
                                   axis=1)

    def _kth_sort(_):
        srt = -jnp.sort(-scaled, axis=-1)
        kidx = jnp.where(k > 0, k - 1, v - 1)
        return jnp.take_along_axis(srt, kidx[:, None], axis=1)

    kth = jax.lax.cond(jnp.any(k > kc), _kth_sort, _kth_topk, None)
    keep = jnp.where((k > 0)[:, None], scaled >= kth, True)
    masked = jnp.where(keep, scaled, -jnp.inf)

    def row_key(s, p):
        return jax.random.fold_in(jax.random.fold_in(key_base, s), p)

    keys = jax.vmap(row_key)(seed, jnp.maximum(pos, 0))
    gmb = jax.vmap(lambda kk: jax.random.gumbel(kk, (v,), jnp.float32))(keys)
    samp = jnp.argmax(masked + gmb, axis=-1).astype(jnp.int32)
    return jnp.where(greedy, gre, samp)


def _make_segment_fn(api):
    """Build the fused segment step for one model family: decode (Pallas
    decode-attention + routed expert GEMM) + the sampling head + the
    stop-mask state update, scanned ``seg_len`` times inside ONE jit."""

    def seg_fn(params, route_state, cache, tokens, pos, emitted, max_new,
               greedy, temperature, top_k, seed, key_base, *,
               seg_len: int, capacity, with_load: bool, max_seq: int):
        def body(carry, _):
            tokens, pos, emitted, cache = carry
            active = pos >= 0
            if with_load:
                logits, cache, load = api.decode(
                    params, tokens, pos, cache, route_state,
                    capacity=capacity, with_load=True)
            else:
                logits, cache = api.decode(params, tokens, pos, cache,
                                           route_state, capacity=capacity)
                load = jnp.zeros((0,), jnp.float32)
            nxt = _sample_tokens(key_base, logits, pos, greedy,
                                 temperature, top_k, seed)
            emitted2 = emitted + active.astype(jnp.int32)
            pos2 = pos + 1
            # stop mask: a row that hit max_new (or the cache ceiling)
            # leaves the active set for the rest of the segment — same
            # transition the host applies between per-step ticks
            alive = active & (emitted2 < max_new) & (pos2 < max_seq - 1)
            tok_out = jnp.where(active, nxt, -1)
            tokens2 = jnp.where(active, nxt, tokens)
            pos3 = jnp.where(alive, pos2, -1)
            return (tokens2, pos3, emitted2, cache), (tok_out, load)

        (tokens, pos, emitted, cache), (ring, loads) = jax.lax.scan(
            body, (tokens, pos, emitted, cache), None, length=seg_len)
        return cache, ring, loads

    return seg_fn


class DecodeLoopPlane:
    """Per-slot sampling state + the jitted device decode loop."""

    def __init__(self, engine):
        self.engine = engine
        ecfg = engine.ecfg
        b = ecfg.max_batch
        self.seg_len = max(1, int(getattr(ecfg, "decode_segment_len", 1)))
        # host mirrors of the per-slot sampling arrays (engine defaults
        # until a request binds its own SamplingParams to its slot)
        self.greedy = np.full((b,), bool(ecfg.greedy))
        self.temperature = np.full((b,), float(ecfg.temperature), np.float32)
        self.top_k = np.full((b,), int(ecfg.top_k), np.int32)
        self.seed = np.zeros((b,), np.int32)
        self._dev: Optional[Tuple] = None      # cached device copies
        self.key_base = jax.random.PRNGKey(ecfg.sample_seed)
        self._sample = jax.jit(_sample_tokens)
        self._seg = jax.jit(
            _make_segment_fn(engine.api),
            static_argnames=("seg_len", "capacity", "with_load", "max_seq"))

    # -- per-slot sampling arrays (RouteState-style pure array writes) ------
    def resolve(self, sampling, rid: str):
        """(greedy, temperature, top_k, seed) for one request: per-request
        SamplingParams override engine defaults; the seed defaults to a
        stable hash of the rid so recomputation after failover/preemption
        — possibly in a different slot — replays the same stream."""
        ecfg = self.engine.ecfg
        greedy = ecfg.greedy if sampling is None else sampling.greedy
        temp = ecfg.temperature if sampling is None else sampling.temperature
        top_k = ecfg.top_k if sampling is None else sampling.top_k
        seed = getattr(sampling, "seed", None) if sampling is not None \
            else None
        if seed is None:
            seed = zlib.crc32(rid.encode()) & 0x7FFFFFFF
        return bool(greedy), float(temp), int(top_k), int(seed)

    def bind(self, r):
        """Install request r's sampling config on its slot — an array
        write, never a trace."""
        g, t, k, s = self.resolve(r.sampling, r.rid)
        self.greedy[r.slot] = g
        self.temperature[r.slot] = t
        self.top_k[r.slot] = k
        self.seed[r.slot] = s
        self._dev = None

    def device_arrays(self):
        if self._dev is None:
            self._dev = (jnp.asarray(self.greedy),
                         jnp.asarray(self.temperature),
                         jnp.asarray(self.top_k),
                         jnp.asarray(self.seed))
        return self._dev

    # -- per-step sampling (decode_segment_len == 1 path) -------------------
    def sample(self, logits, pos_dev):
        """Sample [B] next tokens on device from the decode step's logits
        (still resident — no host round-trip of the [B,V] matrix)."""
        g, t, k, s = self.device_arrays()
        return self._sample(self.key_base, logits, pos_dev, g, t, k, s)

    def sample_rows(self, logits, entries, pos_list: List[int]):
        """First-token sampling for an exact-scheme prefill group: row i of
        ``logits`` belongs to ``entries[i]`` (a QueuedRequest) whose last
        prompt position is ``pos_list[i]``. Runs the same jitted sampler
        (row counts are pow2-padded upstream, so shapes stay O(log B))."""
        rows = logits.shape[0]
        g = np.full((rows,), bool(self.engine.ecfg.greedy))
        t = np.full((rows,), float(self.engine.ecfg.temperature), np.float32)
        k = np.full((rows,), int(self.engine.ecfg.top_k), np.int32)
        s = np.zeros((rows,), np.int32)
        p = np.zeros((rows,), np.int32)
        for i, q in enumerate(entries):
            g[i], t[i], k[i], s[i] = self.resolve(q.sampling, q.rid)
            p[i] = pos_list[i]
        out = self._sample(self.key_base, logits, jnp.asarray(p),
                           jnp.asarray(g), jnp.asarray(t), jnp.asarray(k),
                           jnp.asarray(s))
        return np.asarray(out)

    # -- segmented decode (decode_segment_len > 1 path) ---------------------
    def run_segment(self, act, seg_len: int):
        """One ``lax.scan`` dispatch of ``seg_len`` decode steps over the
        active set. Returns (ring [seg_len, B] np.int32 with -1 for
        inactive rows, loads [seg_len, P] np.float32) — ONE device->host
        drain for the whole segment."""
        eng = self.engine
        b = eng.ecfg.max_batch
        tokens = np.zeros((b,), np.int32)
        pos = np.full((b,), -1, np.int32)
        emitted = np.zeros((b,), np.int32)
        max_new = np.full((b,), np.iinfo(np.int32).max, np.int32)
        for r in act:
            tokens[r.slot] = r.next_input
            pos[r.slot] = r.pos
            emitted[r.slot] = len(r.tokens)
            max_new[r.slot] = r.max_new
            # paged: the whole segment's KV writes land inside the scan —
            # pre-map every page the row can touch (positions up to
            # max_seq - 2; page allocation cannot happen mid-scan)
            eng._kv_ensure(r.slot, min(r.pos + seg_len,
                                       eng.ecfg.max_seq - 1))
        g, t, k, s = self.device_arrays()
        cache, ring, loads = self._seg(
            eng.params, eng.route_state, eng.cache,
            jnp.asarray(tokens), jnp.asarray(pos), jnp.asarray(emitted),
            jnp.asarray(max_new), g, t, k, s, self.key_base,
            seg_len=seg_len, capacity=eng.decode_capacity,
            with_load=eng.collect_load, max_seq=eng.ecfg.max_seq)
        eng.cache = cache
        if eng.telemetry is not None:
            # host-side counters only — the dispatch above is untouched
            eng.telemetry.registry.inc("decode.segments")
            eng.telemetry.registry.inc("decode.segment_steps", seg_len)
            eng.telemetry.registry.observe("decode.segment_rows", len(act))
        return np.asarray(ring), np.asarray(loads)

    def segment_traces(self) -> int:
        """Jit cache sizes of the plane's step functions (the zero-new-
        traces invariant extends to the device loop: segment tails, done
        rows, and SamplingParams changes never mint a trace)."""
        return self._seg._cache_size() + self._sample._cache_size()
