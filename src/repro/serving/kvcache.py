"""Per-request KV/state slot management over the model cache pytree.

The model families expose caches with different structures (stacked attention
KV, Mamba states, xLSTM cells, whisper cross-KV). ``CacheLayout`` discovers,
once per model, (i) the batch axis of every leaf and (ii) which subtrees are
attention caches ({"k","v","pos"} triples), and then provides generic
per-request operations:

  * ``token_segment``   — the incremental checkpoint unit (paper §6.1):
      attention leaves -> the single KV column the decode step just wrote
      (size C = 2*Hkv*head_dim, App. C); state leaves (SSM/xLSTM/cross-KV)
      -> the current constant-size snapshot.
  * ``write_token_segment`` — per-request restoration (§6.2): inject a
      committed segment into any healthy AW's cache slot.
  * ``request_state`` / ``write_request_state`` — whole-slot copy (used for
      request migration and the pause-checkpoint-resume baseline).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


class CacheLayout:
    def __init__(self, init_cache_fn):
        c1 = jax.eval_shape(lambda: init_cache_fn(1, 16))
        c2 = jax.eval_shape(lambda: init_cache_fn(2, 16))
        l1, self.treedef = jax.tree_util.tree_flatten_with_path(c1)
        l2, _ = jax.tree_util.tree_flatten_with_path(c2)
        self.paths: List[str] = []
        self.batch_axis: List[int] = []
        for (p1, a1), (_, a2) in zip(l1, l2):
            diffs = [i for i, (s1, s2) in enumerate(zip(a1.shape, a2.shape))
                     if s1 != s2]
            assert len(diffs) == 1, f"ambiguous batch axis at {p1}: {a1.shape}"
            self.paths.append(_path_str(p1))
            self.batch_axis.append(diffs[0])
        # attention nodes: parent paths having exactly k/v/pos children
        parents: Dict[str, set] = {}
        for p in self.paths:
            if "/" in p:
                par, leaf = p.rsplit("/", 1)
                parents.setdefault(par, set()).add(leaf)
        self.attn_parents = {par for par, kids in parents.items()
                             if {"k", "v", "pos"} <= kids}
        self.leaf_kind: List[str] = []
        for p in self.paths:
            par, _, leaf = p.rpartition("/")
            if par in self.attn_parents and leaf in ("k", "v", "pos"):
                self.leaf_kind.append("attn_" + leaf)
            else:
                self.leaf_kind.append("state")

    # ------------------------------------------------------------------
    def _leaves(self, cache):
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        assert len(leaves) == len(self.paths)
        return leaves, treedef

    @staticmethod
    def _take(a, axis, idx):
        return jax.lax.index_in_dim(a, idx, axis, keepdims=False)

    @staticmethod
    def _put(a, axis, idx, val):
        return jnp.asarray(a).at[
            (slice(None),) * axis + (idx,)].set(jnp.asarray(val, a.dtype))

    # ------------------------------------------------------------------
    def token_segment(self, cache, slot: int, token: int) -> List[Any]:
        """Incremental checkpoint segment for (request slot, token idx)."""
        leaves, _ = self._leaves(cache)
        seg = []
        for leaf, ax, kind in zip(leaves, self.batch_axis, self.leaf_kind):
            per_req = self._take(leaf, ax, slot)     # drop batch axis
            if kind.startswith("attn_"):
                sc = per_req.shape[ax]  # position axis follows batch axis
                per_req = self._take(per_req, ax, token % sc)
            seg.append(np.asarray(per_req))
        return seg

    def write_token_segment(self, cache, slot: int, token: int,
                            seg: List[Any]):
        leaves, treedef = self._leaves(cache)
        out = []
        for leaf, ax, kind, s in zip(leaves, self.batch_axis,
                                     self.leaf_kind, seg):
            if kind.startswith("attn_"):
                sc = leaf.shape[ax + 1]
                idx = (slice(None),) * ax + (slot, token % sc)
            else:
                idx = (slice(None),) * ax + (slot,)
            out.append(jnp.asarray(leaf).at[idx].set(
                jnp.asarray(s, leaf.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    def make_batched_extractor(self):
        """One jitted gather for all active (slot, token) pairs — the
        AW-side analogue of posting all RDMA writes in a single doorbell.
        Returns fn(cache, slots [n], tokens [n]) -> list of leaves with a
        leading n axis."""
        batch_axes = list(self.batch_axis)
        kinds = list(self.leaf_kind)

        def extract(cache, slots, tokens):
            leaves, _ = jax.tree_util.tree_flatten(cache)
            out = []
            for leaf, ax, kind in zip(leaves, batch_axes, kinds):
                def one(slot, tok, leaf=leaf, ax=ax, kind=kind):
                    per = jax.lax.dynamic_index_in_dim(leaf, slot, ax,
                                                       keepdims=False)
                    if kind.startswith("attn_"):
                        sc = per.shape[ax]
                        per = jax.lax.dynamic_index_in_dim(
                            per, tok % sc, ax, keepdims=False)
                    return per

                out.append(jax.vmap(one)(slots, tokens))
            return out

        return jax.jit(extract)

    # ------------------------------------------------------------------
    def make_slot_range_extractor(self):
        """Bulk-segment gather for chunked prefill: one jitted call pulls
        the ``count`` contiguous token segments a chunk just wrote for one
        slot. Returns fn(cache, slot, start, count=<static>) -> list of
        leaves with a leading count axis (attention leaves: the KV columns
        at token indices [start, start+count); state leaves: the current
        snapshot repeated). ``count`` is static, so jit keys track the
        O(log) chunk-shape set, not every chunk length ever seen."""
        batch_axes = list(self.batch_axis)
        kinds = list(self.leaf_kind)

        def extract(cache, slot, start, *, count: int):
            leaves, _ = jax.tree_util.tree_flatten(cache)
            out = []
            for leaf, ax, kind in zip(leaves, batch_axes, kinds):
                per = jax.lax.dynamic_index_in_dim(leaf, slot, ax,
                                                   keepdims=False)
                if kind.startswith("attn_"):
                    sc = per.shape[ax]
                    sl = jax.lax.dynamic_slice_in_dim(
                        per, start % sc, count, axis=ax)
                    out.append(jnp.moveaxis(sl, ax, 0))
                else:
                    out.append(jnp.broadcast_to(
                        per[None], (count,) + per.shape))
            return out

        return jax.jit(extract, static_argnames=("count",))

    # ------------------------------------------------------------------
    def make_multi_slot_range_extractor(self):
        """Segment-drain gather: one jitted call pulls ``count`` contiguous
        token segments for MANY slots at once — the decode plane's
        per-segment checkpoint drain (every active request commits its
        segment's KV in a single device gather instead of one call each).
        Returns fn(cache, slots [n], starts [n], count=<static>) -> list
        of leaves with leading [n, count] axes. ``count`` static and rows
        pow2-padded upstream keep jit keys O(log seg_len · log max_batch)."""
        batch_axes = list(self.batch_axis)
        kinds = list(self.leaf_kind)

        def extract(cache, slots, starts, *, count: int):
            leaves, _ = jax.tree_util.tree_flatten(cache)
            out = []
            for leaf, ax, kind in zip(leaves, batch_axes, kinds):
                def one(slot, start, leaf=leaf, ax=ax, kind=kind):
                    per = jax.lax.dynamic_index_in_dim(leaf, slot, ax,
                                                       keepdims=False)
                    if kind.startswith("attn_"):
                        sc = per.shape[ax]
                        sl = jax.lax.dynamic_slice_in_dim(
                            per, start % sc, count, axis=ax)
                        return jnp.moveaxis(sl, ax, 0)
                    return jnp.broadcast_to(per[None],
                                            (count,) + per.shape)

                out.append(jax.vmap(one)(slots, starts))
            return out

        return jax.jit(extract, static_argnames=("count",))

    # ------------------------------------------------------------------
    def request_state(self, cache, slot: int) -> List[Any]:
        leaves, _ = self._leaves(cache)
        return [np.asarray(self._take(l, ax, slot))
                for l, ax in zip(leaves, self.batch_axis)]

    def write_request_state(self, cache, slot: int, state: List[Any]):
        leaves, treedef = self._leaves(cache)
        out = [self._put(l, ax, slot, s)
               for l, ax, s in zip(leaves, self.batch_axis, state)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def scrub_request_state(self, state: List[Any], valid_len: int
                            ) -> List[Any]:
        """Invalidate pad entries of a batched-prefill request state: any
        attention-cache entry holding a position >= ``valid_len`` gets
        ``pos`` = -1, which the decode kernels mask out. K/V payloads can
        stay — they are unreachable once the position is invalid. Only
        meaningful for pure attention caches (state leaves are recurrent
        summaries that padding must not reach in the first place)."""
        out = []
        for s, kind in zip(state, self.leaf_kind):
            if kind == "attn_pos":
                s = np.where(np.asarray(s) >= valid_len, -1, s)
            out.append(s)
        return out

    def scrub_slot(self, cache, slot: int, valid_len: int):
        """Invalidate positions >= ``valid_len`` of one slot in place:
        attention ``pos`` entries past the valid prefix become -1 (masked
        by the decode kernels); K/V payloads stay — unreachable once the
        position is invalid. This is prefix-cache adoption's counterpart
        of ``clear_slot``: the adopted prefix [0, valid_len) survives, the
        donor's stale tail does not. Only meaningful for pure attention
        caches (slot index == absolute position)."""
        leaves, treedef = self._leaves(cache)
        out = []
        for leaf, ax, kind in zip(leaves, self.batch_axis, self.leaf_kind):
            if kind == "attn_pos":
                per = self._take(leaf, ax, slot)
                per = jnp.where(per >= valid_len, -1, per)
                leaf = self._put(leaf, ax, slot, per)
            out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)

    def clear_slot(self, cache, slot: int):
        """Reset one slot (releases a finished/failed request)."""
        leaves, treedef = self._leaves(cache)
        out = []
        for leaf, ax, kind in zip(leaves, self.batch_axis, self.leaf_kind):
            per = self._take(leaf, ax, slot)
            fill = jnp.full_like(per, -1) if kind == "attn_pos" \
                else jnp.zeros_like(per)
            out.append(self._put(leaf, ax, slot, fill))
        return jax.tree_util.tree_unflatten(treedef, out)

    def segment_nbytes(self, seg: List[Any], attn_only: bool = False) -> int:
        total = 0
        for s, kind in zip(seg, self.leaf_kind):
            if attn_only and not kind.startswith("attn_"):
                continue
            total += np.asarray(s).nbytes
        return total


# Slot allocation lives with the workers that own the partitions:
# see serving/workers.py (SlotPartition / AttentionWorker / ClusterSlotView).
