"""Per-request KV/state slot management over the model cache pytree.

The model families expose caches with different structures (stacked attention
KV, Mamba states, xLSTM cells, whisper cross-KV). ``CacheLayout`` discovers,
once per model, (i) the batch axis of every leaf and (ii) which subtrees are
attention caches ({"k","v","pos"} triples), and then provides generic
per-request operations:

  * ``token_segment``   — the incremental checkpoint unit (paper §6.1):
      attention leaves -> the single KV column the decode step just wrote
      (size C = 2*Hkv*head_dim, App. C); state leaves (SSM/xLSTM/cross-KV)
      -> the current constant-size snapshot.
  * ``write_token_segment`` — per-request restoration (§6.2): inject a
      committed segment into any healthy AW's cache slot.
  * ``request_state`` / ``write_request_state`` — whole-slot copy (used for
      request migration and the pause-checkpoint-resume baseline).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


class CacheLayout:
    def __init__(self, init_cache_fn):
        c1 = jax.eval_shape(lambda: init_cache_fn(1, 16))
        c2 = jax.eval_shape(lambda: init_cache_fn(2, 16))
        l1, self.treedef = jax.tree_util.tree_flatten_with_path(c1)
        l2, _ = jax.tree_util.tree_flatten_with_path(c2)
        self.paths: List[str] = []
        self.batch_axis: List[int] = []
        for (p1, a1), (_, a2) in zip(l1, l2):
            diffs = [i for i, (s1, s2) in enumerate(zip(a1.shape, a2.shape))
                     if s1 != s2]
            assert len(diffs) == 1, f"ambiguous batch axis at {p1}: {a1.shape}"
            self.paths.append(_path_str(p1))
            self.batch_axis.append(diffs[0])
        # attention nodes: parent paths having exactly k/v/pos children
        parents: Dict[str, set] = {}
        for p in self.paths:
            if "/" in p:
                par, leaf = p.rsplit("/", 1)
                parents.setdefault(par, set()).add(leaf)
        self.attn_parents = {par for par, kids in parents.items()
                             if {"k", "v", "pos"} <= kids}
        self.leaf_kind: List[str] = []
        for p in self.paths:
            par, _, leaf = p.rpartition("/")
            if par in self.attn_parents and leaf in ("k", "v", "pos"):
                self.leaf_kind.append("attn_" + leaf)
            else:
                self.leaf_kind.append("state")

    # ------------------------------------------------------------------
    def _leaves(self, cache):
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        assert len(leaves) == len(self.paths)
        return leaves, treedef

    @staticmethod
    def _take(a, axis, idx):
        return jax.lax.index_in_dim(a, idx, axis, keepdims=False)

    @staticmethod
    def _put(a, axis, idx, val):
        return jnp.asarray(a).at[
            (slice(None),) * axis + (idx,)].set(jnp.asarray(val, a.dtype))

    # ------------------------------------------------------------------
    def token_segment(self, cache, slot: int, token: int) -> List[Any]:
        """Incremental checkpoint segment for (request slot, token idx)."""
        leaves, _ = self._leaves(cache)
        seg = []
        for leaf, ax, kind in zip(leaves, self.batch_axis, self.leaf_kind):
            per_req = self._take(leaf, ax, slot)     # drop batch axis
            if kind.startswith("attn_"):
                sc = per_req.shape[ax]  # position axis follows batch axis
                per_req = self._take(per_req, ax, token % sc)
            seg.append(np.asarray(per_req))
        return seg

    def write_token_segment(self, cache, slot: int, token: int,
                            seg: List[Any]):
        leaves, treedef = self._leaves(cache)
        out = []
        for leaf, ax, kind, s in zip(leaves, self.batch_axis,
                                     self.leaf_kind, seg):
            if kind.startswith("attn_"):
                sc = leaf.shape[ax + 1]
                idx = (slice(None),) * ax + (slot, token % sc)
            else:
                idx = (slice(None),) * ax + (slot,)
            out.append(jnp.asarray(leaf).at[idx].set(
                jnp.asarray(s, leaf.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    def make_batched_extractor(self):
        """One jitted gather for all active (slot, token) pairs — the
        AW-side analogue of posting all RDMA writes in a single doorbell.
        Returns fn(cache, slots [n], tokens [n]) -> list of leaves with a
        leading n axis."""
        batch_axes = list(self.batch_axis)
        kinds = list(self.leaf_kind)

        def extract(cache, slots, tokens):
            leaves, _ = jax.tree_util.tree_flatten(cache)
            out = []
            for leaf, ax, kind in zip(leaves, batch_axes, kinds):
                def one(slot, tok, leaf=leaf, ax=ax, kind=kind):
                    per = jax.lax.dynamic_index_in_dim(leaf, slot, ax,
                                                       keepdims=False)
                    if kind.startswith("attn_"):
                        sc = per.shape[ax]
                        per = jax.lax.dynamic_index_in_dim(
                            per, tok % sc, ax, keepdims=False)
                    return per

                out.append(jax.vmap(one)(slots, tokens))
            return out

        return jax.jit(extract)

    # ------------------------------------------------------------------
    def make_slot_range_extractor(self):
        """Bulk-segment gather for chunked prefill: one jitted call pulls
        the ``count`` contiguous token segments a chunk just wrote for one
        slot. Returns fn(cache, slot, start, count=<static>) -> list of
        leaves with a leading count axis (attention leaves: the KV columns
        at token indices [start, start+count); state leaves: the current
        snapshot repeated). ``count`` is static, so jit keys track the
        O(log) chunk-shape set, not every chunk length ever seen."""
        batch_axes = list(self.batch_axis)
        kinds = list(self.leaf_kind)

        def extract(cache, slot, start, *, count: int):
            leaves, _ = jax.tree_util.tree_flatten(cache)
            out = []
            for leaf, ax, kind in zip(leaves, batch_axes, kinds):
                per = jax.lax.dynamic_index_in_dim(leaf, slot, ax,
                                                   keepdims=False)
                if kind.startswith("attn_"):
                    sc = per.shape[ax]
                    sl = jax.lax.dynamic_slice_in_dim(
                        per, start % sc, count, axis=ax)
                    out.append(jnp.moveaxis(sl, ax, 0))
                else:
                    out.append(jnp.broadcast_to(
                        per[None], (count,) + per.shape))
            return out

        return jax.jit(extract, static_argnames=("count",))

    # ------------------------------------------------------------------
    def make_multi_slot_range_extractor(self):
        """Segment-drain gather: one jitted call pulls ``count`` contiguous
        token segments for MANY slots at once — the decode plane's
        per-segment checkpoint drain (every active request commits its
        segment's KV in a single device gather instead of one call each).
        Returns fn(cache, slots [n], starts [n], count=<static>) -> list
        of leaves with leading [n, count] axes. ``count`` static and rows
        pow2-padded upstream keep jit keys O(log seg_len · log max_batch)."""
        batch_axes = list(self.batch_axis)
        kinds = list(self.leaf_kind)

        def extract(cache, slots, starts, *, count: int):
            leaves, _ = jax.tree_util.tree_flatten(cache)
            out = []
            for leaf, ax, kind in zip(leaves, batch_axes, kinds):
                def one(slot, start, leaf=leaf, ax=ax, kind=kind):
                    per = jax.lax.dynamic_index_in_dim(leaf, slot, ax,
                                                       keepdims=False)
                    if kind.startswith("attn_"):
                        sc = per.shape[ax]
                        sl = jax.lax.dynamic_slice_in_dim(
                            per, start % sc, count, axis=ax)
                        return jnp.moveaxis(sl, ax, 0)
                    return jnp.broadcast_to(per[None],
                                            (count,) + per.shape)

                out.append(jax.vmap(one)(slots, starts))
            return out

        return jax.jit(extract, static_argnames=("count",))

    # ------------------------------------------------------------------
    def request_state(self, cache, slot: int) -> List[Any]:
        leaves, _ = self._leaves(cache)
        return [np.asarray(self._take(l, ax, slot))
                for l, ax in zip(leaves, self.batch_axis)]

    def write_request_state(self, cache, slot: int, state: List[Any]):
        leaves, treedef = self._leaves(cache)
        out = [self._put(l, ax, slot, s)
               for l, ax, s in zip(leaves, self.batch_axis, state)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def scrub_request_state(self, state: List[Any], valid_len: int
                            ) -> List[Any]:
        """Invalidate pad entries of a batched-prefill request state: any
        attention-cache entry holding a position >= ``valid_len`` gets
        ``pos`` = -1, which the decode kernels mask out. K/V payloads can
        stay — they are unreachable once the position is invalid. Only
        meaningful for pure attention caches (state leaves are recurrent
        summaries that padding must not reach in the first place)."""
        out = []
        for s, kind in zip(state, self.leaf_kind):
            if kind == "attn_pos":
                s = np.where(np.asarray(s) >= valid_len, -1, s)
            out.append(s)
        return out

    def scrub_slot(self, cache, slot: int, valid_len: int):
        """Invalidate positions >= ``valid_len`` of one slot in place:
        attention ``pos`` entries past the valid prefix become -1 (masked
        by the decode kernels); K/V payloads stay — unreachable once the
        position is invalid. This is prefix-cache adoption's counterpart
        of ``clear_slot``: the adopted prefix [0, valid_len) survives, the
        donor's stale tail does not. Only meaningful for pure attention
        caches (slot index == absolute position)."""
        leaves, treedef = self._leaves(cache)
        out = []
        for leaf, ax, kind in zip(leaves, self.batch_axis, self.leaf_kind):
            if kind == "attn_pos":
                per = self._take(leaf, ax, slot)
                per = jnp.where(per >= valid_len, -1, per)
                leaf = self._put(leaf, ax, slot, per)
            out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)

    def clear_slot(self, cache, slot: int):
        """Reset one slot (releases a finished/failed request)."""
        leaves, treedef = self._leaves(cache)
        out = []
        for leaf, ax, kind in zip(leaves, self.batch_axis, self.leaf_kind):
            per = self._take(leaf, ax, slot)
            fill = jnp.full_like(per, -1) if kind == "attn_pos" \
                else jnp.zeros_like(per)
            out.append(self._put(leaf, ax, slot, fill))
        return jax.tree_util.tree_unflatten(treedef, out)

    def segment_nbytes(self, seg: List[Any], attn_only: bool = False) -> int:
        total = 0
        for s, kind in zip(seg, self.leaf_kind):
            if attn_only and not kind.startswith("attn_"):
                continue
            total += np.asarray(s).nbytes
        return total

    def prefill_paddable(self, cache, max_seq: int) -> bool:
        """True when slot index == absolute position for every leaf (pure
        attention cache, no ring wrap): the precondition for chunked
        prefill and prefix adoption."""
        leaves, _ = self._leaves(cache)
        if not all(k.startswith("attn_") for k in self.leaf_kind):
            return False
        return all(leaf.shape[ax + 1] >= max_seq
                   for leaf, ax, kind in zip(leaves, self.batch_axis,
                                             self.leaf_kind)
                   if kind == "attn_k")


# --------------------------------------------------------------------------
# paged layout: block tables over refcounted physical page pools
# --------------------------------------------------------------------------

class PagedCacheLayout:
    """CacheLayout twin for a PAGED cache (vLLM-style block tables).

    The paged cache pytree is the contiguous pytree with every leaf's
    per-slot rows replaced by a pool of physical pages — batch axis B ->
    page axis P, position axis Sc -> page extent ``page_tokens`` — plus
    one top-level block table ``bt`` [B, nblk] int32 shared by all layers
    (nblk * page_tokens == max_seq, so a slot's gathered pages reproduce
    its contiguous layout element-for-element). Page 0 is reserved: never
    allocated, positions -1 forever; unmapped block-table entries point at
    it so every gather reads a valid page and unmapped regions mask out
    exactly like an empty contiguous cache.

    Every read-side operation gathers the slot's pages into the contiguous
    per-slot view and then applies the contiguous logic, so checkpoint
    segments and request states are LAYOUT-INDEPENDENT: a segment written
    by a paged AW restores onto a contiguous engine and vice versa — the
    property prefix migration and failover restoration ride on.

    Paged mode is attention-only and full-attention-only (no SSM state
    leaves, no sliding-window ring buffers); the engine asserts both.
    """

    def __init__(self, init_cache_fn, page_tokens: int, max_seq: int):
        assert page_tokens > 0 and max_seq % page_tokens == 0, \
            (page_tokens, max_seq)
        self.inner = CacheLayout(init_cache_fn)
        assert all(k.startswith("attn_") for k in self.inner.leaf_kind), \
            "paged KV requires a pure attention cache"
        self.page_tokens = page_tokens
        self.max_seq = max_seq
        self.nblk = max_seq // page_tokens
        # mirrored for callers that introspect the layout generically
        self.paths = self.inner.paths
        self.batch_axis = self.inner.batch_axis
        self.leaf_kind = self.inner.leaf_kind
        self.attn_parents = self.inner.attn_parents
        self._copy_page_fn = jax.jit(self._copy_page_impl)
        self._scrub_pages_fn = jax.jit(self._scrub_pages_impl)

    # ------------------------------------------------------------------
    def make_cache(self, init_cache_fn, batch: int, num_pages: int):
        """Build the paged cache: per-layer page pools (the contiguous
        init with batch=num_pages, max_seq=page_tokens) + the block
        table, all entries at the null page."""
        pools = init_cache_fn(num_pages, self.page_tokens)
        cache = dict(pools)
        cache["bt"] = jnp.zeros((batch, self.nblk), jnp.int32)
        return cache

    def _rest(self, cache):
        rest = {k: v for k, v in cache.items() if k != "bt"}
        leaves, treedef = jax.tree_util.tree_flatten(rest)
        assert len(leaves) == len(self.inner.paths)
        return cache["bt"], leaves, treedef

    def _rebuild(self, bt, leaves, treedef):
        rest = jax.tree_util.tree_unflatten(treedef, leaves)
        out = dict(rest)
        out["bt"] = bt
        return out

    def set_block_table(self, cache, bt_host):
        """Install the host block-table mirror on device (a tiny [B, nblk]
        int32 upload — the only per-allocation device traffic)."""
        out = dict(cache)
        out["bt"] = jnp.asarray(np.asarray(bt_host, np.int32))
        return out

    def _gather_slot(self, leaf, ax, row):
        """Contiguous per-slot view of one pool leaf through a block-table
        row [nblk]: [..., P, pt, ...] -> [..., nblk*pt, ...] at axis ax."""
        g = jnp.take(leaf, row, axis=ax)
        shp = leaf.shape[:ax] + (row.shape[0] * leaf.shape[ax + 1],) + \
            leaf.shape[ax + 2:]
        return g.reshape(shp)

    # ------------------------------------------------------------------
    def token_segment(self, cache, slot: int, token: int) -> List[Any]:
        bt, leaves, _ = self._rest(cache)
        pt = self.page_tokens
        page = bt[slot, (token % self.max_seq) // pt]
        off = token % pt
        seg = []
        for leaf, ax in zip(leaves, self.inner.batch_axis):
            per = jax.lax.index_in_dim(
                jax.lax.dynamic_index_in_dim(leaf, page, ax,
                                             keepdims=False),
                off, ax, keepdims=False)
            seg.append(np.asarray(per))
        return seg

    def write_token_segment(self, cache, slot: int, token: int,
                            seg: List[Any]):
        bt, leaves, treedef = self._rest(cache)
        pt = self.page_tokens
        page = bt[slot, (token % self.max_seq) // pt]
        off = token % pt
        out = []
        for leaf, ax, s in zip(leaves, self.inner.batch_axis, seg):
            # an unmapped block (page 0 — the host failed to pre-allocate)
            # drops the write instead of corrupting the shared null page
            safe = jnp.where(page > 0, page, leaf.shape[ax])
            idx = (slice(None),) * ax + (safe, off)
            out.append(jnp.asarray(leaf).at[idx].set(
                jnp.asarray(s, leaf.dtype), mode="drop"))
        return self._rebuild(bt, out, treedef)

    # ------------------------------------------------------------------
    def make_batched_extractor(self):
        batch_axes = list(self.inner.batch_axis)
        pt, max_seq = self.page_tokens, self.max_seq

        def extract(cache, slots, tokens):
            bt, leaves, _ = self._rest(cache)
            out = []
            for leaf, ax in zip(leaves, batch_axes):
                def one(slot, tok, leaf=leaf, ax=ax):
                    row = jax.lax.dynamic_index_in_dim(bt, slot, 0,
                                                       keepdims=False)
                    page = jax.lax.dynamic_index_in_dim(
                        row, (tok % max_seq) // pt, 0, keepdims=False)
                    per = jax.lax.dynamic_index_in_dim(leaf, page, ax,
                                                       keepdims=False)
                    return jax.lax.dynamic_index_in_dim(
                        per, tok % pt, ax, keepdims=False)

                out.append(jax.vmap(one)(slots, tokens))
            return out

        return jax.jit(extract)

    def make_slot_range_extractor(self):
        batch_axes = list(self.inner.batch_axis)
        max_seq = self.max_seq

        def extract(cache, slot, start, *, count: int):
            bt, leaves, _ = self._rest(cache)
            row = jax.lax.dynamic_index_in_dim(bt, slot, 0, keepdims=False)
            out = []
            for leaf, ax in zip(leaves, batch_axes):
                per = self._gather_slot(leaf, ax, row)
                sl = jax.lax.dynamic_slice_in_dim(
                    per, start % max_seq, count, axis=ax)
                out.append(jnp.moveaxis(sl, ax, 0))
            return out

        return jax.jit(extract, static_argnames=("count",))

    def make_multi_slot_range_extractor(self):
        batch_axes = list(self.inner.batch_axis)
        max_seq = self.max_seq

        def extract(cache, slots, starts, *, count: int):
            bt, leaves, _ = self._rest(cache)
            out = []
            for leaf, ax in zip(leaves, batch_axes):
                def one(slot, start, leaf=leaf, ax=ax):
                    row = jax.lax.dynamic_index_in_dim(bt, slot, 0,
                                                       keepdims=False)
                    per = self._gather_slot(leaf, ax, row)
                    sl = jax.lax.dynamic_slice_in_dim(
                        per, start % max_seq, count, axis=ax)
                    return jnp.moveaxis(sl, ax, 0)

                out.append(jax.vmap(one)(slots, starts))
            return out

        return jax.jit(extract, static_argnames=("count",))

    # ------------------------------------------------------------------
    def request_state(self, cache, slot: int) -> List[Any]:
        """Whole-slot state in the CONTIGUOUS layout (gathered through the
        block table) — interchangeable with a contiguous engine's."""
        bt, leaves, _ = self._rest(cache)
        row = bt[slot]
        return [np.asarray(self._gather_slot(leaf, ax, row))
                for leaf, ax in zip(leaves, self.inner.batch_axis)]

    def write_request_state(self, cache, slot: int, state: List[Any]):
        """Scatter a contiguous per-slot state into the slot's mapped
        pages. Blocks left unmapped drop their writes — callers pre-
        allocate pages covering the valid prefix; the dropped tail is
        scrubbed (-1) state anyway."""
        bt, leaves, treedef = self._rest(cache)
        row = bt[slot]
        out = []
        for leaf, ax, s in zip(leaves, self.inner.batch_axis, state):
            safe = jnp.where(row > 0, row, leaf.shape[ax])
            s = jnp.asarray(s, leaf.dtype)
            shp = s.shape[:ax] + (self.nblk, self.page_tokens) + \
                s.shape[ax + 1:]
            # block axis to the front to pair with the page-fronted pool;
            # the page-offset axis stays at ax+1 in both, matching shapes
            paged = jnp.moveaxis(s.reshape(shp), ax, 0)
            dest = jnp.moveaxis(jnp.asarray(leaf), ax, 0)
            dest = dest.at[safe].set(paged, mode="drop")
            out.append(jnp.moveaxis(dest, 0, ax))
        return self._rebuild(bt, out, treedef)

    def scrub_request_state(self, state: List[Any], valid_len: int
                            ) -> List[Any]:
        return self.inner.scrub_request_state(state, valid_len)

    def scrub_slot(self, cache, slot: int, valid_len: int):
        """Mask positions >= valid_len in the slot's mapped pages. Writes
        to shared pages are value-identical (a fully-shared page only
        covers positions < valid_len), and null-page duplicates rewrite
        -1 with -1, so sharing is never corrupted."""
        bt, leaves, treedef = self._rest(cache)
        row = bt[slot]
        out = []
        for leaf, ax, kind in zip(leaves, self.inner.batch_axis,
                                  self.leaf_kind):
            if kind == "attn_pos":
                sub = jnp.take(leaf, row, axis=ax)
                sub = jnp.where(sub >= valid_len, -1, sub)
                idx = (slice(None),) * ax + (row,)
                leaf = jnp.asarray(leaf).at[idx].set(sub)
            out.append(leaf)
        return self._rebuild(bt, out, treedef)

    def clear_slot(self, cache, slot: int):
        """Reset the slot's block-table row to the null page. Page
        disposition (decref / scrub-on-free) is the PagePool's job — the
        engine facade runs it before calling this."""
        bt, leaves, treedef = self._rest(cache)
        return self._rebuild(bt.at[slot].set(0), leaves, treedef)

    def segment_nbytes(self, seg: List[Any], attn_only: bool = False) -> int:
        return self.inner.segment_nbytes(seg, attn_only)

    def prefill_paddable(self, cache, max_seq: int) -> bool:
        return max_seq <= self.max_seq

    # -- device page ops (jitted once; int operands are traced) ----------
    def _copy_page_impl(self, cache, src, dst):
        """Copy-on-extend: duplicate one physical page (all layers)."""
        bt, leaves, treedef = self._rest(cache)
        out = []
        for leaf, ax in zip(leaves, self.inner.batch_axis):
            page = jax.lax.dynamic_index_in_dim(leaf, src, ax,
                                                keepdims=False)
            idx = (slice(None),) * ax + (dst,)
            out.append(leaf.at[idx].set(page))
        return self._rebuild(bt, out, treedef)

    def copy_page(self, cache, src: int, dst: int):
        return self._copy_page_fn(cache, jnp.int32(src), jnp.int32(dst))

    def _scrub_pages_impl(self, cache, pages):
        """Invalidate freed pages' positions so a recycled page can never
        leak stale entries into its next mapper's attention. ``pages`` is
        a fixed-size [nblk] id vector padded with the null page (whose
        positions are -1 already — a no-op rewrite)."""
        bt, leaves, treedef = self._rest(cache)
        out = []
        for leaf, ax, kind in zip(leaves, self.inner.batch_axis,
                                  self.leaf_kind):
            if kind == "attn_pos":
                idx = (slice(None),) * ax + (pages,)
                leaf = leaf.at[idx].set(-1)
            out.append(leaf)
        return self._rebuild(bt, out, treedef)

    def scrub_pages(self, cache, pages: List[int]):
        """Scrub an arbitrary host list of freed page ids (chunked through
        the fixed-size jitted scatter: one trace total)."""
        k = self.nblk
        for i in range(0, len(pages), k):
            chunk = list(pages[i:i + k])
            chunk += [0] * (k - len(chunk))
            cache = self._scrub_pages_fn(
                cache, jnp.asarray(chunk, jnp.int32))
        return cache


# --------------------------------------------------------------------------
# host-side page allocator
# --------------------------------------------------------------------------

class PagePool:
    """Host bookkeeping for the physical page pools: per-AW free lists
    (pages partition across AWs like slots do — a failure domain owns its
    pages), refcounts, and the host mirror of the device block table.

    Page ids are global; page 0 is reserved (never allocated). Refcount
    semantics: an allocated page starts at 1; prefix-cache entries and
    adopting slots each hold one reference; a page returns to its AW's
    free list only when the count hits 0 — the invariant the eviction fix
    (never free a page with refcount > 1) and the property test lean on.
    """

    def __init__(self, num_slots: int, num_aw: int, blocks_per_slot: int,
                 page_tokens: int, pages_per_aw: int = 0):
        from collections import deque
        self.page_tokens = page_tokens
        self.nblk = blocks_per_slot
        self.num_aw = num_aw
        self.slots_per_aw = num_slots // num_aw
        self.pages_per_aw = pages_per_aw or \
            self.slots_per_aw * blocks_per_slot
        self.num_pages = 1 + self.pages_per_aw * num_aw
        self._free = [deque(range(1 + a * self.pages_per_aw,
                                  1 + (a + 1) * self.pages_per_aw))
                      for a in range(num_aw)]
        self.ref = np.zeros(self.num_pages, np.int32)
        self.bt = np.zeros((num_slots, self.nblk), np.int32)
        self.dirty = False   # host bt differs from the device copy

    # ------------------------------------------------------------------
    def aw_of_page(self, pid: int) -> int:
        assert pid > 0
        return (pid - 1) // self.pages_per_aw

    def aw_of_slot(self, slot: int) -> int:
        return slot // self.slots_per_aw

    def free_pages(self, aw: int) -> int:
        return len(self._free[aw])

    def alloc(self, aw: int) -> int:
        """Allocate one page on AW ``aw`` (refcount 1), or -1 if its pool
        is exhausted (caller evicts cached prefixes and retries)."""
        if not self._free[aw]:
            return -1
        pid = self._free[aw].popleft()
        assert self.ref[pid] == 0, pid
        self.ref[pid] = 1
        return pid

    def incref(self, pid: int):
        assert pid > 0 and self.ref[pid] > 0, pid
        self.ref[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one reference; True when the page was freed (caller must
        scrub it on device before it can be re-allocated)."""
        assert pid > 0 and self.ref[pid] > 0, pid
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            self._free[self.aw_of_page(pid)].append(pid)
            return True
        return False

    # ------------------------------------------------------------------
    def map_block(self, slot: int, blk: int, pid: int):
        self.bt[slot, blk] = pid
        self.dirty = True

    def mapped_blocks(self, slot: int) -> int:
        return int((self.bt[slot] > 0).sum())

    def slot_pages(self, slot: int, upto_blocks: int = -1) -> List[int]:
        row = self.bt[slot]
        if upto_blocks >= 0:
            row = row[:upto_blocks]
        return [int(p) for p in row if p > 0]

    def release_slot(self, slot: int) -> List[int]:
        """Unmap the whole slot, decref its pages; returns the pages whose
        refcount hit 0 (to scrub + recycle). Shared pages survive with
        their remaining holders."""
        freed = [pid for pid in self.slot_pages(slot) if self.decref(pid)]
        if self.bt[slot].any():
            self.bt[slot] = 0
            self.dirty = True
        return freed

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {"pages_total": self.num_pages - 1,
                "pages_used": int((self.ref[1:] > 0).sum()),
                "pages_shared": int((self.ref[1:] > 1).sum())}

    def check(self) -> None:
        """Allocator invariants (the property test's oracle): every page
        is either free exactly once with refcount 0, or allocated with
        refcount > 0 and on no free list; block tables only reference
        allocated pages."""
        seen: Dict[int, int] = {}
        for aw, fl in enumerate(self._free):
            for pid in fl:
                assert self.aw_of_page(pid) == aw, (pid, aw)
                seen[pid] = seen.get(pid, 0) + 1
        for pid in range(1, self.num_pages):
            if self.ref[pid] == 0:
                assert seen.get(pid, 0) == 1, \
                    f"page {pid} free-count {seen.get(pid, 0)} != 1"
            else:
                assert pid not in seen, f"page {pid} allocated AND free"
        mapped = self.bt[self.bt > 0]
        assert (self.ref[mapped] > 0).all(), "bt references a free page"


# Slot allocation lives with the workers that own the partitions:
# see serving/workers.py (SlotPartition / AttentionWorker / ClusterSlotView).
