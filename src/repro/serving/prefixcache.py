"""Prefix-cache plane: per-AW radix KV reuse with checkpoint-backed
restoration.

Tarragon makes resident KV a first-class, recoverable asset (§6.1/§6.2);
this plane stops throwing it away at request completion. Each
AttentionWorker keeps a **radix index over committed KV prefixes**: when a
request finishes, its slot is not cleared — the cache *adopts* it, keyed
by the token sequence whose KV the slot holds. A later request whose
prompt shares a prefix (the multi-turn chat pattern: every turn replays
the whole conversation) adopts the cached slot **by reference** — no KV
copy — scrubs the stale tail, and starts its chunked-prefill stream at
``prefill_cursor = matched_prefix_len``. Only the uncached tail is ever
prefilled, and the result is bit-identical to a cold run (resuming a
chunk stream mid-prompt is exactly the machinery mid-prefill recovery
already exercises).

Sharing is slot-level and refcounted: an index entry holds its slot, and
a live request adopting that slot marks the entry *live* — live entries
are never evicted (the slot is the request's working state). Eviction is
LRU with a recompute-cost tie-break (older first; among equals, the
shortest prefix — the cheapest to rebuild — goes first), under a
configurable per-AW slot budget and optional token budget. Under slot
pressure the cache yields: an AW's free capacity counts evictable cached
slots, and allocation evicts transparently.

The resilience twist (FailSafe's warm-standby insight applied to KV):
cached prefixes are **checkpoint-backed**. On adoption the prefix is
re-streamed into the adopting request's own store log through the
existing bulk-segment path, so its recovery never depends on the donor;
and when an AW dies, its non-live cached entries become *orphans* whose
KV still lives in the checkpoint store — recovery restores each hot
session prefix per-request onto the failover AW (§6.2 applied to cache
state), so the session's next turn still hits. Every transition here is
a host-side array/bookkeeping update: zero new jit traces.

On **paged** engines (``EngineConfig.kv_page_tokens > 0``) sharing moves
down a level, from slots to physical pages: entries pin refcounted KV
pages instead of holding a slot, adoption maps the SAME pages into any
number of concurrently decoding slots (copy-on-extend at the boundary
page keeps shared pages read-only), and eviction becomes page-granular —
under allocation pressure the LRU entry loses tail pages one at a time,
priced by the pages only it keeps alive. With
``EngineConfig.prefix_global_index`` the plane also mirrors every per-AW
trie into one cluster-wide index that routes arrivals to the AW holding
their best cached prefix anywhere, and ``prefix_migrate`` lets a hot
prefix follow demand to a free AW through the same checkpoint-replay
path failover restoration uses.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np


def _common_len(a, b) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class _RadixNode:
    __slots__ = ("edge", "children", "slot")

    def __init__(self, edge=()):
        self.edge: Tuple[int, ...] = tuple(edge)  # tokens on the edge in
        self.children: Dict[int, "_RadixNode"] = {}
        self.slot: int = -1      # slot whose cached prefix ends exactly here


class RadixIndex:
    """Compressed radix trie over token sequences. Each inserted sequence
    ends at a node carrying the slot id whose cache holds that prefix's
    KV. ``match`` returns the usable entry with the longest common prefix
    against a query — the LCP may end mid-edge (the divergence point):
    any entry below it still shares exactly that many leading tokens."""

    def __init__(self):
        self.root = _RadixNode()

    # -- mutation -----------------------------------------------------------
    def insert(self, tokens, slot: int):
        toks = tuple(int(t) for t in tokens)
        node, i = self.root, 0
        while i < len(toks):
            child = node.children.get(toks[i])
            if child is None:
                leaf = _RadixNode(toks[i:])
                leaf.slot = slot
                node.children[toks[i]] = leaf
                return
            k = _common_len(child.edge, toks[i:])
            if k == len(child.edge):
                node = child
                i += k
                continue
            # split the child's edge at the divergence point
            mid = _RadixNode(child.edge[:k])
            child.edge = child.edge[k:]
            mid.children[child.edge[0]] = child
            node.children[toks[i]] = mid
            if i + k == len(toks):
                mid.slot = slot
            else:
                leaf = _RadixNode(toks[i + k:])
                leaf.slot = slot
                mid.children[toks[i + k]] = leaf
            return
        node.slot = slot

    def remove(self, tokens, slot: int):
        """Clear the entry at the exact path ``tokens`` if it holds
        ``slot`` (collision-safe: a different slot at that path is left
        alone). Stale slot-less nodes are kept — they are harmless to
        matching and trivial at slot-count scale."""
        toks = tuple(int(t) for t in tokens)
        node, i = self.root, 0
        while i < len(toks):
            child = node.children.get(toks[i])
            if child is None:
                return
            if child.edge != toks[i:i + len(child.edge)]:
                return
            node = child
            i += len(child.edge)
        if node.slot == slot:
            node.slot = -1

    def exact_slot(self, tokens) -> int:
        toks = tuple(int(t) for t in tokens)
        node, i = self.root, 0
        while i < len(toks):
            child = node.children.get(toks[i])
            if child is None or child.edge != toks[i:i + len(child.edge)]:
                return -1
            node = child
            i += len(child.edge)
        return node.slot

    # -- lookup -------------------------------------------------------------
    def _any_slot(self, node: _RadixNode, usable: Set[int]) -> int:
        if node.slot in usable:
            return node.slot
        for child in node.children.values():
            s = self._any_slot(child, usable)
            if s >= 0:
                return s
        return -1

    def match(self, tokens, usable: Set[int]) -> Tuple[int, int]:
        """(slot, lcp) of the usable entry sharing the longest prefix with
        ``tokens`` — (-1, 0) when nothing usable matches at least one
        token. Walk the query down the trie; the deepest reachable subtree
        gives the longest guaranteed LCP, shallower fully-matched nodes
        give progressively shorter ones."""
        toks = tuple(int(t) for t in tokens)
        path: List[Tuple[_RadixNode, int]] = []
        node, i = self.root, 0
        deep: Optional[Tuple[_RadixNode, int]] = None
        while i < len(toks):
            child = node.children.get(toks[i])
            if child is None:
                break
            k = _common_len(child.edge, toks[i:])
            if k < len(child.edge):
                # diverged (or query exhausted) inside the edge: everything
                # below shares exactly i + k leading tokens with the query
                deep = (child, i + k)
                break
            node = child
            i += len(child.edge)
            path.append((node, i))
        if deep is not None and deep[1] > 0:
            s = self._any_slot(deep[0], usable)
            if s >= 0:
                return s, deep[1]
        for n, depth in reversed(path):
            s = self._any_slot(n, usable)
            if s >= 0:
                return s, depth
        return -1, 0


@dataclass
class PrefixEntry:
    """One cached prefix: ``slot`` holds committed KV for ``tokens``
    (positions [0, len(tokens))). ``rid`` names the checkpoint-store log
    backing the entry across AW failures ('' = unbacked — a live entry's
    adopter carries the prefix in its own log). ``live`` is the slot-level
    refcount bit: a resident request shares the slot, so the entry can be
    neither evicted nor re-adopted until it completes or releases."""
    slot: int
    tokens: np.ndarray
    rid: str
    session: Optional[str]
    last_use: float
    live: bool = False

    @property
    def length(self) -> int:
        return len(self.tokens)


@dataclass
class PrefixCacheStats:
    offered: int = 0
    cached: int = 0
    refused: int = 0

    def snapshot(self) -> dict:
        return {"offered": self.offered, "cached": self.cached,
                "refused": self.refused}


class AWPrefixCache:
    """Per-AW prefix cache: the radix index plus slot bookkeeping over the
    worker's own ``SlotPartition``. Pure host-side metadata — the KV
    itself stays resident in the engine's slot cache (or in the
    checkpoint store, for failover restoration)."""

    def __init__(self, partition, max_slots: int, max_tokens: int = 0,
                 min_match: int = 4, release_log=None, stats=None):
        self.partition = partition
        self.max_slots = max(1, max_slots)
        self.max_tokens = max(0, max_tokens)
        # adoption truncates the matched entry to the LCP, so a trivial
        # (coincidental) match must not be allowed to destroy a long
        # cached prefix for a few-token prefill saving
        self.min_match = max(1, min_match)
        self.release_log = release_log or (lambda rid: None)
        self.stats = stats           # GatewayStats (shared hit accounting)
        self.entries: Dict[int, PrefixEntry] = {}
        self.index = RadixIndex()
        self.local = PrefixCacheStats()

    # -- capacity view ------------------------------------------------------
    def evictable_count(self) -> int:
        return sum(1 for e in self.entries.values() if not e.live)

    def cached_tokens(self) -> int:
        return sum(e.length for e in self.entries.values() if not e.live)

    def match_len(self, prompt) -> int:
        """Routing probe (no side effects): longest cached prefix of
        ``prompt`` on this AW, live entries included — the session's KV
        being in use right now is still a reason to route here. Matches
        below ``min_match`` report 0 (they would not be adopted)."""
        if prompt is None or len(prompt) < 2:
            return 0
        _, lcp = self.index.match(prompt, set(self.entries.keys()))
        lcp = min(lcp, len(prompt) - 1)
        return lcp if lcp >= self.min_match else 0

    # -- allocation: match-or-evict ----------------------------------------
    def take_slot(self, prompt, now: float = 0.0) -> Tuple[int, int]:
        """Hand out a slot for an admission. Prefix match first: a usable
        (non-live) entry sharing >= ``min_match`` tokens is adopted by
        reference — the entry truncates to the matched prefix, goes live,
        and the caller prefills only the tail. Otherwise a partition
        slot, else the LRU cached entry is evicted and its slot reused."""
        if prompt is not None and len(prompt) >= 2:
            usable = {s for s, e in self.entries.items() if not e.live}
            slot, lcp = self.index.match(prompt, usable)
            lcp = min(lcp, len(prompt) - 1)
            if slot >= 0 and lcp >= self.min_match:
                e = self.entries[slot]
                self.index.remove(e.tokens, slot)
                # truncate to the match: the adopter overwrites [lcp, ...)
                e.tokens = np.asarray(e.tokens[:lcp], np.int32)
                e.live = True
                e.last_use = now
                if e.rid:
                    # the adopter re-checkpoints the prefix into its own
                    # log (bulk-segment path); the donor log is done
                    self.release_log(e.rid)
                    e.rid = ""
                self.index.insert(e.tokens, slot)
                return slot, lcp
        if self.partition.free_count() > 0:
            return self.partition.alloc(), 0
        victim = self._pick_victim()
        assert victim is not None, "take_slot called with no capacity"
        self._evict(victim, free_slot=False)
        return victim.slot, 0

    # -- population ---------------------------------------------------------
    def offer(self, slot: int, tokens: np.ndarray, rid: str,
              session: Optional[str], now: float) -> bool:
        """A finished request's slot is offered for caching. Replaces the
        slot's live entry (the completed adoption), enforces the slot and
        token budgets by evicting LRU entries, and refuses (slot returns
        to the free list) when the sequence is trivial, duplicates an
        existing path, or cannot fit."""
        self.local.offered += 1
        old = self.entries.pop(slot, None)
        if old is not None:
            self.index.remove(old.tokens, slot)
            if old.rid:
                self.release_log(old.rid)
        n = len(tokens)
        if n < 2 or (self.max_tokens and n > self.max_tokens) or \
                self.index.exact_slot(tokens) >= 0:
            self.local.refused += 1
            return False
        while self.evictable_count() >= self.max_slots or \
                (self.max_tokens and
                 self.cached_tokens() + n > self.max_tokens):
            victim = self._pick_victim()
            if victim is None:
                self.local.refused += 1
                return False
            self._evict(victim, free_slot=True)
        self.entries[slot] = PrefixEntry(slot, np.asarray(tokens, np.int32),
                                         rid, session, now)
        self.index.insert(tokens, slot)
        self.local.cached += 1
        return True

    def insert_restored(self, slot: int, tokens: np.ndarray, rid: str,
                        session: Optional[str], now: float) -> bool:
        """Failover path: an orphaned prefix restored from the checkpoint
        store joins this AW's index (same budget discipline as offer)."""
        return self.offer(slot, tokens, rid, session, now)

    # -- teardown -----------------------------------------------------------
    def forget_slot(self, slot: int):
        """Drop the entry at ``slot`` without touching the slot itself
        (the caller owns it: cancellation, preemption, failed offer)."""
        e = self.entries.pop(slot, None)
        if e is not None:
            self.index.remove(e.tokens, slot)
            if e.rid:
                self.release_log(e.rid)

    def clear(self):
        """AW crash: the metadata dies with the worker (orphan snapshots
        are taken by the plane *before* the worker's fail())."""
        self.entries = {}
        self.index = RadixIndex()

    # -- eviction -----------------------------------------------------------
    def _pick_victim(self) -> Optional[PrefixEntry]:
        """LRU + cost-aware: oldest ``last_use`` first; among equals the
        shortest prefix (cheapest to recompute) goes first; slot id breaks
        the final tie for determinism. Live entries are untouchable."""
        cands = [e for e in self.entries.values() if not e.live]
        if not cands:
            return None
        return min(cands, key=lambda e: (e.last_use, e.length, e.slot))

    def _evict(self, e: PrefixEntry, free_slot: bool):
        del self.entries[e.slot]
        self.index.remove(e.tokens, e.slot)
        if e.rid:
            self.release_log(e.rid)
        if free_slot:
            self.partition.release(e.slot)
        if self.stats is not None:
            self.stats.prefix_evictions += 1

    def snapshot(self) -> dict:
        return {"entries": len(self.entries),
                "live": sum(1 for e in self.entries.values() if e.live),
                "cached_tokens": self.cached_tokens(),
                **self.local.snapshot()}


# --------------------------------------------------------------------------
# paged mode: page-level sharing, entry-id keyed caches, global routing
# --------------------------------------------------------------------------

@dataclass
class PagedPrefixEntry:
    """One cached prefix on a PAGED engine: the entry holds pinned
    references to the physical pages whose KV covers ``tokens`` — not a
    slot. Entries are keyed by a synthetic id (``eid``), never consumed by
    adoption, and serve any number of concurrent adopters: each adopter's
    slot maps the SAME pages (refcount bumped, copy-on-extend at the
    boundary), which is what lets far more shared-prefix sessions stay
    resident than there are slots. ``rid`` names the checkpoint-store log
    backing the entry across AW failures ('' = unbacked)."""
    eid: int
    tokens: np.ndarray
    pages: List[int]
    rid: str
    session: Optional[str]
    last_use: float

    @property
    def length(self) -> int:
        return len(self.tokens)


class PagedAWPrefixCache:
    """Per-AW prefix cache over the engine's refcounted page pool.

    Differences from the slot-level ``AWPrefixCache``:
      * entries pin PAGES, not slots — ``take_slot`` always hands out a
        real partition slot and maps the matched entry's pages into it
        (``engine._kv_adopt``: shared full pages + a private boundary
        copy), so ``evictable_count`` is 0 and the worker's free count is
        its true partition free count;
      * entries are multi-adopter: adoption neither truncates nor
        consumes them, and two live requests decoding off the same prefix
        reference the same physical pages;
      * eviction is page-pressure driven and PARTIAL: under pressure the
        LRU entry's tail pages are trimmed first (the entry survives,
        shortened), and the victim's cost is priced by its EXCLUSIVE
        pages — a mostly-shared entry is cheap to drop because its pages
        outlive it with their other holders. A page with refcount > 1 is
        never freed (the pool's decref invariant).
    """

    def __init__(self, aw_id: int, partition, engine, max_tokens: int = 0,
                 min_match: int = 4, release_log=None, stats=None,
                 eid_gen=None, plane=None):
        self.aw_id = aw_id
        self.partition = partition
        self.engine = engine
        self.pool = engine.pages
        self.max_tokens = max(0, max_tokens)
        self.min_match = max(1, min_match)
        self.release_log = release_log or (lambda rid: None)
        self.stats = stats
        self._eid_gen = eid_gen or iter(range(1, 1 << 60)).__next__
        self.plane = plane
        self.entries: Dict[int, PagedPrefixEntry] = {}
        self.index = RadixIndex()
        self.local = PrefixCacheStats()

    # -- index maintenance (local trie + the plane's global one) ------------
    def _index_insert(self, e: PagedPrefixEntry):
        self.index.insert(e.tokens, e.eid)
        if self.plane is not None:
            self.plane.on_index_insert(self.aw_id, e)

    def _index_remove(self, e: PagedPrefixEntry):
        self.index.remove(e.tokens, e.eid)
        if self.plane is not None:
            self.plane.on_index_remove(e)

    # -- capacity view ------------------------------------------------------
    def evictable_count(self) -> int:
        return 0                 # entries hold pages, never slots

    def cached_tokens(self) -> int:
        return sum(e.length for e in self.entries.values())

    def exclusive_pages(self, e: PagedPrefixEntry) -> int:
        return sum(1 for p in e.pages if self.pool.ref[p] == 1)

    def match_len(self, prompt) -> int:
        if prompt is None or len(prompt) < 2:
            return 0
        _, lcp = self.index.match(prompt, set(self.entries.keys()))
        lcp = min(lcp, len(prompt) - 1)
        return lcp if lcp >= self.min_match else 0

    # -- allocation: slot + page-level adoption -----------------------------
    def take_slot(self, prompt, now: float = 0.0) -> Tuple[int, int]:
        """Allocate a partition slot; when the prompt shares >= min_match
        tokens with a cached entry, map the entry's pages into the slot
        (zero KV copied for the shared full pages). The entry stays in
        the cache for the next adopter."""
        slot = self.partition.alloc()
        if prompt is None or len(prompt) < 2:
            return slot, 0
        eid, lcp = self.index.match(prompt, set(self.entries.keys()))
        lcp = min(lcp, len(prompt) - 1)
        if eid < 0 or lcp < self.min_match:
            return slot, 0
        e = self.entries[eid]
        hit = self.engine._kv_adopt(slot, e.pages, min(lcp, e.length))
        if hit < self.min_match:
            # boundary-copy degrade fell under the adoption threshold:
            # roll the shared references back and admit cold
            self.engine._kv_clear_slot(slot)
            return slot, 0
        e.last_use = now
        return slot, hit

    # -- population ---------------------------------------------------------
    def offer(self, slot: int, tokens: np.ndarray, rid: str,
              session: Optional[str], now: float) -> bool:
        """Pin the finished request's pages as a new entry. The slot
        itself is NOT retained — the caller releases it (decref'ing the
        slot's references) and the entry's own references keep the pages
        alive. Duplicates refresh the existing entry instead."""
        self.local.offered += 1
        n = len(tokens)
        if n < 2 or (self.max_tokens and n > self.max_tokens):
            self.local.refused += 1
            return False
        dup = self.index.exact_slot(tokens)
        if dup >= 0 and dup in self.entries:
            self.entries[dup].last_use = now
            self.local.refused += 1
            return False
        while self.max_tokens and self.cached_tokens() + n > self.max_tokens:
            victim = self._pick_victim()
            if victim is None:
                self.local.refused += 1
                return False
            self.engine._kv_free_pages(self.remove_entry(victim.eid))
            if self.stats is not None:
                self.stats.prefix_evictions += 1
        pages = self.engine._kv_snapshot(slot, n)
        if len(pages) < -(-n // self.pool.page_tokens):
            # the slot's mapped extent doesn't cover the claimed prefix
            # (should not happen — defensive roll-back, no leak)
            for pid in pages:
                self.pool.decref(pid)
            self.local.refused += 1
            return False
        e = PagedPrefixEntry(self._eid_gen(), np.asarray(tokens, np.int32),
                             pages, rid, session, now)
        self.entries[e.eid] = e
        self._index_insert(e)
        self.local.cached += 1
        return True

    def insert_restored(self, slot: int, tokens: np.ndarray, rid: str,
                        session: Optional[str], now: float) -> bool:
        return self.offer(slot, tokens, rid, session, now)

    # -- teardown -----------------------------------------------------------
    def forget_slot(self, slot: int):
        """No-op: paged entries are not slot-keyed — an adopter's teardown
        just decrefs its slot's page references (engine._kv_clear_slot)."""

    def remove_entry(self, eid: int, release_log: bool = True) -> List[int]:
        """Drop one entry, decref its pages; returns the page ids whose
        refcount hit 0 (the CALLER scrubs them on device — pages shared
        with live slots or other entries survive untouched)."""
        e = self.entries.pop(eid, None)
        if e is None:
            return []
        self._index_remove(e)
        freed = [p for p in e.pages if self.pool.decref(p)]
        e.pages = []
        if release_log and e.rid:
            self.release_log(e.rid)
        return freed

    def release_all_pages(self) -> List[int]:
        """AW failure path: drop every entry's page references (orphan
        metadata was snapshotted by the plane already). Returns freed
        page ids for the engine to scrub."""
        freed = []
        for e in list(self.entries.values()):
            self._index_remove(e)
            freed += [p for p in e.pages if self.pool.decref(p)]
            e.pages = []
            self._index_insert(e)   # keep metadata addressable until clear()
        return freed

    def clear(self):
        for e in list(self.entries.values()):
            self._index_remove(e)
        self.entries = {}
        self.index = RadixIndex()

    # -- eviction: page-pressure, partial, exclusive-priced -----------------
    def _pick_victim(self) -> Optional[PagedPrefixEntry]:
        """LRU first; among equals the entry with the FEWEST exclusive
        pages (eviction cost is the KV only this entry keeps alive —
        shared pages survive their holder, so a mostly-shared entry is
        nearly free to drop); eid breaks the final tie."""
        if not self.entries:
            return None
        return min(self.entries.values(),
                   key=lambda e: (e.last_use, self.exclusive_pages(e),
                                  e.eid))

    def evict_pages(self) -> List[int]:
        """Free at least one physical page under allocation pressure by
        trimming victims TAIL-FIRST: the LRU entry loses its last page
        (partial-prefix eviction — the shortened entry still serves
        shorter matches) until a page actually frees. Entries trimmed
        below usefulness (< min_match tokens) drop entirely. Returns
        freed page ids for the engine to scrub; [] when nothing more can
        free a page."""
        freed: List[int] = []
        while not freed:
            victim = self._pick_victim()
            if victim is None:
                break
            freed += self._trim_tail(victim)
        return freed

    def _trim_tail(self, e: PagedPrefixEntry) -> List[int]:
        freed: List[int] = []
        self._index_remove(e)
        if e.pages:
            pid = e.pages.pop()
            if self.pool.decref(pid):
                freed.append(pid)
        new_len = min(e.length, len(e.pages) * self.pool.page_tokens)
        e.tokens = np.asarray(e.tokens[:new_len], np.int32)
        if not e.pages or e.length < max(2, self.min_match):
            del self.entries[e.eid]
            freed += [p for p in e.pages if self.pool.decref(p)]
            e.pages = []
            if e.rid:
                self.release_log(e.rid)
        else:
            self._index_insert(e)
        if self.stats is not None:
            self.stats.prefix_evictions += 1
        return freed

    # -- metrics ------------------------------------------------------------
    def snapshot(self) -> dict:
        return {"entries": len(self.entries),
                "shared": sum(1 for e in self.entries.values()
                              if any(self.pool.ref[p] > 1
                                     for p in e.pages)),
                "cached_tokens": self.cached_tokens(),
                **self.local.snapshot()}


class GlobalPrefixIndex:
    """Gateway-level radix index over EVERY AW's cached prefixes: one trie
    whose entries are global eids mapped to their home AW. The per-AW
    indexes stay authoritative for adoption; this one answers the routing
    question — \"which AW, cluster-wide, holds the longest cached prefix
    of this prompt?\" — in one lookup instead of a per-AW scan, and is
    what prefix migration consults for the source entry."""

    def __init__(self):
        self.index = RadixIndex()
        self.home: Dict[int, int] = {}        # eid -> aw_id

    def insert(self, tokens, eid: int, aw_id: int):
        self.index.insert(tokens, eid)
        self.home[eid] = aw_id

    def remove(self, tokens, eid: int):
        self.index.remove(tokens, eid)
        self.home.pop(eid, None)

    def match(self, prompt) -> Tuple[int, int, int]:
        """(eid, home aw_id, lcp) of the best cluster-wide match, or
        (-1, -1, 0)."""
        eid, lcp = self.index.match(prompt, set(self.home.keys()))
        return eid, self.home.get(eid, -1), lcp


class PrefixCachePlane:
    """Engine-level coordinator: attaches an ``AWPrefixCache`` (or, on
    paged engines, a ``PagedAWPrefixCache``) to every AttentionWorker,
    owns the offer/forget lifecycle hooks the engine calls, and carries
    dead AWs' cached prefixes across failover via the checkpoint store.

    On paged engines with ``prefix_global_index`` the plane additionally
    maintains one cluster-wide radix index mirroring every per-AW trie
    and installs itself into the gateway's placement path: arrivals route
    to the AW holding their best cached prefix anywhere in the cluster,
    and (with ``prefix_migrate``) hot prefixes whose home AW is full
    migrate to a free AW by replaying their committed checkpoint
    segments — the same bulk-segment path failover restoration uses."""

    def __init__(self, engine, max_slots: int, max_tokens: int = 0,
                 min_match: int = 4):
        self.engine = engine
        self.orphans: List[PrefixEntry] = []
        self._log_seq = 0        # unique suffix for adopted-log keys
        self.min_match = max(1, min_match)
        self.paged = engine.pages is not None
        self._eid = 0            # plane-owned: eids unique cluster-wide
        self.global_index: Optional[GlobalPrefixIndex] = None
        if self.paged and engine.ecfg.prefix_global_index:
            self.global_index = GlobalPrefixIndex()
        for w in engine.aws:
            if self.paged:
                w.prefix_cache = PagedAWPrefixCache(
                    w.aw_id, w.slots, engine, max_tokens=max_tokens,
                    min_match=min_match, release_log=engine.store.release,
                    stats=engine.gateway.stats, eid_gen=self._next_eid,
                    plane=self)
            else:
                w.prefix_cache = AWPrefixCache(
                    w.slots, max_slots, max_tokens, min_match=min_match,
                    release_log=engine.store.release,
                    stats=engine.gateway.stats)
        if self.global_index is not None:
            from repro.serving.gateway import SessionAffinityPolicy
            pol = engine.gateway.policy
            if isinstance(pol, SessionAffinityPolicy):
                pol.global_router = self.route
            engine.gateway.match_probe = self.global_match_len

    # -- global-index maintenance (called by the per-AW caches) -------------
    def _next_eid(self) -> int:
        self._eid += 1
        return self._eid

    def on_index_insert(self, aw_id: int, e: PagedPrefixEntry):
        if self.global_index is not None:
            self.global_index.insert(e.tokens, e.eid, aw_id)

    def on_index_remove(self, e: PagedPrefixEntry):
        if self.global_index is not None:
            self.global_index.remove(e.tokens, e.eid)

    # -- cluster-wide routing ------------------------------------------------
    def global_match_len(self, prompt) -> int:
        """Gateway admission probe: longest cached prefix of ``prompt``
        anywhere in the cluster (one trie walk instead of a per-AW scan).
        Used only for token accounting — adoption still happens against
        the chosen AW's own cache."""
        if self.global_index is None or prompt is None or len(prompt) < 2:
            return 0
        _, _, lcp = self.global_index.match(prompt)
        lcp = min(lcp, len(prompt) - 1)
        return lcp if lcp >= self.min_match else 0

    def route(self, workers, prompt) -> Optional[int]:
        """SessionAffinityPolicy's ``global_router``: the AW holding the
        best cluster-wide prefix match for this prompt, when it can take
        the request. If the home AW has no slot headroom and
        ``prefix_migrate`` is on, the entry is migrated to a free AW via
        checkpoint replay and the request routes there instead."""
        eng = self.engine
        if self.global_index is None or prompt is None or len(prompt) < 2:
            return None
        eid, aw_id, lcp = self.global_index.match(prompt)
        lcp = min(lcp, len(prompt) - 1)
        if eid < 0 or aw_id < 0 or lcp < self.min_match:
            return None
        w = eng.aws[aw_id]
        if w.alive and w.has_capacity():
            eng.gateway.stats.prefix_global_hits += 1
            return aw_id
        if eng.ecfg.prefix_migrate:
            dst = self._migrate(eid, aw_id, now=float(eng.steps))
            if dst is not None:
                eng.gateway.stats.prefix_global_hits += 1
                return dst
        return None

    def _migrate(self, eid: int, src_aw: int, now: float) -> Optional[int]:
        """Move one cached prefix to an AW with headroom by replaying its
        committed store segments into fresh pages there (pages never move
        between AW partitions — the checkpoint path is the only
        cross-failure-domain channel). On success the destination entry
        adopts the store log and the source entry is dropped WITHOUT
        releasing it."""
        eng = self.engine
        src = eng.aws[src_aw].prefix_cache
        e = src.entries.get(eid) if src is not None else None
        if e is None or not e.rid or not eng.ecfg.checkpoint:
            return None
        best, best_free = None, -1
        for w in eng.aws:
            if not w.alive or w.aw_id == src_aw or not w.has_capacity():
                continue
            if w.slots.free_count() == 0:
                continue
            fp = eng.pages.free_pages(w.aw_id)
            if fp > best_free:
                best, best_free = w, fp
        if best is None:
            return None
        if not self._materialize(best, e.tokens, e.rid, e.session, now):
            return None
        # the destination entry now backs the rid log; drop the source
        # entry but keep the log alive
        eng._kv_free_pages(src.remove_entry(eid, release_log=False))
        eng.gateway.stats.prefix_migrated += 1
        eng._note_request_event(
            "prefix_migrated", e.rid, now,
            f"aw{src_aw}->aw{best.aw_id}, {e.length} tokens"
            + (f", session={e.session}" if e.session else ""))
        return best.aw_id

    def _materialize(self, target, tokens, rid: str, session, now: float
                     ) -> bool:
        """Rebuild a checkpointed prefix on ``target`` through a scratch
        slot: allocate a free partition slot, replay the committed token
        segments into freshly allocated pages, offer the result to the
        target's cache (which pins its own page references), then release
        the scratch slot either way. Shared by prefix migration and paged
        orphan restoration."""
        eng = self.engine
        committed, _tv, segs = eng.store.restore_request(rid)
        n = min(len(tokens), committed + 1)
        if n < 2 or any(t not in segs for t in range(n)):
            return False
        slot = target.slots.alloc()
        ok = False
        try:
            eng._kv_clear_slot(slot)
            try:
                eng._kv_ensure(slot, n)
            except RuntimeError:
                return False      # page pool exhausted on target
            cache = eng.cache
            for t in range(n):
                cache = eng.layout.write_token_segment(cache, slot, t,
                                                       segs[t])
            eng.cache = cache
            ok = bool(target.prefix_cache.offer(
                slot, np.asarray(tokens[:n], np.int32), rid, session, now))
            if ok:
                eng.store.reassign(rid, target.aw_id)
        finally:
            eng._kv_clear_slot(slot)
            target.slots.release(slot)
        return ok

    # -- completion: adopt the slot ----------------------------------------
    def offer(self, r) -> bool:
        """Cache a finished request's resident prefix. The cached length
        is clamped to the store's commit watermark (what restoration can
        actually rebuild); on checkpoint=False engines the resident extent
        is trusted but the entry is not failure-restorable."""
        eng = self.engine
        aw = eng.aws[r._aw]
        if aw.prefix_cache is None:
            return False
        n = r.pos                       # positions [0, pos) hold KV
        rid = ""
        if eng.ecfg.checkpoint:
            n = min(n, eng.store.committed_token(r.rid) + 1)
        if n < 2:
            return False
        if eng.ecfg.checkpoint:
            # the log outlives the request under a reserved key, so the
            # original rid stays reusable for a fresh submission
            rid = f"~prefix{self._log_seq}:{r.rid}"
            self._log_seq += 1
            eng.store.rename(r.rid, rid)
        seq = np.concatenate(
            [np.asarray(r.prompt, np.int32),
             np.asarray(r.tokens, np.int32)])[:n]
        now = r.t_done if r.t_done >= 0 else float(eng.steps)
        ok = aw.prefix_cache.offer(r.slot, seq, rid, r.session, now)
        if not ok and rid:
            # refused: hand the log back so the caller's release path
            # (store.release(r.rid)) finds it under the original key
            eng.store.rename(rid, r.rid)
        return ok

    def forget_slot(self, aw_id: int, slot: int):
        cache = self.engine.aws[aw_id].prefix_cache
        if cache is not None:
            cache.forget_slot(slot)

    # -- failover: orphan + restore ----------------------------------------
    def note_aw_failed(self, aw_id: int):
        """Snapshot the dying AW's cache *before* worker.fail() clears it:
        checkpoint-backed non-live entries become restorable orphans; the
        rest release their store logs (a live entry's adopter already
        carries the prefix in its own log)."""
        eng = self.engine
        cache = eng.aws[aw_id].prefix_cache
        if cache is None:
            return
        restorable = eng.ecfg.checkpoint and eng.ecfg.prefix_restore
        for e in list(cache.entries.values()):
            # paged entries have no live flag — adoption never consumes
            # them, so every rid-backed entry is a restoration candidate
            if restorable and e.rid and not getattr(e, "live", False):
                self.orphans.append(e)
            elif e.rid:
                eng.store.release(e.rid)

    def restore_orphans(self, now: float = 0.0) -> int:
        """§6.2 applied to cache state: inject each orphaned prefix's
        committed segments into a fresh slot on a healthy AW (the
        session's re-pinned home when affinity placement is active) and
        re-index it there. Pure host-side writes — zero new jit traces.
        Orphans that cannot land (no free partition slot anywhere, or a
        refused offer) release their store log instead of leaking."""
        eng = self.engine
        restored = 0
        orphans, self.orphans = self.orphans, []
        for e in orphans:
            target = self._pick_target(e, now)
            if target is None:
                eng.store.release(e.rid)
                continue
            if self.paged:
                # replay through a scratch slot into fresh pages on the
                # target's partition; the offered entry pins the pages
                if self._materialize(target, e.tokens, e.rid, e.session,
                                     now):
                    restored += 1
                    eng.gateway.stats.prefix_restored += 1
                    eng._note_request_event(
                        "prefix_restored", e.rid, now,
                        f"aw{target.aw_id}, {e.length} tokens"
                        + (f", session={e.session}" if e.session else ""))
                    if eng.telemetry is not None:
                        eng.telemetry.registry.observe(
                            "prefix.restored_len", e.length)
                else:
                    eng.store.release(e.rid)
                continue
            committed, _tv, segs = eng.store.restore_request(e.rid)
            n = min(e.length, committed + 1)
            if n < 2 or any(t not in segs for t in range(n)):
                target = None
            if target is None:
                eng.store.release(e.rid)
                continue
            slot = target.slots.alloc()
            cache = eng.layout.clear_slot(eng.cache, slot)
            for t in range(n):
                cache = eng.layout.write_token_segment(cache, slot, t,
                                                       segs[t])
            eng.cache = cache
            eng.store.reassign(e.rid, target.aw_id)
            if target.prefix_cache.insert_restored(
                    slot, e.tokens[:n], e.rid, e.session, now):
                restored += 1
                eng.gateway.stats.prefix_restored += 1
                eng._note_request_event(
                    "prefix_restored", e.rid, now,
                    f"aw{target.aw_id}, {n} tokens"
                    + (f", session={e.session}" if e.session else ""))
                if eng.telemetry is not None:
                    eng.telemetry.registry.observe(
                        "prefix.restored_len", n)
            else:
                eng.cache = eng.layout.clear_slot(eng.cache, slot)
                target.slots.release(slot)
                eng.store.release(e.rid)
        return restored

    def _pick_target(self, e: PrefixEntry, now: float):
        """Failover home for an orphaned prefix: the affinity policy's
        (re-pinned) choice for the entry's session when available, else
        the AW with the most free partition slots. Restoration never
        evicts the target's own entries — it only takes genuinely free
        slots."""
        from repro.serving.gateway import SessionAffinityPolicy
        eng = self.engine
        pol = eng.gateway.policy
        if e.session and isinstance(pol, SessionAffinityPolicy):
            aw_id = pol(eng.gateway.workers, e.session, now=now)
            if aw_id is not None:
                w = eng.aws[aw_id]
                if w.alive and w.slots.free_count() > 0:
                    return w
        best, best_free = None, 0
        for w in eng.aws:
            if w.alive and w.slots.free_count() > best_free:
                best, best_free = w, w.slots.free_count()
        return best

    # -- metrics ------------------------------------------------------------
    def snapshot(self) -> dict:
        per_aw = {}
        for w in self.engine.aws:
            if w.prefix_cache is not None:
                per_aw[w.aw_id] = w.prefix_cache.snapshot()
        return per_aw
