"""Cluster Gateway: admission control, the waiting queue, and AW placement.

The Gateway is the front door of the serving stack (paper Fig. 5's cluster
coordinator, request-plane half): every request — fresh arrivals and
requests preempted by an AW failure alike — enters a FIFO waiting queue and
is admitted onto an AttentionWorker by a pluggable placement policy. A
request that cannot be placed (no healthy AW with a free slot) stays at the
head of the queue and is retried on the next scheduler tick; it is never
dropped.

Placement policies (select a healthy AW with free capacity, or None):
  * ``least_loaded``     — most free slots wins (default; ties -> lowest id)
  * ``round_robin``      — cycle over healthy AWs, skipping full ones
  * ``session_affinity`` — stable hash of the session prefix of the request
    id (``rid.rsplit('-', 1)[0]``), falling back to least-loaded when the
    home AW is dead or full. Keeps a session's requests co-located so later
    PRs can exploit prefix-cache locality.

Recovery entries (``recovery=True``) carry no prompt work to redo: the
scheduler restores their committed KV from the checkpoint store instead of
re-prefilling. They re-enter at the *front* of the queue (they are older
than anything waiting behind them).
"""
from __future__ import annotations

import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.workers import AttentionWorker


@dataclass
class QueuedRequest:
    rid: str
    prompt: np.ndarray
    max_new: int
    frames: Optional[np.ndarray] = None
    t_enqueue: float = 0.0
    recovery: bool = False          # re-admission of a preempted request
    retries: int = 0                # ticks spent blocked at the queue head


# --------------------------------------------------------------------------
# placement policies
# --------------------------------------------------------------------------

class LeastLoadedPolicy:
    """Most free slots wins; ties break toward the lowest AW id (matches the
    original engine's admission behaviour)."""

    def __call__(self, workers: List[AttentionWorker],
                 rid: str) -> Optional[int]:
        best, best_free = None, 0
        for w in workers:
            f = w.free_slots()
            if f > best_free:
                best, best_free = w.aw_id, f
        return best


class RoundRobinPolicy:
    """Cycle over AWs regardless of load, skipping dead/full ones."""

    def __init__(self):
        self._next = 0

    def __call__(self, workers: List[AttentionWorker],
                 rid: str) -> Optional[int]:
        n = len(workers)
        for i in range(n):
            w = workers[(self._next + i) % n]
            if w.has_capacity():
                self._next = (w.aw_id + 1) % n
                return w.aw_id
        return None


class SessionAffinityPolicy:
    """Stable-hash the session prefix of the rid onto the AW ring; fall back
    to least-loaded when the home AW cannot take the request."""

    def __init__(self):
        self._fallback = LeastLoadedPolicy()

    @staticmethod
    def session_key(rid: str) -> str:
        return rid.rsplit("-", 1)[0]

    def __call__(self, workers: List[AttentionWorker],
                 rid: str) -> Optional[int]:
        home = zlib.crc32(self.session_key(rid).encode()) % len(workers)
        if workers[home].has_capacity():
            return home
        return self._fallback(workers, rid)


PLACEMENT_POLICIES = {
    "least_loaded": LeastLoadedPolicy,
    "round_robin": RoundRobinPolicy,
    "session_affinity": SessionAffinityPolicy,
}


@dataclass
class GatewayStats:
    enqueued: int = 0
    admitted: int = 0
    requeued: int = 0               # recovery re-admissions queued
    blocked_ticks: int = 0          # head-of-queue retries
    queue_delay: Dict[str, float] = field(default_factory=dict)


class Gateway:
    """Admission + waiting queue + placement over the AW pool."""

    def __init__(self, workers: List[AttentionWorker],
                 policy="least_loaded"):
        self.workers = workers
        if isinstance(policy, str):
            policy = PLACEMENT_POLICIES[policy]()
        self.policy = policy
        self.queue: Deque[QueuedRequest] = deque()
        self.stats = GatewayStats()
        # token-based admission (chunked-prefill plane): cap on prompt
        # tokens admitted but not yet prefilled. ``prefill_load`` is a
        # probe supplied by the engine (the plane's outstanding_tokens);
        # cap 0 = slot-bound admission only.
        self.prefill_token_cap: int = 0
        self.prefill_load = None

    # -- queue management ---------------------------------------------------
    def enqueue(self, rid: str, prompt: np.ndarray, max_new: int, *,
                now: float = 0.0, frames: Optional[np.ndarray] = None):
        self.queue.append(QueuedRequest(
            rid, np.asarray(prompt, np.int32), max_new, frames, now))
        self.stats.enqueued += 1

    def requeue_recovery(self, entries: List[QueuedRequest]):
        """Preempted/recovered requests re-enter at the FRONT of the queue
        (they are older than everything waiting behind them)."""
        for q in reversed(entries):
            q.recovery = True
            self.queue.appendleft(q)
            self.stats.requeued += 1

    def depth(self) -> int:
        return len(self.queue)

    def drop(self, rid: str) -> bool:
        """Remove a still-queued request (admission refused by the caller)."""
        for q in list(self.queue):
            if q.rid == rid:
                self.queue.remove(q)
                return True
        return False

    # -- placement ----------------------------------------------------------
    def choose_aw(self, rid: str = "") -> Optional[int]:
        return self.policy(self.workers, rid)

    def admit(self, now: float = 0.0
              ) -> List[Tuple[QueuedRequest, int, int]]:
        """Pop FIFO while placement succeeds, reserving a slot on the
        chosen AW per admission (so the policy sees live free counts).
        Head-of-line blocking is deliberate: a request is never overtaken,
        only retried. Returns (entry, aw_id, slot) triples."""
        admitted = []
        new_tokens = 0                 # fresh prompt tokens admitted now
        while self.queue:
            head = self.queue[0]
            # admission is token-aware, not just slot-aware: a free slot
            # is not enough if the prefill plane is already saturated with
            # outstanding prompt tokens. Recovery entries bypass the cap —
            # their committed prefix restores from the store. The first
            # admission is always allowed so an over-cap prompt cannot
            # deadlock the queue.
            if self.prefill_token_cap and not head.recovery:
                load = new_tokens + \
                    (self.prefill_load() if self.prefill_load else 0)
                if load > 0 and \
                        load + len(head.prompt) > self.prefill_token_cap:
                    head.retries += 1
                    self.stats.blocked_ticks += 1
                    break
            aw = self.choose_aw(head.rid)
            if aw is None:
                head.retries += 1
                self.stats.blocked_ticks += 1
                break
            self.queue.popleft()
            if not head.recovery:
                new_tokens += len(head.prompt)
            slot = self.workers[aw].slots.alloc()
            self.stats.admitted += 1
            # total time spent waiting at the gateway, summed over spells
            # (a recovery re-admission is a second spell for the same rid)
            self.stats.queue_delay[head.rid] = \
                self.stats.queue_delay.get(head.rid, 0.0) + \
                (now - head.t_enqueue)
            admitted.append((head, aw, slot))
        return admitted
