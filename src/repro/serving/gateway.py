"""Cluster Gateway: multi-class admission, per-class waiting queues, and AW
placement.

The Gateway is the front door of the serving stack (paper Fig. 5's cluster
coordinator, request-plane half). Since the typed request API
(serving/api.py) it is a **multi-class admission plane**: every request —
fresh arrivals and requests preempted by an AW failure or a planned
eviction alike — enters the waiting queue of its SLO class
(``interactive`` / ``standard`` / ``batch``), and admission services the
class heads by *weighted dequeue* (interactive 4 : standard 2 : batch 1
credits per round) instead of a single FIFO. Within a class, ordering is
**deadline-aware**: entries carrying an earlier first-token deadline sort
ahead of later/undeadlined ones (stable for ties), and recovery entries
always sit at the very front (they are older than anything behind them).
A class head that cannot be placed blocks only its own class — it is
retried next tick, never dropped or overtaken within its class.

Placement policies (select a healthy AW with free capacity, or None):
  * ``least_loaded``     — most free slots wins (default; ties -> lowest id)
  * ``round_robin``      — cycle over healthy AWs, skipping full ones
  * ``session_affinity`` — stable hash of the request's session key (the
    explicit ``session`` field when given, else the session prefix of the
    request id, ``rid.rsplit('-', 1)[0]``), falling back to least-loaded
    when the home AW is dead or full.

Preempt-and-requeue: when an *interactive* head cannot be placed, the
Gateway consults the engine-installed ``preemptor`` hook, which may
checkpoint a batch-class victim out of its slot (via the bulk-segment
path) and requeue it as a recovery entry — planned eviction rides the same
restore machinery as crash recovery, so the victim later resumes from its
committed cursor, not from token 0.

Recovery entries (``recovery=True``) carry no prompt work to redo: the
scheduler restores their committed KV from the checkpoint store instead of
re-prefilling. They re-enter at the *front* of their class queue.
"""
from __future__ import annotations

import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.api import (CLASS_WEIGHTS, PREEMPTING_CLASSES,
                               SLO_CLASSES, STANDARD, SamplingParams)
from repro.serving.workers import AttentionWorker


@dataclass
class QueuedRequest:
    rid: str
    prompt: np.ndarray
    max_new: int
    frames: Optional[np.ndarray] = None
    t_enqueue: float = 0.0
    recovery: bool = False          # re-admission of a preempted request
    retries: int = 0                # ticks spent blocked at the queue head
    slo_class: str = STANDARD
    deadline: Optional[float] = None   # virtual-clock first-token deadline
    sampling: Optional[SamplingParams] = None
    session: Optional[str] = None      # affinity key for placement
    deadline_flagged: bool = False     # deadline_missed already emitted

    @property
    def deadline_key(self) -> float:
        return self.deadline if self.deadline is not None else float("inf")

    @property
    def placement_key(self) -> str:
        """Affinity key for placement: the explicit session verbatim, else
        the session prefix of the rid (``sess-0``/``sess-1`` share
        ``sess``). Derivation happens HERE, not in the policy, so an
        explicit session key containing '-' is never truncated."""
        if self.session is not None:
            return self.session
        return SessionAffinityPolicy.session_key(self.rid)


# --------------------------------------------------------------------------
# placement policies
# --------------------------------------------------------------------------

class LeastLoadedPolicy:
    """Most free slots wins; ties break toward the lowest AW id (matches the
    original engine's admission behaviour)."""

    def __call__(self, workers: List[AttentionWorker],
                 rid: str) -> Optional[int]:
        best, best_free = None, 0
        for w in workers:
            f = w.free_slots()
            if f > best_free:
                best, best_free = w.aw_id, f
        return best


class RoundRobinPolicy:
    """Cycle over AWs regardless of load, skipping dead/full ones."""

    def __init__(self):
        self._next = 0

    def __call__(self, workers: List[AttentionWorker],
                 rid: str) -> Optional[int]:
        n = len(workers)
        for i in range(n):
            w = workers[(self._next + i) % n]
            if w.has_capacity():
                self._next = (w.aw_id + 1) % n
                return w.aw_id
        return None


class SessionAffinityPolicy:
    """Stable-hash the placement key verbatim onto the AW ring; fall back
    to least-loaded when the home AW cannot take the request. The caller
    (``QueuedRequest.placement_key``) supplies either the explicit session
    or the rid-derived session prefix — the policy never truncates."""

    def __init__(self):
        self._fallback = LeastLoadedPolicy()

    @staticmethod
    def session_key(rid: str) -> str:
        """Session prefix of a request id (``sess-3`` -> ``sess``)."""
        return rid.rsplit("-", 1)[0]

    def __call__(self, workers: List[AttentionWorker],
                 key: str) -> Optional[int]:
        home = zlib.crc32(key.encode()) % len(workers)
        if workers[home].has_capacity():
            return home
        return self._fallback(workers, key)


PLACEMENT_POLICIES = {
    "least_loaded": LeastLoadedPolicy,
    "round_robin": RoundRobinPolicy,
    "session_affinity": SessionAffinityPolicy,
}


@dataclass
class GatewayStats:
    enqueued: int = 0
    admitted: int = 0
    requeued: int = 0               # recovery re-admissions queued
    blocked_ticks: int = 0          # head-of-queue retries
    preemptions: int = 0            # victims evicted to place a higher class
    queue_delay: Dict[str, float] = field(default_factory=dict)
    # per-class lifecycle counters:
    #   class -> {enqueued, admitted, preempted, cancelled, deadline_missed}
    by_class: Dict[str, Dict[str, int]] = field(default_factory=dict)

    def bump(self, slo_class: str, key: str, n: int = 1):
        c = self.by_class.setdefault(slo_class, {})
        c[key] = c.get(key, 0) + n

    def class_count(self, slo_class: str, key: str) -> int:
        return self.by_class.get(slo_class, {}).get(key, 0)


class Gateway:
    """Multi-class admission + per-class waiting queues + placement over
    the AW pool."""

    def __init__(self, workers: List[AttentionWorker],
                 policy="least_loaded"):
        self.workers = workers
        if isinstance(policy, str):
            policy = PLACEMENT_POLICIES[policy]()
        self.policy = policy
        self.queues: Dict[str, Deque[QueuedRequest]] = {
            cls: deque() for cls in SLO_CLASSES}
        self.stats = GatewayStats()
        # token-based admission (chunked-prefill plane): cap on prompt
        # tokens admitted but not yet prefilled. ``prefill_load`` is a
        # probe supplied by the engine (the plane's outstanding_tokens);
        # cap 0 = slot-bound admission only.
        self.prefill_token_cap: int = 0
        self.prefill_load = None
        # engine-installed hook: (blocked interactive head, now) -> bool.
        # True means a victim's slot was freed (preempt-and-requeue) and
        # placement should be retried for the head.
        self.preemptor = None

    # -- queue management ---------------------------------------------------
    @property
    def queue(self) -> Tuple[QueuedRequest, ...]:
        """Read-only combined view in class-priority order (back-compat:
        the single-FIFO era exposed the deque directly)."""
        return tuple(q for cls in SLO_CLASSES for q in self.queues[cls])

    def enqueue(self, rid: str, prompt: np.ndarray, max_new: int, *,
                now: float = 0.0, frames: Optional[np.ndarray] = None,
                slo_class: str = STANDARD,
                deadline: Optional[float] = None,
                sampling: Optional[SamplingParams] = None,
                session: Optional[str] = None):
        if slo_class not in SLO_CLASSES:
            raise ValueError(f"unknown slo_class {slo_class!r}: expected "
                             f"one of {SLO_CLASSES}")
        entry = QueuedRequest(rid, np.asarray(prompt, np.int32), max_new,
                              frames, now, slo_class=slo_class,
                              deadline=deadline, sampling=sampling,
                              session=session)
        self._insert(entry)
        self.stats.enqueued += 1
        self.stats.bump(slo_class, "enqueued")

    def _insert(self, entry: QueuedRequest):
        """Deadline-aware, stable insertion: after every recovery entry,
        after any head that has already been blocked (``retries > 0`` — a
        deadlined newcomer must not overtake it, or a cap-blocked large
        prompt could be starved forever by a steady deadlined stream), and
        after every entry with an equal-or-earlier deadline (undeadlined =
        +inf, i.e. plain FIFO among themselves)."""
        q = self.queues[entry.slo_class]
        i = len(q)
        for j, e in enumerate(q):
            if e.recovery or e.retries > 0:
                continue
            if e.deadline_key > entry.deadline_key:
                i = j
                break
        q.insert(i, entry)

    def requeue_recovery(self, entries: List[QueuedRequest]):
        """Preempted/recovered requests re-enter at the FRONT of their
        class queue (they are older than everything waiting behind them)."""
        for e in reversed(entries):
            e.recovery = True
            self.queues[e.slo_class].appendleft(e)
            self.stats.requeued += 1

    def depth(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def find(self, rid: str) -> Optional[QueuedRequest]:
        for q in self.queues.values():
            for e in q:
                if e.rid == rid:
                    return e
        return None

    def drop(self, rid: str) -> Optional[QueuedRequest]:
        """Remove a still-queued request from whichever class queue holds
        it (admission refused, cancellation, or a stale recovery entry).
        Returns the removed entry, or None. In-flight requests are torn
        down by ``engine.release_request`` / ``engine.cancel_request``,
        which also free the owning AW's slot, pending checkpoint WRs, and
        prefill-stream state."""
        e = self.find(rid)
        if e is not None:
            self.queues[e.slo_class].remove(e)
        return e

    # -- placement ----------------------------------------------------------
    def choose_aw(self, rid: str = "") -> Optional[int]:
        return self.policy(self.workers, rid)

    def admit(self, now: float = 0.0
              ) -> List[Tuple[QueuedRequest, int, int]]:
        """Weighted dequeue over the class queues: each round hands every
        class its weight in admission credits (interactive first), popping
        that class's head while placement succeeds and reserving a slot on
        the chosen AW per admission (so the policy sees live free counts).
        Head-of-line blocking is *per class*: a blocked head stalls only
        its own class for this tick — it is retried, never overtaken
        within the class. A blocked interactive head may trigger the
        preempt-and-requeue hook to evict a batch victim first. Returns
        (entry, aw_id, slot) triples."""
        admitted = []
        new_tokens = 0                 # fresh prompt tokens admitted now
        blocked = set()
        while True:
            progressed = False
            for cls in SLO_CLASSES:
                if cls in blocked:
                    continue
                q = self.queues[cls]
                for _ in range(CLASS_WEIGHTS[cls]):
                    if not q:
                        break
                    head = q[0]
                    # admission is token-aware, not just slot-aware: a free
                    # slot is not enough if the prefill plane is already
                    # saturated with outstanding prompt tokens. Recovery
                    # entries bypass the cap — their committed prefix
                    # restores from the store. The first admission is
                    # always allowed so an over-cap prompt cannot deadlock
                    # the queue.
                    if self.prefill_token_cap and not head.recovery:
                        load = new_tokens + \
                            (self.prefill_load() if self.prefill_load else 0)
                        if load > 0 and \
                                load + len(head.prompt) > \
                                self.prefill_token_cap:
                            head.retries += 1
                            self.stats.blocked_ticks += 1
                            blocked.add(cls)
                            break
                    aw = self.choose_aw(head.placement_key)
                    if aw is None and cls in PREEMPTING_CLASSES and \
                            self.preemptor is not None:
                        # preempt-and-requeue: evict a batch victim (its KV
                        # is committed to the store, its slot freed, and it
                        # re-enters its class queue as a recovery entry);
                        # stats.preemptions is bumped by preempt_request
                        # itself, so direct/policy-driven evictions count
                        # in the same place as hook-driven ones
                        if self.preemptor(head, now):
                            aw = self.choose_aw(head.placement_key)
                    if aw is None:
                        head.retries += 1
                        self.stats.blocked_ticks += 1
                        blocked.add(cls)
                        break
                    q.popleft()
                    if not head.recovery:
                        new_tokens += len(head.prompt)
                    slot = self.workers[aw].slots.alloc()
                    self.stats.admitted += 1
                    self.stats.bump(cls, "admitted")
                    # total time spent waiting at the gateway, summed over
                    # spells (a recovery re-admission is a second spell for
                    # the same rid)
                    self.stats.queue_delay[head.rid] = \
                        self.stats.queue_delay.get(head.rid, 0.0) + \
                        (now - head.t_enqueue)
                    admitted.append((head, aw, slot))
                    progressed = True
            if not progressed:
                break
        return admitted
