"""Cluster Gateway: multi-class admission, per-class waiting queues, and AW
placement.

The Gateway is the front door of the serving stack (paper Fig. 5's cluster
coordinator, request-plane half). Since the typed request API
(serving/api.py) it is a **multi-class admission plane**: every request —
fresh arrivals and requests preempted by an AW failure or a planned
eviction alike — enters the waiting queue of its SLO class
(``interactive`` / ``standard`` / ``batch``), and admission services the
class heads by *weighted dequeue* (interactive 4 : standard 2 : batch 1
credits per round) instead of a single FIFO. Within a class, ordering is
**deadline-aware**: entries carrying an earlier first-token deadline sort
ahead of later/undeadlined ones (stable for ties), and recovery entries
always sit at the very front (they are older than anything behind them).
A class head that cannot be placed blocks only its own class — it is
retried next tick, never dropped or overtaken within its class.

Placement policies (select a healthy AW with free capacity, or None):
  * ``least_loaded``     — most free slots wins (default; ties -> lowest id)
  * ``round_robin``      — cycle over healthy AWs, skipping full ones
  * ``session_affinity`` — session-sticky pinning, prefix-cache aware: a
    session's first placement picks the AW holding the longest cached
    prefix of the prompt (else the stable hash of the session key — the
    explicit ``session`` field when given, else the rid's session prefix
    ``rid.rsplit('-', 1)[0]``) and pins the session there. A full home
    spills to least-loaded per-request; a dead home re-pins the session
    (``session_repinned`` event). Free capacity counts the prefix
    cache's evictable slots, and admission adopts a matching cached
    prefix by slot reference (``QueuedRequest.prefix_hit``).

Preempt-and-requeue: when an *interactive* head cannot be placed, the
Gateway consults the engine-installed ``preemptor`` hook, which may
checkpoint a batch-class victim out of its slot (via the bulk-segment
path) and requeue it as a recovery entry — planned eviction rides the same
restore machinery as crash recovery, so the victim later resumes from its
committed cursor, not from token 0.

Recovery entries (``recovery=True``) carry no prompt work to redo: the
scheduler restores their committed KV from the checkpoint store instead of
re-prefilling. They re-enter at the *front* of their class queue.
"""
from __future__ import annotations

import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.orchestrator import WorkerEvent
from repro.serving.api import (CLASS_WEIGHTS, PREEMPTING_CLASSES,
                               SLO_CLASSES, STANDARD, SamplingParams)
from repro.serving.workers import AttentionWorker


@dataclass
class QueuedRequest:
    rid: str
    prompt: np.ndarray
    max_new: int
    frames: Optional[np.ndarray] = None
    t_enqueue: float = 0.0
    recovery: bool = False          # re-admission of a preempted request
    retries: int = 0                # ticks spent blocked at the queue head
    slo_class: str = STANDARD
    deadline: Optional[float] = None   # virtual-clock first-token deadline
    completion_deadline: Optional[float] = None  # last-token deadline
    sampling: Optional[SamplingParams] = None
    session: Optional[str] = None      # affinity key for placement
    deadline_flagged: bool = False     # deadline_missed already emitted
    completion_flagged: bool = False   # completion overrun already emitted
    prefix_hit: int = 0             # tokens adopted from the prefix cache
    #                                 at placement (0 = cold admission)

    @property
    def deadline_key(self) -> float:
        return self.deadline if self.deadline is not None else float("inf")

    @property
    def placement_key(self) -> str:
        """Affinity key for placement: the explicit session verbatim, else
        the session prefix of the rid (``sess-0``/``sess-1`` share
        ``sess``). Derivation happens HERE, not in the policy, so an
        explicit session key containing '-' is never truncated."""
        if self.session is not None:
            return self.session
        return SessionAffinityPolicy.session_key(self.rid)


# --------------------------------------------------------------------------
# placement policies
# --------------------------------------------------------------------------

class LeastLoadedPolicy:
    """Most free slots wins; ties break toward the lowest AW id (matches the
    original engine's admission behaviour)."""

    def __call__(self, workers: List[AttentionWorker], rid: str,
                 prompt=None, now: float = 0.0) -> Optional[int]:
        best, best_free = None, 0
        for w in workers:
            f = w.free_slots()
            if f > best_free:
                best, best_free = w.aw_id, f
        return best


class RoundRobinPolicy:
    """Cycle over AWs regardless of load, skipping dead/full ones."""

    def __init__(self):
        self._next = 0

    def __call__(self, workers: List[AttentionWorker], rid: str,
                 prompt=None, now: float = 0.0) -> Optional[int]:
        n = len(workers)
        for i in range(n):
            w = workers[(self._next + i) % n]
            if w.has_capacity():
                self._next = (w.aw_id + 1) % n
                return w.aw_id
        return None


class SessionAffinityPolicy:
    """Session-sticky placement with prefix-cache awareness.

    A session's first placement chooses its home — the AW holding the
    longest cached prefix of the prompt when the prefix-cache plane is on,
    else the stable hash of the key onto the AW ring — and *pins* the
    session there, so every later turn lands where its KV already lives.
    A pinned-but-full home spills to least-loaded for that request only
    (the pin survives: the session returns home when capacity frees). A
    pinned-but-**dead** home re-pins the session to the healthy AW the
    same choice rule selects, and emits a ``session_repinned`` event —
    the stale pin can never strand a session on a failed worker. The
    caller (``QueuedRequest.placement_key``) supplies either the explicit
    session or the rid-derived session prefix — the policy never
    truncates."""

    def __init__(self):
        self._fallback = LeastLoadedPolicy()
        self.pins: Dict[str, int] = {}
        self.events: List[WorkerEvent] = []
        self.stats = None            # bound by the owning Gateway
        # installed by the prefix-cache plane when the cluster-wide radix
        # index is on: (workers, prompt) -> aw_id | None. One global trie
        # lookup replaces the per-AW match scan, and may migrate the
        # matched prefix to a free AW before answering.
        self.global_router = None

    @staticmethod
    def session_key(rid: str) -> str:
        """Session prefix of a request id (``sess-3`` -> ``sess``)."""
        return rid.rsplit("-", 1)[0]

    def _prefix_best(self, workers, prompt) -> Optional[int]:
        """The healthy AW with capacity holding the longest cached prefix
        of ``prompt`` (None when no AW has a match, or no prefix caches
        exist)."""
        if prompt is None:
            return None
        if self.global_router is not None:
            # cluster-wide index: one lookup answers for every AW (and
            # covers migration); no match there means no match anywhere
            return self.global_router(workers, prompt)
        best, best_len = None, 0
        for w in workers:
            if w.prefix_cache is None or not w.has_capacity():
                continue
            lcp = w.prefix_cache.match_len(prompt)
            if lcp > best_len:
                best, best_len = w.aw_id, lcp
        return best

    def _choose_home(self, workers, key: str, prompt) -> Optional[int]:
        best = self._prefix_best(workers, prompt)
        if best is not None:
            return best
        home = zlib.crc32(key.encode()) % len(workers)
        if workers[home].has_capacity():
            return home
        return self._fallback(workers, key)

    def __call__(self, workers: List[AttentionWorker], key: str,
                 prompt=None, now: float = 0.0) -> Optional[int]:
        if not key:
            return self._fallback(workers, key)
        pin = self.pins.get(key)
        if pin is not None:
            w = workers[pin]
            if w.alive and w.has_capacity():
                return pin
            if w.alive:
                # home is full but healthy: spill without re-pinning
                return self._fallback(workers, key)
            new = self._choose_home(workers, key, prompt)
            if new is None:
                return None        # nothing placeable now; keep the pin
            #                        and retry (re-pin on a real placement)
            self.pins[key] = new
            ev = WorkerEvent(now, "session_repinned", key,
                             f"aw{pin}->aw{new}")
            self.events.append(ev)
            bus = getattr(self, "bus", None)
            if bus is not None:
                bus.publish(ev)
            if self.stats is not None:
                self.stats.session_repins += 1
            return new
        choice = self._choose_home(workers, key, prompt)
        if choice is not None:
            self.pins[key] = choice
        return choice


PLACEMENT_POLICIES = {
    "least_loaded": LeastLoadedPolicy,
    "round_robin": RoundRobinPolicy,
    "session_affinity": SessionAffinityPolicy,
}


@dataclass
class GatewayStats:
    enqueued: int = 0
    admitted: int = 0
    requeued: int = 0               # recovery re-admissions queued
    blocked_ticks: int = 0          # head-of-queue retries
    preemptions: int = 0            # victims evicted to place a higher class
    host_syncs: int = 0             # decode-path device->host token drains
    #                                 (one per decode segment — per STEP at
    #                                 decode_segment_len=1; the observable
    #                                 cost the device-resident loop divides
    #                                 by seg_len)
    # prefix-cache plane accounting (serving/prefixcache.py)
    prefix_hits: int = 0            # admissions that adopted a cached prefix
    prefix_misses: int = 0          # cache-eligible admissions without a hit
    prefix_hit_tokens: int = 0      # prompt tokens adopted (prefill skipped)
    prefix_evictions: int = 0       # cached prefixes evicted (budget/pressure)
    prefix_restored: int = 0        # dead-AW prefixes restored on failover
    prefix_global_hits: int = 0     # placements routed by the cluster-wide
    #                                 radix index (paged engines)
    prefix_migrated: int = 0        # prefixes migrated between AWs via
    #                                 checkpoint replay (paged engines)
    session_repins: int = 0         # sessions re-pinned off a dead AW
    queue_delay: Dict[str, float] = field(default_factory=dict)
    # per-class lifecycle counters:
    #   class -> {enqueued, admitted, preempted, cancelled, deadline_missed}
    by_class: Dict[str, Dict[str, int]] = field(default_factory=dict)

    def bump(self, slo_class: str, key: str, n: int = 1):
        c = self.by_class.setdefault(slo_class, {})
        c[key] = c.get(key, 0) + n

    def class_count(self, slo_class: str, key: str) -> int:
        return self.by_class.get(slo_class, {}).get(key, 0)


class Gateway:
    """Multi-class admission + per-class waiting queues + placement over
    the AW pool."""

    def __init__(self, workers: List[AttentionWorker],
                 policy="least_loaded"):
        self.workers = workers
        if isinstance(policy, str):
            policy = PLACEMENT_POLICIES[policy]()
        self.policy = policy
        self.queues: Dict[str, Deque[QueuedRequest]] = {
            cls: deque() for cls in SLO_CLASSES}
        self.stats = GatewayStats()
        if isinstance(policy, SessionAffinityPolicy):
            policy.stats = self.stats
        # token-based admission (chunked-prefill plane): cap on prompt
        # tokens admitted but not yet prefilled. ``prefill_load`` is a
        # probe supplied by the engine (the plane's outstanding_tokens);
        # cap 0 = slot-bound admission only.
        self.prefill_token_cap: int = 0
        self.prefill_load = None
        # prefix-cache-plane probe: prompt -> cluster-wide best match len.
        # When installed (paged global index) it replaces the per-AW scan
        # in _cached_match_len.
        self.match_probe = None
        # engine-installed hook: (blocked interactive head, now) -> bool.
        # True means a victim's slot was freed (preempt-and-requeue) and
        # placement should be retried for the head.
        self.preemptor = None
        # telemetry plane (serving/telemetry.py): the engine installs the
        # event bus and (optionally) the TelemetryPlane after construction
        self.bus = None
        self.telemetry = None
        # forensics plane (serving/flightrec.py): records every external
        # submission — the replay workload — at the enqueue boundary
        self.flightrec = None

    def attach_bus(self, bus):
        """Install the publish-at-emission event bus; the placement policy
        shares it so session_repinned events publish at emission instead
        of waiting for the next destructive drain."""
        self.bus = bus
        self.policy.bus = bus

    # -- queue management ---------------------------------------------------
    @property
    def queue(self) -> Tuple[QueuedRequest, ...]:
        """Read-only combined view in class-priority order (back-compat:
        the single-FIFO era exposed the deque directly)."""
        return tuple(q for cls in SLO_CLASSES for q in self.queues[cls])

    def enqueue(self, rid: str, prompt: np.ndarray, max_new: int, *,
                now: float = 0.0, frames: Optional[np.ndarray] = None,
                slo_class: str = STANDARD,
                deadline: Optional[float] = None,
                completion_deadline: Optional[float] = None,
                sampling: Optional[SamplingParams] = None,
                session: Optional[str] = None):
        if slo_class not in SLO_CLASSES:
            raise ValueError(f"unknown slo_class {slo_class!r}: expected "
                             f"one of {SLO_CLASSES}")
        entry = QueuedRequest(rid, np.asarray(prompt, np.int32), max_new,
                              frames, now, slo_class=slo_class,
                              deadline=deadline,
                              completion_deadline=completion_deadline,
                              sampling=sampling, session=session)
        self._insert(entry)
        self.stats.enqueued += 1
        self.stats.bump(slo_class, "enqueued")
        if self.telemetry is not None:
            self.telemetry.on_enqueue(rid, now, slo_class)
        if self.flightrec is not None:
            self.flightrec.on_submit(entry, now)

    def _insert(self, entry: QueuedRequest):
        """Deadline-aware, stable insertion: after every recovery entry,
        after any head that has already been blocked (``retries > 0`` — a
        deadlined newcomer must not overtake it, or a cap-blocked large
        prompt could be starved forever by a steady deadlined stream), and
        after every entry with an equal-or-earlier deadline (undeadlined =
        +inf, i.e. plain FIFO among themselves)."""
        q = self.queues[entry.slo_class]
        i = len(q)
        for j, e in enumerate(q):
            if e.recovery or e.retries > 0:
                continue
            if e.deadline_key > entry.deadline_key:
                i = j
                break
        q.insert(i, entry)

    def requeue_recovery(self, entries: List[QueuedRequest]):
        """Preempted/recovered requests re-enter at the FRONT of their
        class queue (they are older than everything waiting behind them)."""
        for e in reversed(entries):
            e.recovery = True
            self.queues[e.slo_class].appendleft(e)
            self.stats.requeued += 1

    def depth(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # -- control-plane signals (serving/controller.py) ----------------------
    def class_depth(self, slo_class: str) -> int:
        return len(self.queues[slo_class])

    def min_queued_deadline(self, slo_class: str) -> Optional[float]:
        """Earliest first-token deadline waiting in one class queue (None
        when the queue is empty or nothing in it carries a deadline)."""
        dls = [e.deadline for e in self.queues[slo_class]
               if e.deadline is not None]
        return min(dls) if dls else None

    def find(self, rid: str) -> Optional[QueuedRequest]:
        for q in self.queues.values():
            for e in q:
                if e.rid == rid:
                    return e
        return None

    def drop(self, rid: str) -> Optional[QueuedRequest]:
        """Remove a still-queued request from whichever class queue holds
        it (admission refused, cancellation, or a stale recovery entry).
        Returns the removed entry, or None. In-flight requests are torn
        down by ``engine.release_request`` / ``engine.cancel_request``,
        which also free the owning AW's slot, pending checkpoint WRs, and
        prefill-stream state."""
        e = self.find(rid)
        if e is not None:
            self.queues[e.slo_class].remove(e)
        return e

    # -- placement ----------------------------------------------------------
    def choose_aw(self, rid: str = "", prompt=None,
                  now: float = 0.0) -> Optional[int]:
        return self.policy(self.workers, rid, prompt=prompt, now=now)

    def _cached_match_len(self, prompt) -> int:
        """Best cached-prefix match for ``prompt`` across live AWs — the
        token-cap gate's estimate of how much of the prompt would be
        adopted rather than prefilled (the exact tail is charged after
        placement)."""
        if self.match_probe is not None:
            return self.match_probe(prompt)
        best = 0
        for w in self.workers:
            if w.alive and w.prefix_cache is not None:
                best = max(best, w.prefix_cache.match_len(prompt))
        return best

    def drain_events(self) -> List[WorkerEvent]:
        """Placement-plane events (``session_repinned``) accumulated by
        the policy; drained into the engine's request-event timeline."""
        evs = getattr(self.policy, "events", None)
        if not evs:
            return []
        self.policy.events = []
        return evs

    def admit(self, now: float = 0.0
              ) -> List[Tuple[QueuedRequest, int, int]]:
        """Weighted dequeue over the class queues: each round hands every
        class its weight in admission credits (interactive first), popping
        that class's head while placement succeeds and reserving a slot on
        the chosen AW per admission (so the policy sees live free counts).
        Head-of-line blocking is *per class*: a blocked head stalls only
        its own class for this tick — it is retried, never overtaken
        within the class. A blocked interactive head may trigger the
        preempt-and-requeue hook to evict a batch victim first. Returns
        (entry, aw_id, slot) triples."""
        admitted = []
        new_tokens = 0                 # fresh prompt tokens admitted now
        blocked = set()
        while True:
            progressed = False
            for cls in SLO_CLASSES:
                if cls in blocked:
                    continue
                q = self.queues[cls]
                for _ in range(CLASS_WEIGHTS[cls]):
                    if not q:
                        break
                    head = q[0]
                    # admission is token-aware, not just slot-aware: a free
                    # slot is not enough if the prefill plane is already
                    # saturated with outstanding prompt tokens. Recovery
                    # entries bypass the cap — their committed prefix
                    # restores from the store. The first admission is
                    # always allowed so an over-cap prompt cannot deadlock
                    # the queue.
                    if self.prefill_token_cap and not head.recovery:
                        load = new_tokens + \
                            (self.prefill_load() if self.prefill_load else 0)
                        # a mostly-cached warm prompt only brings its
                        # uncached tail to the prefill plane — gate on
                        # that estimate, not the raw prompt length
                        need = len(head.prompt) - \
                            self._cached_match_len(head.prompt)
                        if load > 0 and \
                                load + need > self.prefill_token_cap:
                            head.retries += 1
                            self.stats.blocked_ticks += 1
                            blocked.add(cls)
                            break
                    # the policy sees the prompt (prefix-aware routing);
                    # recovery entries restore their own KV — no matching
                    match_prompt = None if head.recovery else head.prompt
                    aw = self.choose_aw(head.placement_key,
                                        prompt=match_prompt, now=now)
                    if aw is None and cls in PREEMPTING_CLASSES and \
                            self.preemptor is not None:
                        # preempt-and-requeue: evict a batch victim (its KV
                        # is committed to the store, its slot freed, and it
                        # re-enters its class queue as a recovery entry);
                        # stats.preemptions is bumped by preempt_request
                        # itself, so direct/policy-driven evictions count
                        # in the same place as hook-driven ones
                        if self.preemptor(head, now):
                            aw = self.choose_aw(head.placement_key,
                                                prompt=match_prompt, now=now)
                    if aw is None:
                        head.retries += 1
                        self.stats.blocked_ticks += 1
                        blocked.add(cls)
                        break
                    q.popleft()
                    slot, head.prefix_hit = self.workers[aw].take_slot(
                        match_prompt, now)
                    if not head.recovery:
                        # charge only the uncached tail against the cap:
                        # adopted tokens never enter the prefill plane
                        new_tokens += len(head.prompt) - head.prefix_hit
                    if self.workers[aw].prefix_cache is not None and \
                            match_prompt is not None:
                        if head.prefix_hit:
                            self.stats.prefix_hits += 1
                            self.stats.prefix_hit_tokens += head.prefix_hit
                        else:
                            self.stats.prefix_misses += 1
                    self.stats.admitted += 1
                    self.stats.bump(cls, "admitted")
                    # total time spent waiting at the gateway, summed over
                    # spells (a recovery re-admission is a second spell for
                    # the same rid)
                    self.stats.queue_delay[head.rid] = \
                        self.stats.queue_delay.get(head.rid, 0.0) + \
                        (now - head.t_enqueue)
                    if self.telemetry is not None:
                        self.telemetry.on_admit(
                            head.rid, now, aw, slot, cls, head.recovery,
                            head.prefix_hit, now - head.t_enqueue)
                    admitted.append((head, aw, slot))
                    progressed = True
            if not progressed:
                break
        return admitted
