"""Flight recorder, health watchdogs, and postmortem forensics.

The telemetry plane (serving/telemetry.py) can *aggregate* a run —
histograms, spans, stall attribution — but it cannot *reconstruct* one:
after a 0.3 s recovery the interesting operator question is "what exactly
happened, and did the system do the right thing?", and answering it needs
the inputs, the fault schedule, and the control decisions, not just their
statistical shadows. This module closes that gap in three pieces:

  * **FlightRecorder** — a bounded-memory black box riding the EventBus as
    an independent cursor-based consumer. It keeps a ring of structured
    records (worker events, controller decisions, placement generations,
    chunk commits, preemption/restore markers, submissions) plus periodic
    engine-state *fingerprints* (config hash, plan generation, per-AW
    slot/page occupancy, KV page-pool watermarks, checkpoint-store
    cursors). Memory is bounded by ``EngineConfig.flight_capacity`` per
    ring; past that, oldest records drop and a truncation counter rises.
  * **Postmortem bundles** — ``dump()`` exports a versioned JSON bundle
    (schema ``repro.postmortem.v1``): the record ring, every submission
    (prompt tokens included — the replay workload), recorded outputs,
    external fault/scale injections, controller decisions, open spans,
    the stall records of the incident window, and per-worker snapshots.
    A dump fires automatically on the first failure *detection* or
    watchdog trip when ``flight_autodump`` names a path, or on demand
    (``--postmortem``). ``launch/replay.py`` consumes a bundle and
    re-runs the incident deterministically, asserting bit-identical
    outputs — any captured incident becomes a runnable regression test.
  * **HealthWatchdogs** — continuous detectors for *slow* degradation the
    per-run asserts cannot see: a leak detector (monotone-trend test over
    the PagePool free-list and cluster slot free-list watermarks across a
    sliding window of intervals), a stall-regression detector (windowed
    TTFT/TBT p99 from streamed histogram deltas vs a baseline window,
    suppressed around injected faults — recovery stalls are expected),
    and invariant probes (``PagePool.check()`` free-xor-allocated oracle;
    every open root span belongs to a live request). Trips emit
    ``health_*`` events + registry counters and trip the recorder's dump.

Invariants: everything here is host-side bookkeeping — no device arrays,
no jax calls — so recorder+watchdogs on/off is bit-identical and adds
zero new jit traces by construction (asserted in tests/test_flightrec.py,
hook cost priced inside the bench_steady_state <=3 % overhead gate).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import weakref
from collections import deque
from typing import Dict, List, Optional

import numpy as np

SCHEMA = "repro.postmortem.v1"

#: knobs that must not perturb the config hash: they name output paths or
#: toggle the forensics plane itself, and replay neutralizes them
_HASH_EXCLUDE = ("flight_autodump", "trace_export_path")

#: bus event kinds that mark the system as "disturbed" for the watchdogs:
#: a window overlapping one of these must not be judged for leaks or
#: stall regressions (failover churn moves every watermark legitimately)
_DISTURB_KINDS = frozenset((
    "fail_aw", "fail_ew", "detected", "provisioned", "reprotected",
    "scale_out_started", "drain_started", "rebalance_started",
    "scaled_out", "scaled_in", "rebalanced", "scale_failed",
    "placement_changed", "preempted"))

#: live recorders, for the pytest postmortem-on-failure hook
#: (tests/conftest.py dumps the most recent ones when a test fails)
_LIVE: "weakref.WeakSet" = weakref.WeakSet()


def _jsonable(x):
    """Recursively coerce numpy scalars/arrays so the bundle JSON-dumps."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    return x


def key_host_data(key) -> np.ndarray:
    """Host copy of a PRNG key's raw data (old-style uint32 arrays pass
    through; typed keys go through ``jax.random.key_data``)."""
    try:
        return np.asarray(key)
    except TypeError:
        import jax
        return np.asarray(jax.random.key_data(key))


def hash_config_dicts(model_d: dict, engine_d: dict) -> str:
    """Digest of (ModelConfig, EngineConfig) as plain dicts, minus the
    knobs that cannot affect outputs (dump paths). JSON-canonical, so a
    bundle round-trip (tuples -> lists) hashes identically."""
    e = {k: v for k, v in engine_d.items() if k not in _HASH_EXCLUDE}
    blob = json.dumps({"model": model_d, "engine": e},
                      sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def config_hash(cfg, ecfg) -> str:
    """Stable digest of live (ModelConfig, EngineConfig) — the replay
    handshake: a bundle only replays against a byte-identical config."""
    return hash_config_dicts(dataclasses.asdict(cfg),
                             dataclasses.asdict(ecfg))


def live_recorders() -> List["FlightRecorder"]:
    return list(_LIVE)


def dump_live_recorders(directory: str, tag: str, limit: int = 3
                        ) -> List[str]:
    """Postmortem-on-test-failure: dump the most recently created live
    recorders into ``directory`` (best-effort — a broken engine must not
    mask the original test failure). Returns the bundle paths written."""
    recs = sorted(_LIVE, key=lambda fr: fr.serial)[-limit:]
    paths = []
    safe = "".join(c if c.isalnum() or c in "-_." else "_" for c in tag)
    for fr in recs:
        try:
            os.makedirs(directory, exist_ok=True)
            path = os.path.join(directory,
                                f"{safe}.r{fr.serial}.postmortem.json")
            fr.dump(path, reason=f"test failure: {tag}")
            paths.append(path)
        except Exception:
            pass
    return paths


# ---------------------------------------------------------------------------
# the recorder
# ---------------------------------------------------------------------------


class FlightRecorder:
    """Bounded-memory black box for one engine. Host-side only; every
    hook site guards on ``engine.flightrec is not None``, mirroring the
    telemetry plane — switching it off cannot change a single token."""

    CONSUMER = "flightrec"
    _serial = 0

    def __init__(self, engine):
        self.engine = engine
        ecfg = engine.ecfg
        FlightRecorder._serial += 1
        self.serial = FlightRecorder._serial
        cap = max(int(ecfg.flight_capacity), 16)
        self.records: deque = deque(maxlen=cap)
        self.records_total = 0
        self.submissions: deque = deque(maxlen=cap)
        self.sub_total = 0
        self.outputs: deque = deque(maxlen=cap)
        self.out_total = 0
        self.injections = {"failures": [], "scales": []}
        self.loops: List[dict] = []      # one entry per run_serving call
        self.orch: Optional[dict] = None
        self.fingerprint_every = float(ecfg.flight_fingerprint_every)
        self._next_fp = 0.0
        self.fingerprints = 0
        self.autodump_path = str(ecfg.flight_autodump or "")
        self._autodumped = False
        self.last_dump_path: Optional[str] = None
        self.now = 0.0
        self.config_hash = config_hash(engine.cfg, ecfg)
        self.watchdogs: Optional[HealthWatchdogs] = \
            HealthWatchdogs(engine, self) if ecfg.watchdogs else None
        _LIVE.add(self)

    # -- record ring ---------------------------------------------------------
    @property
    def records_dropped(self) -> int:
        return self.records_total - len(self.records)

    def _rec(self, t: float, kind: str, who: str, detail: str = "",
             **extra):
        d = {"t": float(t), "kind": str(kind), "who": str(who),
             "detail": str(detail)}
        if extra:
            d.update(extra)
        self.records.append(d)
        self.records_total += 1
        if t > self.now:
            self.now = float(t)

    # -- capture hooks -------------------------------------------------------
    def on_submit(self, q, now: float):
        """Gateway.enqueue: the full replay workload — prompt tokens
        included. Recovery requeues never come through enqueue, so the
        ring holds exactly the external arrivals."""
        self.sub_total += 1
        self.submissions.append({
            "rid": q.rid, "t": float(now),
            "prompt": [int(x) for x in np.asarray(q.prompt).ravel()],
            "max_new": int(q.max_new),
            "slo_class": q.slo_class,
            "deadline": None if q.deadline is None else float(q.deadline),
            "completion_deadline": None if q.completion_deadline is None
            else float(q.completion_deadline),
            "session": q.session,
            "sampling": None if q.sampling is None
            else dataclasses.asdict(q.sampling)})
        self._rec(now, "submit", q.rid,
                  f"{len(q.prompt)} prompt tokens, max_new={q.max_new}, "
                  f"{q.slo_class}")

    def on_release(self, r):
        """engine.release_request: pin the final token stream — the
        bit-identity oracle the replay asserts against."""
        self.out_total += 1
        self.outputs.append({
            "rid": r.rid, "state": r.state,
            "tokens": [int(t) for t in r.tokens],
            "t_done": float(r.t_done), "preemptions": int(r.preemptions)})

    def on_chunk(self, rid: str, t: float, take: int, shape: int,
                 cursor: int):
        self._rec(t, "chunk_commit", rid,
                  f"take={take} shape={shape} cursor={cursor}")

    def on_restore(self, rid: str, t: float, segments: int,
                   resumed_prefill: bool):
        self._rec(t, "restore", rid,
                  f"{segments} segments, "
                  f"{'mid-prefill resume' if resumed_prefill else 'decode'}")

    def note_loop(self, *, duration: float, step_time, prefill_token_time,
                  max_steps: int):
        self.loops.append({
            "duration": float(duration),
            "step_time": None if step_time is None else float(step_time),
            "prefill_token_time": None if prefill_token_time is None
            else float(prefill_token_time),
            "max_steps": int(max_steps)})
        self._rec(0.0, "serving_loop", "loop",
                  f"duration={duration} step_time={step_time}")

    def note_injection(self, kind: str, plan):
        """External (scripted) fault/scale injections, recorded at the
        run_serving injection site — distinct from controller-originated
        scale requests, which the replayed controller re-decides itself."""
        entry = {"t": float(plan.t), "kind": plan.kind,
                 "worker_id": int(getattr(plan, "worker_id", -1))}
        self.injections["failures" if kind == "failure"
                        else "scales"].append(entry)

    def note_orchestrator(self, orch):
        self.orch = {
            "worker_init_time": float(orch.T_w),
            "weight_push_time": float(orch.T_push),
            "ew_policy": orch.ew_policy,
            "auto_rebalance": bool(orch.auto_rebalance),
            "rebalance_cooldown": float(orch.rebalance_cooldown),
            "profile_detect": float(orch.profile.detect),
            "profile_detect_retries": int(orch.profile.detect_retries)}

    # -- per-tick work -------------------------------------------------------
    def _drain(self, now: float):
        """Pull the bus forward through this recorder's own cursor: worker
        events, controller decisions, placement generations, preemptions,
        and health events all ride the same stream."""
        for ev in self.engine.bus.drain(self.CONSUMER):
            self._rec(ev.t, ev.kind, ev.worker, ev.detail)
            if ev.kind in _DISTURB_KINDS and self.watchdogs is not None:
                self.watchdogs.note_disturbance(ev.t)
            if ev.kind == "detected":
                self._maybe_autodump(
                    now, f"failure detected: {ev.worker} at t={ev.t:g}")
        if now > self.now:
            self.now = float(now)

    def tick(self, now: float):
        """Once per scheduler step: drain the bus, fingerprint when due,
        advance the watchdogs. O(new events) — no device work, ever."""
        self._drain(now)
        if self.fingerprint_every > 0 and now >= self._next_fp:
            self.fingerprint(now)
            self._next_fp = now + self.fingerprint_every
        if self.watchdogs is not None:
            self.watchdogs.tick(now)

    def fingerprint(self, now: float):
        """Periodic engine-state fingerprint: enough to cross-check a
        replay's trajectory against the original without storing full
        state — config hash, plan generation, per-AW slot/page occupancy,
        page-pool watermarks, checkpoint-store cursors."""
        eng = self.engine
        per_aw = []
        for w in eng.aws:
            used, total = w.slot_occupancy()
            d = {"aw": w.aw_id, "alive": bool(w.alive),
                 "slots_used": int(used), "slots_total": int(total)}
            ps = w.kv_page_stats()
            if ps is not None:
                d["pages_used"], d["pages_total"] = int(ps[0]), int(ps[1])
            per_aw.append(d)
        store = eng.store
        rids = sorted(store._logs)
        cursors = {rid: int(store.committed_token(rid))
                   for rid in rids[:64]}
        fp = {"gen": int(eng.placement_generation),
              "config_hash": self.config_hash,
              "workers": per_aw,
              "ew_live": sorted(eng.live_ews),
              "queue_depth": int(eng.gateway.depth()),
              "active": len(eng.active_requests()),
              "prefilling": len(eng.prefilling_requests()),
              "store": {"logs": len(rids), "cursors": cursors}}
        if eng.pages is not None:
            fp["free_pages"] = [eng.pages.free_pages(a)
                                for a in range(eng.pages.num_aw)]
            fp["pages"] = eng.pages.stats()
        self.fingerprints += 1
        self._rec(now, "fingerprint", "engine", "", **fp)

    # -- dump ----------------------------------------------------------------
    def _maybe_autodump(self, now: float, reason: str):
        if not self.autodump_path or self._autodumped:
            return
        self._autodumped = True
        self.dump(self.autodump_path, reason=reason, now=now)

    def dump(self, path: Optional[str] = None, reason: str = "manual",
             now: Optional[float] = None) -> dict:
        """Export the postmortem bundle (schema ``repro.postmortem.v1``).
        Non-destructive: the rings keep recording afterwards."""
        eng = self.engine
        t = self.now if now is None else max(float(now), self.now)
        self._drain(t)
        self.fingerprint(t)
        tel = eng.telemetry
        t0 = self.records[0]["t"] if self.records else 0.0
        open_spans = []
        if tel is not None:
            for rid, sp in tel._root.items():
                open_spans.append({"rid": rid, "kind": "root",
                                   "since": sp.t0})
            for rid, sp in tel._phase.items():
                open_spans.append({"rid": rid, "kind": "phase",
                                   "name": sp.name, "since": sp.t0})
        stalls = [] if tel is None else \
            [s.to_dict() for s in tel._stalls if s.t1 >= t0]
        outputs: Dict[str, List[int]] = {}
        for o in self.outputs:
            if o["state"] == "done":
                outputs[o["rid"]] = o["tokens"]
        bundle = {
            "schema": SCHEMA,
            "reason": reason,
            "clock": t,
            "config": {
                "hash": self.config_hash,
                "model": dataclasses.asdict(eng.cfg),
                "engine": dataclasses.asdict(eng.ecfg),
                "key": [int(x) for x in
                        np.asarray(eng.init_key_data).ravel()]},
            "loops": list(self.loops),
            "orchestrator": self.orch,
            "injections": {k: list(v) for k, v in self.injections.items()},
            "controller": None if eng.controller is None else {
                "decisions": [dict(d) for d in eng.controller.decisions],
                "counts": dict(eng.controller.counts)},
            "truncated": {"records": self.records_dropped,
                          "submissions": self.sub_total
                          - len(self.submissions),
                          "outputs": self.out_total - len(self.outputs)},
            "records": list(self.records),
            "submissions": list(self.submissions),
            "outputs": outputs,
            "request_states": {
                rid: {"state": r.state, "aw": r.aw, "slot": r.slot,
                      "tokens_emitted": len(r.tokens), "pos": r.pos,
                      "prefill_cursor": r.prefill_cursor,
                      "preemptions": r.preemptions}
                for rid, r in sorted(eng.requests.items())},
            "workers": {
                "aw": [{"aw": w.aw_id, "alive": bool(w.alive),
                        "slots": list(w.slot_occupancy())}
                       for w in eng.aws],
                "ew": [{"ew": w.ew_id, "member": bool(w.member),
                        "alive": bool(w.alive)} for w in eng.ews]},
            "open_spans": open_spans,
            "stalls": stalls,
            "health": None if self.watchdogs is None
            else self.watchdogs.summary(),
        }
        bundle = _jsonable(bundle)
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(path, "w") as f:
                json.dump(bundle, f)
            self.last_dump_path = path
        return bundle


# ---------------------------------------------------------------------------
# health watchdogs
# ---------------------------------------------------------------------------


def _window_quantile(h, counts: np.ndarray, q: float) -> float:
    """Quantile over a *delta* of a StreamingHistogram's counts (the
    observations of one interval window) using the histogram's bucket
    geometry — windowed percentiles without per-sample state."""
    total = int(counts.sum())
    if total == 0:
        return 0.0
    target = q * total
    cum = 0
    for i in range(h.n):
        c = int(counts[i])
        if c == 0:
            continue
        if cum + c >= target:
            blo, bhi = h.bucket_bounds(i)
            if not math.isfinite(bhi):
                return float(h.vmax)
            return blo + (target - cum) / c * (bhi - blo)
        cum += c
    return float(h.vmax)


class HealthWatchdogs:
    """Continuous degradation detectors over a sliding window of
    ``wd_interval``-second intervals. All judgments suppress around
    disturbances (failures, scale events, preemptions): those move every
    watermark for legitimate reasons, and the watchdogs hunt *unexplained*
    trends, not recovery churn."""

    def __init__(self, engine, recorder: FlightRecorder):
        ecfg = engine.ecfg
        self.engine = engine
        self.recorder = recorder
        self.interval = float(ecfg.wd_interval)
        self.window = max(int(ecfg.wd_window), 2)
        self.min_drop = int(ecfg.wd_leak_min_drop)
        self.stall_factor = float(ecfg.wd_stall_factor)
        self.stall_floor = float(getattr(ecfg, "stall_threshold", 0.25))
        self.settle = float(ecfg.wd_settle)
        self.trips: List[dict] = []
        self.trip_counts: Dict[str, int] = {}
        self.intervals = 0
        self._t_edge: Optional[float] = None
        self._last_disturb = -math.inf
        # per-interval free-list watermarks (the max free count seen in
        # the interval: a leak lowers the *upper envelope*, transient
        # occupancy only lowers the instantaneous value)
        self._marks: Dict[str, deque] = {
            "pages": deque(maxlen=self.window),
            "slots": deque(maxlen=self.window)}
        self._active_marks: deque = deque(maxlen=self.window)
        self._cur: Dict[str, int] = {}
        # stall regression: histogram counts at the last interval edge
        self._hist_prev: Dict[str, np.ndarray] = {}
        self.baseline_p99: Dict[str, float] = {}
        self._invariant_seen: set = set()

    # -- signals -------------------------------------------------------------
    def note_disturbance(self, t: float):
        if t > self._last_disturb:
            self._last_disturb = float(t)

    def _disturbed(self, now: float, span: float) -> bool:
        return now - self._last_disturb < span + self.settle

    def _free_counts(self) -> Dict[str, int]:
        eng = self.engine
        out = {"slots": sum(w.slots.free_count() for w in eng.aws
                            if w.alive)}
        if eng.pages is not None:
            out["pages"] = sum(eng.pages.free_pages(a)
                               for a in range(eng.pages.num_aw))
        return out

    def tick(self, now: float):
        if self._t_edge is None:
            self._t_edge = float(now)
        for res, v in self._free_counts().items():
            if v > self._cur.get(res, -1):
                self._cur[res] = v
        if now - self._t_edge >= self.interval:
            self._close_interval(now)
            self._t_edge = float(now)

    # -- interval close: push marks, run every detector ----------------------
    def _close_interval(self, now: float):
        self.intervals += 1
        eng = self.engine
        for res, mk in self._marks.items():
            if res in self._cur:
                mk.append(self._cur[res])
        self._active_marks.append(
            len(eng.requests) + eng.gateway.depth())
        self._cur = {}
        self._probe_invariants(now)
        span = self.window * self.interval
        if not self._disturbed(now, span):
            self._check_leaks(now)
            self._check_stall_regression(now)
        else:
            # a disturbed window still advances the histogram cursors so
            # the next quiet window's delta is truly one window wide
            self._advance_hist_cursors()

    def _probe_invariants(self, now: float):
        eng = self.engine
        if eng.pages is not None and "pages" not in self._invariant_seen:
            try:
                eng.pages.check()
            except AssertionError as e:
                self._invariant_seen.add("pages")
                self._trip(now, "invariant", "pages",
                           f"PagePool.check failed: {e}")
        tel = eng.telemetry
        if tel is not None:
            gw = eng.gateway
            for rid in list(tel._root):
                if rid in self._invariant_seen or rid in eng.requests:
                    continue
                if any(e.rid == rid for q in gw.queues.values()
                       for e in q):
                    continue
                self._invariant_seen.add(rid)
                self._trip(now, "invariant", "spans",
                           f"root span for {rid!r} open but the request "
                           f"is neither resident nor queued")

    def _check_leaks(self, now: float):
        for res, mk in self._marks.items():
            if len(mk) < self.window:
                continue
            vals = list(mk)
            drop = vals[0] - vals[-1]
            monotone = all(b <= a for a, b in zip(vals, vals[1:]))
            if not monotone or drop < self.min_drop:
                continue
            if self._active_marks[-1] > self._active_marks[0]:
                continue   # load ramp, not a leak
            self._trip(now, "leak", res,
                       f"free-{res} watermark {vals[0]} -> {vals[-1]} "
                       f"over {len(vals)} intervals with no load growth",
                       watermarks=vals)
            mk.clear()     # re-arm instead of re-tripping every interval

    def _hist_sources(self):
        tel = self.engine.telemetry
        if tel is None:
            return
        for name in ("tbt", "ttft"):
            h = tel.registry.hists.get(name)
            if h is not None:
                yield name, h

    def _advance_hist_cursors(self):
        for name, h in self._hist_sources():
            self._hist_prev[name] = h.counts.copy()

    def _check_stall_regression(self, now: float):
        for name, h in self._hist_sources():
            counts = h.counts.copy()
            prev = self._hist_prev.get(name)
            self._hist_prev[name] = counts
            if prev is None:
                continue
            win = counts - prev
            if int(win.sum()) < 8:
                continue   # too few observations to judge
            p99 = _window_quantile(h, win, 0.99)
            base = self.baseline_p99.get(name)
            if base is None:
                # first quiet window with enough mass IS the baseline
                self.baseline_p99[name] = p99
                continue
            if p99 > self.stall_factor * max(base, 1e-9) and \
                    p99 > self.stall_floor:
                self._trip(now, "stall_regression", name,
                           f"windowed {name} p99 {p99:.4f}s vs baseline "
                           f"{base:.4f}s (x{p99 / max(base, 1e-9):.1f}) "
                           f"with no fault in the window",
                           p99=p99, baseline=base)
                # re-arm at the regressed level: a persistent plateau
                # trips once, a further regression trips again
                self.baseline_p99[name] = p99

    def _trip(self, now: float, kind: str, what: str, detail: str,
              **extra):
        trip = {"t": float(now), "kind": kind, "what": what,
                "detail": detail}
        trip.update(_jsonable(extra))
        self.trips.append(trip)
        self.trip_counts[kind] = self.trip_counts.get(kind, 0) + 1
        # health_* rides the request-event path: bus + telemetry counter
        # + audit log, so operators see trips wherever they already look
        self.engine._note_request_event(f"health_{kind}", what, now,
                                        detail)
        self.recorder._maybe_autodump(now, f"watchdog {kind}: {what}")

    def summary(self) -> dict:
        return {"trips": len(self.trips),
                "by_kind": dict(self.trip_counts),
                "intervals": self.intervals,
                "watermarks": {res: list(mk)
                               for res, mk in self._marks.items()},
                "baseline_p99": dict(self.baseline_p99),
                "last_trips": [dict(t) for t in self.trips[-5:]]}
