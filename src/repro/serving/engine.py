"""Tarragon inference engine: continuous batching over a slot-based cache,
decoupled AW/EW roles via mesh-partitioned routing, per-token incremental
KV checkpointing, and worker-granularity failure injection/recovery.

The engine is the AW-side "Compute Engine" of Fig. 5, generalized to all ten
assigned architectures. One jitted decode step serves every active slot;
prefill runs per request (exact prompt length) and the resulting cache slice
is merged into the global slot cache.

Failure API (used by the orchestrator and by tests):
  * ``fail_aw(a)``   — drop AW a: its slots are lost; requests recover via
    per-request restoration from the checkpoint store onto healthy AWs.
  * ``fail_ew(e)``   — drop EW e: the ERT immediately resolves its experts
    to shadow slots (AW-side self-healing); nothing else changes.
  * ``provision_*`` — background capacity restoration (§5.4).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import selfheal
from repro.core.checkpoint import CheckpointStore, KVCheckpointer
from repro.core.refe import RouteState
from repro.models import get_model
from repro.serving.kvcache import CacheLayout, SlotManager


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 96
    num_aw: int = 2
    num_ew: int = 2
    tarragon: bool = True          # False = MegaScale-style static binding
    checkpoint: bool = True
    checkpoint_reorder: int = 0    # test hook: reorder window for WR arrival
    greedy: bool = True
    capacity_factor_decode: float = 0.0  # 0 = use model default


@dataclass
class RequestState:
    rid: str
    slot: int
    prompt: np.ndarray
    max_new: int
    tokens: List[int] = field(default_factory=list)  # generated tokens
    pos: int = 0                  # next position to write
    done: bool = False
    ttft: float = -1.0
    token_times: List[float] = field(default_factory=list)

    @property
    def aw(self) -> int:
        return self._aw

    _aw: int = -1


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig, key=None):
        self.cfg = cfg
        self.ecfg = ecfg
        key = key if key is not None else jax.random.PRNGKey(0)
        self.api = get_model(cfg, num_aw=ecfg.num_aw, num_ew=ecfg.num_ew,
                             tarragon=ecfg.tarragon)
        self.params = self.api.init_params(key)
        self.route_state: RouteState = self.api.init_route_state()
        self.cache = self.api.init_cache(ecfg.max_batch, ecfg.max_seq)
        self.layout = CacheLayout(self.api.init_cache)
        self.slots = SlotManager(ecfg.max_batch, ecfg.num_aw)
        self.store = CheckpointStore()
        self.checkpointers = {
            a: KVCheckpointer(self.store, a,
                              reorder_window=ecfg.checkpoint_reorder, seed=a)
            for a in range(ecfg.num_aw)}
        self.requests: Dict[str, RequestState] = {}
        self._extract = self.layout.make_batched_extractor()
        self._decode = jax.jit(self.api.decode)
        self._prefill = jax.jit(self.api.prefill,
                                static_argnames=("max_seq",))
        self.failed_aws: set = set()
        self.failed_ews: set = set()
        self.steps = 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _healthy_aws(self) -> List[int]:
        return [a for a in range(self.ecfg.num_aw) if a not in self.failed_aws]

    def choose_aw(self) -> Optional[int]:
        """Gateway policy: least-loaded healthy AW with a free slot."""
        best, best_free = None, 0
        for a in self._healthy_aws():
            f = self.slots.free_count(a)
            if f > best_free:
                best, best_free = a, f
        return best

    def submit(self, rid: str, prompt: np.ndarray, max_new: int,
               frames: Optional[np.ndarray] = None) -> bool:
        aw = self.choose_aw()
        if aw is None:
            return False
        slot = self.slots.alloc(aw)
        prompt = np.asarray(prompt, np.int32)
        batch = {"tokens": jnp.asarray(prompt[None, :])}
        if self.cfg.is_encdec:
            if frames is None:
                frames = np.zeros((self.cfg.encoder_seq, self.cfg.d_model),
                                  np.float32)
            batch["frames"] = jnp.asarray(frames[None])
        # prefill runs on a single healthy AW: other AWs' health must not
        # mask this request's tokens (EW health still applies)
        rs_prefill = self.route_state._replace(
            aw_health=jnp.ones_like(self.route_state.aw_health))
        last_logits, req_cache = self._prefill(
            self.params, batch, rs_prefill, max_seq=self.ecfg.max_seq)
        state = self.layout.request_state(req_cache, 0)
        self.cache = self.layout.write_request_state(self.cache, slot, state)

        first = int(jnp.argmax(last_logits[0]))
        st = RequestState(rid=rid, slot=slot, prompt=prompt, max_new=max_new,
                          tokens=[first], pos=len(prompt),
                          ttft=time.monotonic())
        st._aw = aw
        self.requests[rid] = st

        if self.ecfg.checkpoint:
            ck = self.checkpointers[aw]
            ck.register(rid, prompt_len=len(prompt))
            # bulk-checkpoint the prefill KV (prompt tokens), then stream
            # incrementally per decoded token (§6.1). One batched gather.
            n = len(prompt)
            slots = jnp.full((n,), slot, jnp.int32)
            toks = jnp.arange(n, dtype=jnp.int32)
            stacked = [np.asarray(a)
                       for a in self._extract(self.cache, slots, toks)]
            for t in range(n):
                seg = [a[t] for a in stacked]
                tv = int(prompt[t]) if t + 1 < n else first
                ck.checkpoint_token(rid, t, seg, token_value=tv)
            ck.flush()
        return True

    # ------------------------------------------------------------------
    # decode step
    # ------------------------------------------------------------------
    def active_requests(self) -> List[RequestState]:
        return [r for r in self.requests.values() if not r.done]

    def step(self) -> Dict[str, int]:
        """One decode step over all active slots. Returns {rid: new_token}."""
        act = self.active_requests()
        if not act:
            return {}
        tokens = np.zeros((self.ecfg.max_batch,), np.int32)
        pos = np.zeros((self.ecfg.max_batch,), np.int32)
        for r in act:
            tokens[r.slot] = r.tokens[-1]
            pos[r.slot] = r.pos
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), jnp.asarray(pos), self.cache,
            self.route_state)
        logits = np.asarray(logits)
        out = {}
        now = time.monotonic()
        ck_reqs = [r for r in act
                   if self.ecfg.checkpoint and r.aw not in self.failed_aws]
        stacked = None
        if ck_reqs:
            # single batched device->host gather for all requests' segments
            slots = jnp.asarray([r.slot for r in ck_reqs], jnp.int32)
            toks = jnp.asarray([r.pos for r in ck_reqs], jnp.int32)
            stacked = [np.asarray(a)
                       for a in self._extract(self.cache, slots, toks)]
        ck_index = {r.rid: i for i, r in enumerate(ck_reqs)}
        for r in act:
            nxt = int(np.argmax(logits[r.slot]))
            written_pos = r.pos          # decode wrote KV at this position
            r.pos += 1
            r.tokens.append(nxt)
            r.token_times.append(now)
            out[r.rid] = nxt
            if r.rid in ck_index:
                i = ck_index[r.rid]
                seg = [a[i] for a in stacked]
                self.checkpointers[r.aw].checkpoint_token(
                    r.rid, written_pos, seg, token_value=nxt)
            if len(r.tokens) >= r.max_new or r.pos >= self.ecfg.max_seq - 1:
                r.done = True
        for a, ck in self.checkpointers.items():
            ck.flush()
        self.steps += 1
        return out

    # ------------------------------------------------------------------
    # failure injection & recovery
    # ------------------------------------------------------------------
    def fail_ew(self, ew: int):
        self.failed_ews.add(ew)
        self.route_state = selfheal.fail_ew(self.route_state, ew)

    def fail_aw(self, aw: int):
        """AW crash: its slots (and un-checkpointed state) are gone."""
        self.failed_aws.add(aw)
        self.route_state = selfheal.fail_aw(self.route_state, aw)
        self.slots.drop_aw(aw)

    def recover_aw_requests(self) -> List[str]:
        """Per-request restoration (§6.2): move every affected request to a
        healthy AW, restore committed KV, resume from the committed token."""
        recovered = []
        for aw in sorted(self.failed_aws):
            for rid in self.store.active_requests_on(aw):
                r = self.requests.get(rid)
                if r is None or r.done:
                    continue
                target = self.choose_aw()
                if target is None:
                    continue  # no capacity until provisioning completes
                new_slot = self.slots.alloc(target)
                committed, tok_val, segs = self.store.restore_request(rid)
                self.cache = self.layout.clear_slot(self.cache, new_slot)
                for t, seg in segs.items():
                    self.cache = self.layout.write_token_segment(
                        self.cache, new_slot, t, seg)
                # rewind the request to the committed point
                n_prompt = len(r.prompt)
                n_gen_committed = max(0, committed + 1 - n_prompt) + 1
                r.tokens = r.tokens[:n_gen_committed]
                if tok_val >= 0:
                    r.tokens[-1] = tok_val
                r.pos = committed + 1
                r.slot = new_slot
                r._aw = target
                self.store.reassign(rid, target)
                recovered.append(rid)
        return recovered

    def provision_aw(self, aw: int):
        in_use = {r.slot for r in self.active_requests()}
        self.failed_aws.discard(aw)
        self.slots.restore_aw(aw, in_use)
        self.route_state = selfheal.recover_aw(self.route_state, aw)

    def provision_ew(self, ew: int, repoint_protect: Optional[int] = None):
        self.failed_ews.discard(ew)
        self.route_state = selfheal.recover_ew(self.route_state, ew)
        if repoint_protect is not None:
            self.repoint_shadows(repoint_protect)

    def repoint_shadows(self, protect_ew: int):
        """Background re-pointing of shadow slots (host-side weight push)."""
        if self.api.placement is None or \
                self.api.placement.num_shadow_slots == 0:
            return
        new_rs = None

        def walk(node):
            nonlocal new_rs
            if isinstance(node, dict):
                if "experts" in node and "shadow" in node:
                    rs2, bank = selfheal.repoint_shadows(
                        self.route_state, self.api.placement,
                        node["experts"], protect_ew)
                    new_rs = rs2
                    node = dict(node)
                    node["shadow"] = bank
                    return node
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, tuple):
                return tuple(walk(v) for v in node)
            return node

        self.params = walk(self.params)
        if new_rs is not None:
            self.route_state = new_rs

    def release_request(self, rid: str):
        r = self.requests.pop(rid, None)
        if r is None:
            return
        if r.aw not in self.failed_aws:
            self.cache = self.layout.clear_slot(self.cache, r.slot)
            self.slots.release(r.slot)
        self.store.release(rid)

    # ------------------------------------------------------------------
    def generate(self, rid: str, prompt: np.ndarray, max_new: int
                 ) -> List[int]:
        """Convenience: run one request to completion."""
        assert self.submit(rid, prompt, max_new)
        r = self.requests[rid]
        while not r.done:
            self.step()
        return r.tokens
