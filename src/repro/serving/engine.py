"""Tarragon inference engine — a thin facade over the layered serving stack.

Layers (paper Fig. 5; see ARCHITECTURE.md for the full map):

  * ``Gateway``        (serving/gateway.py)  — admission, FIFO waiting
    queue, pluggable AW placement policy.
  * ``AttentionWorker`` / ``ExpertWorker`` (serving/workers.py) — per-worker
    failure domains: each AW owns its slot partition + checkpoint stream,
    each EW its liveness; ``fail``/``provision`` are worker methods.
  * ``ContinuousBatchScheduler`` (serving/batching.py) — length-bucketed
    batched prefill, per-request restoration for recovery re-admissions,
    and the shared decode step.

The engine itself owns only the *device-side* arrays of the single-process
simulation (params, route state, the slot-partitioned cache pytree) plus
the jitted step functions, and re-exports the historical API
(``submit``/``step``/``generate``/``fail_*``/``provision_*``) so tests,
benchmarks, and the orchestrator keep working unchanged.

Failure API (used by the orchestrator and by tests):
  * ``fail_aw(a)``   — AW a crashes: its slots are lost and its requests
    pause; they re-enter through the Gateway and restore from the
    checkpoint store onto healthy AWs (per-request restoration, §6.2).
  * ``fail_ew(e)``   — EW e crashes: the ERT immediately resolves its
    experts to shadow slots (AW-side self-healing); nothing else changes.
  * ``provision_*`` — background capacity restoration (§5.4).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import selfheal
from repro.core.checkpoint import CheckpointStore
from repro.core.orchestrator import WorkerEvent
from repro.core.placement import ExpertPlacementManager, PlacementPlan
from repro.core.refe import RouteState
from repro.models import get_model
from repro.serving import flightrec
from repro.serving.api import (PREEMPTIBLE_CLASSES, STANDARD, Client,
                               SamplingParams)
from repro.serving.batching import ContinuousBatchScheduler
from repro.serving.chunked import ChunkedPrefillPlane
from repro.serving.controller import ServingController
from repro.serving.decode_loop import DecodeLoopPlane
from repro.serving.gateway import Gateway, QueuedRequest
from repro.serving.kvcache import CacheLayout, PagedCacheLayout, PagePool
from repro.serving.prefixcache import PrefixCachePlane
from repro.serving.telemetry import EventBus, TelemetryPlane
from repro.serving.workers import (AttentionWorker, ClusterSlotView,
                                   ExpertWorker)


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 96
    num_aw: int = 2
    num_ew: int = 2
    max_ew: int = 0                # elastic EW pool ceiling (spare worker
    #                                ids the Orchestrator can scale out
    #                                into; 0 = num_ew, i.e. no spares)
    tarragon: bool = True          # False = MegaScale-style static binding
    checkpoint: bool = True
    checkpoint_reorder: int = 0    # test hook: reorder window for WR arrival
    greedy: bool = True
    temperature: float = 1.0       # sampling temperature (greedy=False)
    top_k: int = 0                 # 0 = full distribution (greedy=False)
    sample_seed: int = 0
    decode_segment_len: int = 1    # decode steps per jitted lax.scan
    #                                segment (serving/decode_loop.py);
    #                                1 = per-step dispatch, today's cadence.
    #                                >1 drains tokens to the host once per
    #                                segment and checkpoints the segment
    #                                through the bulk range path; a failure
    #                                mid-segment rewinds at most this many
    #                                tokens (transformer family only)
    capacity_factor_decode: float = 0.0  # 0 = use model default
    placement: str = "least_loaded"      # Gateway placement policy
    prefill_bucket: int = 16             # padded-prefill length bucket
    # ---- chunked-prefill plane (serving/chunked.py) ----------------------
    chunk_token_budget: int = 0          # real prefill tokens per tick
    #                                      (0 = whole-prompt prefill path)
    chunk_min: int = 8                   # smallest chunk shape; shapes are
    #                                      chunk_min * 2^i (O(log) jit keys)
    prefill_token_cap: int = 0           # Gateway admission cap on
    #                                      outstanding prefill tokens (0 =
    #                                      slot-bound admission only)
    preempt: bool = True                 # blocked interactive heads may
    #                                      checkpoint-and-evict a batch
    #                                      victim (preempt-and-requeue)
    victim_policy: str = "remaining_work"  # preemption victim selection:
    #                                      "remaining_work" (most tokens
    #                                      left, prefill debt included) or
    #                                      "youngest" (latest arrival —
    #                                      the pre-PR-5 behavior)
    # ---- prefix-cache plane (serving/prefixcache.py) ---------------------
    prefix_cache_slots: int = 0          # per-AW cached-prefix slot budget
    #                                      (0 = plane off; requires the
    #                                      chunked plane)
    prefix_cache_tokens: int = 0         # per-AW cached-token budget
    #                                      (0 = slot budget only)
    prefix_min_match: int = 4            # shortest prefix worth adopting
    #                                      (adoption truncates the entry —
    #                                      a trivial coincidental match
    #                                      must not eat a long prefix)
    prefix_restore: bool = True          # restore a dead AW's cached
    #                                      prefixes from the checkpoint
    #                                      store onto healthy AWs
    # ---- paged KV plane (serving/kvcache.py) -----------------------------
    kv_page_tokens: int = 0              # physical KV page extent in tokens
    #                                      (0 = contiguous per-slot cache;
    #                                      >0 needs a pure full-attention
    #                                      cache family and must divide
    #                                      max_seq). Paged slots map pages
    #                                      through a block table; shared
    #                                      prefixes reference the SAME
    #                                      physical pages (refcounted,
    #                                      copy-on-extend at the boundary)
    kv_pages: int = 0                    # per-AW physical page budget
    #                                      (0 = parity with the contiguous
    #                                      footprint: slots_per_aw * nblk;
    #                                      smaller budgets trade capacity
    #                                      against prefix-sharing wins)
    prefix_global_index: bool = False    # lift the per-AW radix indexes to
    #                                      one gateway-level index routing
    #                                      any arrival to its best-match AW
    #                                      cluster-wide (paged mode only)
    prefix_migrate: bool = False         # when the best-match AW cannot
    #                                      take the hit (full or dead),
    #                                      replay the hot prefix onto a
    #                                      healthy AW through the existing
    #                                      checkpoint-store bulk path
    # ---- telemetry plane (serving/telemetry.py) --------------------------
    telemetry: bool = True               # metrics registry + span tracing
    #                                      + stall attribution (host-side
    #                                      only: on/off is bit-identical
    #                                      and trace-count-identical)
    stall_threshold: float = 0.25        # TTFT/TBT gap (virtual s) above
    #                                      which per-cause attribution runs
    hist_buckets_per_decade: int = 32    # streaming-histogram resolution
    #                                      (quantile error = one bucket,
    #                                      ~7.5% at 32)
    trace_export_path: str = ""          # write the Perfetto/Chrome trace
    #                                      here at run finalize ("" = off)
    # ---- control plane (serving/controller.py) ---------------------------
    controller: str = "off"              # "off" (shipped default: every
    #                                      knob stays static, byte-identical
    #                                      to pre-controller behavior) |
    #                                      "on" (one decision pass per tick)
    ctl_autoscale: bool = True           # policy 1: EW pool sizing from
    #                                      queue-depth EMA watermarks
    ctl_rebalance: bool = True           # policy 2: trajectory-triggered
    #                                      rebalance + weighted split plans
    ctl_chunk_budget: bool = True        # policy 3: SLO-headroom-adaptive
    #                                      chunk budget
    ctl_queue_high: float = 3.0          # scale-out watermark (queue EMA)
    ctl_queue_low: float = 0.25          # scale-in watermark (queue EMA;
    #                                      pool must also be idle + above
    #                                      its boot size)
    ctl_scale_dwell: float = 0.0         # debounce between scale decisions
    #                                      (0 = auto: T_w + 2*T_push of the
    #                                      attached orchestrator)
    ctl_headroom: float = 0.25           # interactive deadline headroom
    #                                      (virtual s) under which the
    #                                      chunk budget shrinks
    ctl_budget_min: int = 0              # adaptive-budget floor (0 = auto:
    #                                      max(min_chunk, base/4))
    ctl_budget_max: int = 0              # adaptive-budget ceiling (0 =
    #                                      auto: 4x the configured base)
    ctl_deadline_risk: float = 0.1       # head deadline headroom (virtual
    #                                      s) below which the preemption
    #                                      gate opens (victim_policy=
    #                                      "controller" only)
    ctl_kv_weight: float = 1.0           # victim pricing: weight on the
    #                                      resident/exclusive-KV value
    #                                      subtracted from remaining work
    # ---- forensics plane (serving/flightrec.py) --------------------------
    flight_recorder: bool = True         # black-box FlightRecorder riding
    #                                      the EventBus (host-side only:
    #                                      on/off is bit-identical and
    #                                      trace-count-identical)
    flight_capacity: int = 4096          # ring size for records /
    #                                      submissions / outputs (oldest
    #                                      drop past this; drops counted)
    flight_fingerprint_every: float = 0.5  # virtual-clock period between
    #                                      engine-state fingerprints
    #                                      (0 = only on dump)
    flight_autodump: str = ""            # write a postmortem bundle here
    #                                      on the first failure detection
    #                                      or watchdog trip ("" = off)
    watchdogs: bool = False              # continuous health watchdogs:
    #                                      leak detector, stall-regression
    #                                      detector, invariant probes
    wd_interval: float = 0.25            # watchdog sampling interval
    #                                      (virtual s); watermarks close
    #                                      once per interval
    wd_window: int = 8                   # sliding window length
    #                                      (intervals) for trend tests
    wd_leak_min_drop: int = 2            # free-list watermark drop across
    #                                      a full window that counts as a
    #                                      leak (monotone trend required)
    wd_stall_factor: float = 2.0         # windowed TTFT/TBT p99 multiple
    #                                      over baseline that trips the
    #                                      stall-regression detector
    wd_settle: float = 1.0               # quiet time after a disturbance
    #                                      (fault/scale/preempt) before
    #                                      leak/stall judgments resume


@dataclass
class RequestState:
    rid: str
    slot: int
    prompt: np.ndarray
    max_new: int
    tokens: List[int] = field(default_factory=list)  # generated tokens
    pos: int = 0                  # next position to write
    next_input: int = -1          # token id the next decode step consumes
    done: bool = False
    paused: bool = False          # owning AW died; awaiting re-admission
    queued_for_recovery: bool = False
    prefilling: bool = False      # prompt still streaming through the
    #                               chunked-prefill plane (no decode yet)
    prefill_cursor: int = 0       # prompt tokens already written to cache
    # typed request-lifecycle fields (serving/api.py)
    slo_class: str = STANDARD
    deadline: Optional[float] = None   # virtual-clock first-token deadline
    completion_deadline: Optional[float] = None  # last-token deadline
    sampling: Optional[SamplingParams] = None
    session: Optional[str] = None
    preemptions: int = 0          # planned evictions survived
    cancelled: bool = False
    deadline_flagged: bool = False
    completion_flagged: bool = False   # completion overrun already counted
    prefix_hit: int = 0           # prompt tokens adopted from the prefix
    #                               cache at admission (0 = cold)
    # virtual-clock timeline (all on the serving loop's clock)
    t_enqueue: float = 0.0
    t_admit: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0

    _aw: int = -1

    @property
    def aw(self) -> int:
        return self._aw

    @property
    def state(self) -> str:
        """Lifecycle state machine: queued -> placed -> prefilling ->
        decoding -> {done, preempted, cancelled} (queued is pre-admission,
        i.e. before a RequestState exists; preempted is transient — the
        request re-enters via the recovery path)."""
        if self.cancelled:
            return "cancelled"
        if self.done:
            return "done"
        if self.paused or self.queued_for_recovery:
            return "preempted"
        if self.prefilling:
            return "prefilling"
        return "decoding" if self.tokens else "placed"

    @property
    def ttft(self) -> float:
        """Virtual-clock time-to-first-token (enqueue -> first token)."""
        return self.t_first_token - self.t_enqueue \
            if self.t_first_token >= 0 else -1.0


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig, key=None):
        self.cfg = cfg
        self.ecfg = ecfg
        key = key if key is not None else jax.random.PRNGKey(0)
        # host copy of the init key, pinned so a postmortem bundle can
        # rebuild THIS engine exactly (serving/flightrec.py)
        self.init_key_data = flightrec.key_host_data(key)
        self.api = get_model(cfg, num_aw=ecfg.num_aw, num_ew=ecfg.num_ew,
                             tarragon=ecfg.tarragon)
        self.params = self.api.init_params(key)
        self.route_state: RouteState = self.api.init_route_state()
        # ---- KV plane: contiguous per-slot cache, or paged block tables ---
        # Paged mode (kv_page_tokens > 0) swaps the layout, not the model:
        # the per-layer pools are the ordinary contiguous cache built with
        # batch=num_pages, max_seq=page_tokens, plus one [B, nblk] block
        # table the transformer stack keys its paged attention variants on.
        # The engine is paged or contiguous for life — one trace set either
        # way, and the decision never leaks into jit keys.
        assert ecfg.max_batch % ecfg.num_aw == 0
        self.pages: Optional[PagePool] = None
        if ecfg.kv_page_tokens > 0:
            pt = ecfg.kv_page_tokens
            assert ecfg.max_seq % pt == 0, (
                f"kv_page_tokens={pt} must divide max_seq={ecfg.max_seq}")
            assert not getattr(cfg, "sliding_window", 0), (
                "paged KV requires all-global attention (the block-table "
                "gather has no ring-buffer wrap); set sliding_window=0")
            self.layout = PagedCacheLayout(self.api.init_cache, pt,
                                           ecfg.max_seq)
            self.pages = PagePool(ecfg.max_batch, ecfg.num_aw,
                                  self.layout.nblk, pt,
                                  pages_per_aw=ecfg.kv_pages)
            self.cache = self.layout.make_cache(
                self.api.init_cache, ecfg.max_batch, self.pages.num_pages)
        else:
            self.cache = self.api.init_cache(ecfg.max_batch, ecfg.max_seq)
            self.layout = CacheLayout(self.api.init_cache)
        assert self.pages is not None or not (
            ecfg.prefix_global_index or ecfg.prefix_migrate), (
            "prefix_global_index / prefix_migrate require the paged KV "
            "plane (kv_page_tokens > 0)")
        self.store = CheckpointStore()

        # ---- worker pool: per-worker failure domains ----------------------
        assert ecfg.max_batch % ecfg.num_aw == 0
        per_aw = ecfg.max_batch // ecfg.num_aw
        self.aws = [AttentionWorker(a, a * per_aw, (a + 1) * per_aw,
                                    self.store,
                                    reorder_window=ecfg.checkpoint_reorder)
                    for a in range(ecfg.num_aw)]
        if self.pages is not None:
            for w in self.aws:
                w.page_pool = self.pages
        max_ew = max(ecfg.max_ew or ecfg.num_ew, ecfg.num_ew)
        self.ews = [ExpertWorker(e, member=e < ecfg.num_ew)
                    for e in range(max_ew)]
        self.slots = ClusterSlotView(self.aws, ecfg.max_batch)

        # ---- elastic expert plane (core/placement.py) ---------------------
        # versioned placement plans + load telemetry; the manager's arrays
        # ride RouteState, so every plan install is trace-free
        self.placement_mgr: Optional[ExpertPlacementManager] = None
        self.plan_log: List[WorkerEvent] = []
        if ecfg.tarragon and self.api.placement is not None:
            self.placement_mgr = ExpertPlacementManager(
                self.api.placement, ecfg.num_ew, max_ew=max_ew)
            self.route_state = self.route_state._replace(
                ew_health=jnp.asarray(self.placement_mgr.ew_member_mask()),
                **self._plan_arrays(self.placement_mgr.plan))
        self.collect_load = (self.placement_mgr is not None and
                             self.api.reports_load)

        # ---- request plane ------------------------------------------------
        self.gateway = Gateway(self.aws, policy=ecfg.placement)
        self.scheduler = ContinuousBatchScheduler(
            self, self.gateway, bucket=ecfg.prefill_bucket)
        # ---- telemetry plane (serving/telemetry.py) -----------------------
        # publish-at-emission event bus (multi-consumer, cursor-based) +
        # optional metrics/span/attribution plane. Both are host-side
        # bookkeeping only: no device arrays, no jax calls.
        self.bus = EventBus()
        self.gateway.attach_bus(self.bus)
        self.telemetry: Optional[TelemetryPlane] = \
            TelemetryPlane(self) if ecfg.telemetry else None
        self.gateway.telemetry = self.telemetry
        self.requests: Dict[str, RequestState] = {}
        # typed request-lifecycle plane (serving/api.py): preemption hook,
        # lifecycle event timeline, release listeners for handles
        if ecfg.preempt:
            self.gateway.preemptor = self._preempt_for
        self.request_log: List[WorkerEvent] = []
        self._release_hooks: List[Callable] = []
        self._client: Optional[Client] = None
        self._extract_range = None     # lazy bulk-segment extractor
        self._extract_multi = None     # lazy multi-slot segment extractor

        # ---- jitted step functions ---------------------------------------
        self._extract = self.layout.make_batched_extractor()
        load_static = ("with_load",) if self.api.reports_load else ()
        self._decode = jax.jit(self.api.decode,
                               static_argnames=("capacity",) + load_static)
        # pad-free dispatch (batch["mask"] + real-token capacity) is a
        # transformer-family extension, marked by the prefill_chunk entry
        self.prefill_masked = self.api.prefill_chunk is not None
        pre_static = ("max_seq", "capacity") if self.prefill_masked \
            else ("max_seq",)
        self._prefill = jax.jit(self.api.prefill,
                                static_argnames=pre_static + load_static)
        # device-resident decode loop (serving/decode_loop.py): jitted
        # counter-based sampling + multi-token lax.scan segments. Sampling
        # lives on device for EVERY engine — the host-RNG path is gone.
        self.decode_plane = DecodeLoopPlane(self)
        if ecfg.decode_segment_len > 1:
            assert getattr(self.api, "supports_decode_segments", False), (
                f"decode_segment_len={ecfg.decode_segment_len} requires a "
                f"model family with a segmentable decode step (the "
                f"transformer family); {cfg.name} does not support it")
        self.steps = 0

        # padded prefill is only sound for pure full-attention caches:
        # recurrent-state leaves or ring buffers must never see pad tokens
        # (a layout question, so each layout answers it for its own cache)
        self.prefill_paddable = self.layout.prefill_paddable(
            self.cache, ecfg.max_seq)

        # ---- chunked-prefill plane (serving/chunked.py) -------------------
        # chunked streams need slot == absolute position, i.e. the padded
        # (full-attention) cache family; others keep the whole-prompt path
        self.chunked: Optional[ChunkedPrefillPlane] = None
        if ecfg.chunk_token_budget > 0 and self.prefill_paddable and \
                self.api.prefill_chunk is not None:
            # chunked == whole-prompt bit-identity relies on a common
            # online-softmax KV block partition: both the cache extent and
            # the padded bucket lengths must be PREFILL_BLOCK_K-aligned,
            # or _pick_block silently degrades to mismatched block sizes
            from repro.models.attention import PREFILL_BLOCK_K
            assert ecfg.max_seq % PREFILL_BLOCK_K == 0 and \
                ecfg.prefill_bucket % PREFILL_BLOCK_K == 0, (
                    f"chunked prefill requires max_seq and prefill_bucket "
                    f"to be multiples of PREFILL_BLOCK_K="
                    f"{PREFILL_BLOCK_K} (got max_seq={ecfg.max_seq}, "
                    f"prefill_bucket={ecfg.prefill_bucket})")
            self._prefill_chunk = jax.jit(
                self.api.prefill_chunk,
                static_argnames=("capacity",) + load_static)
            self.chunked = ChunkedPrefillPlane(
                self, ecfg.chunk_token_budget, min_chunk=ecfg.chunk_min)
            self.gateway.prefill_load = self.chunked.outstanding_tokens
        self.gateway.prefill_token_cap = ecfg.prefill_token_cap

        # ---- prefix-cache plane (serving/prefixcache.py) ------------------
        # per-AW radix index over committed KV prefixes: finished slots are
        # adopted instead of cleared, and later prompts sharing a prefix
        # chunk-prefill only the uncached tail. Requires the chunked plane
        # (adoption IS a mid-prompt resume of the chunk stream).
        self.prefix_plane: Optional[PrefixCachePlane] = None
        if ecfg.prefix_cache_slots > 0:
            assert self.chunked is not None, (
                "prefix_cache_slots > 0 requires the chunked-prefill plane "
                "(chunk_token_budget > 0 on a full-attention cache family)")
            self.prefix_plane = PrefixCachePlane(
                self, ecfg.prefix_cache_slots, ecfg.prefix_cache_tokens,
                min_match=ecfg.prefix_min_match)
        assert self.prefix_plane is not None or not (
            ecfg.prefix_global_index or ecfg.prefix_migrate), (
            "prefix_global_index/prefix_migrate require the prefix-cache "
            "plane (prefix_cache_slots > 0)")
        assert ecfg.victim_policy in ("remaining_work", "youngest",
                                      "controller"), (
            f"unknown victim_policy {ecfg.victim_policy!r}")

        # ---- control plane (serving/controller.py) ------------------------
        # one decision pass per tick over signals the stack already emits,
        # actuating only through existing mechanisms — host-side only, so
        # controller on/off is bit-identical under identical decisions and
        # adds zero new jit traces by construction
        assert ecfg.controller in ("off", "on"), (
            f"unknown controller mode {ecfg.controller!r}")
        self.controller: Optional[ServingController] = None
        if ecfg.controller == "on":
            self.controller = ServingController(self)
        assert ecfg.victim_policy != "controller" or \
            self.controller is not None, (
            'victim_policy="controller" requires controller="on"')

        # ---- forensics plane (serving/flightrec.py) -----------------------
        # bounded-memory black box + health watchdogs, riding the bus as
        # its own consumer. Host-side bookkeeping only, like telemetry:
        # on/off is bit-identical and adds zero new jit traces.
        self.flightrec: Optional[flightrec.FlightRecorder] = None
        if ecfg.flight_recorder:
            self.flightrec = flightrec.FlightRecorder(self)
        self.gateway.flightrec = self.flightrec
        assert not ecfg.watchdogs or ecfg.flight_recorder, (
            "watchdogs=True requires flight_recorder=True (the watchdogs "
            "ride the recorder's bus cursor and trip its dump)")

    # ------------------------------------------------------------------
    # decode routing capacity (§5.2): the decode path may run at a tighter
    # capacity factor than prefill — fewer tokens per step means the
    # default (prefill-sized) factor over-provisions slot capacity
    # ------------------------------------------------------------------
    @property
    def decode_capacity(self) -> Optional[int]:
        cf = self.ecfg.capacity_factor_decode
        if not cf or not self.cfg.moe.enabled:
            return None
        return int(max(1, round(cf * self.cfg.moe.top_k *
                                self.ecfg.max_batch /
                                self.cfg.moe.num_experts)))

    def prefill_capacity(self, n_real_tokens: int) -> Optional[int]:
        """Expert capacity for a prefill/chunk call, computed from the
        REAL token count (pads are excluded from rank competition by the
        dispatch mask) and rounded up to a power of two — jit keys stay
        bounded, and a request's routing no longer depends on how much
        padding its batch happens to carry."""
        if not self.prefill_masked or not self.cfg.moe.enabled:
            return None
        cap = int(max(1, round(self.cfg.moe.capacity_factor *
                               self.cfg.moe.top_k * n_real_tokens /
                               self.cfg.moe.num_experts)))
        p = 1
        while p < cap:
            p *= 2
        return p

    # ------------------------------------------------------------------
    # sampling (the decode head): device-resident, serving/decode_loop.py.
    # The host shim below survives only for external callers.
    # ------------------------------------------------------------------
    def sample_token(self, row_logits: np.ndarray,
                     sampling: Optional[SamplingParams] = None, *,
                     seed: Optional[int] = None, pos: int = 0) -> int:
        """DEPRECATED host-side sampling shim. The serving stack samples on
        device (``decode_plane``); this remains for external callers that
        hold host logits. Top-k slices the k candidate rows *before* the
        softmax (float32 throughout — no full-vocab float64 partition), and
        the draw is counter-based (Philox keyed on (seed, pos)) instead of
        stateful, matching the device sampler's reproducibility contract
        though not its bitstream."""
        greedy = self.ecfg.greedy if sampling is None else sampling.greedy
        temperature = self.ecfg.temperature if sampling is None \
            else sampling.temperature
        top_k = self.ecfg.top_k if sampling is None else sampling.top_k
        if greedy:
            return int(np.argmax(row_logits))
        logits = np.asarray(row_logits, np.float32)
        v = logits.size
        if top_k and top_k < v:
            idx = np.argpartition(logits, v - top_k)[v - top_k:]
        else:
            idx = np.arange(v)
        sub = logits[idx] / np.float32(max(temperature, 1e-6))
        sub = sub - sub.max()
        p = np.exp(sub)
        p /= p.sum()
        s = self.ecfg.sample_seed if seed is None else seed
        rng = np.random.Generator(
            np.random.Philox(key=[s & 0xFFFFFFFFFFFFFFFF, max(pos, 0)]))
        return int(idx[rng.choice(idx.size, p=p)])

    # ------------------------------------------------------------------
    # admission (delegates to Gateway + ContinuousBatchScheduler)
    # ------------------------------------------------------------------
    def choose_aw(self) -> Optional[int]:
        return self.gateway.choose_aw()

    def make_request_state(self, q: QueuedRequest, slot: int
                           ) -> RequestState:
        st = RequestState(rid=q.rid, slot=slot, prompt=q.prompt,
                          max_new=q.max_new, t_enqueue=q.t_enqueue,
                          slo_class=q.slo_class, deadline=q.deadline,
                          completion_deadline=q.completion_deadline,
                          sampling=q.sampling, session=q.session,
                          prefix_hit=q.prefix_hit,
                          # a miss flagged while queued is not re-flagged
                          deadline_flagged=q.deadline_flagged,
                          completion_flagged=q.completion_flagged)
        # slot-indexed sampling arrays ride the slot assignment (recovery
        # re-binds through _install_recovery)
        self.decode_plane.bind(st)
        return st

    @property
    def client(self) -> Client:
        """The typed request-API front door (serving/api.py): submit
        ``RequestSpec``s, get ``RequestHandle``s with status/streaming/
        cancel. Lazily constructed; multiple explicit Clients over one
        engine are also fine."""
        if self._client is None:
            self._client = Client(self)
        return self._client

    def add_release_hook(self, fn: Callable):
        """Register fn(RequestState) to run when a request is released
        (done, cancelled, or torn down) — clients pin final states onto
        their handles through this."""
        self._release_hooks.append(fn)

    def submit(self, rid: str, prompt: np.ndarray, max_new: int,
               frames: Optional[np.ndarray] = None,
               now: float = 0.0) -> bool:
        """DEPRECATED positional shim over the typed request API: enqueue
        as a standard-class request and admit immediately; refuse (rather
        than queue) when no AW has capacity — the historical synchronous
        semantics, pinned by tests/test_request_api.py. New code should use
        ``engine.client.submit(RequestSpec(...))``, which queues instead of
        refusing and returns a RequestHandle."""
        warnings.warn(
            "InferenceEngine.submit(rid, prompt, max_new) is deprecated; "
            "use engine.client.submit(RequestSpec(...)) -> RequestHandle",
            DeprecationWarning, stacklevel=2)
        return self._submit_sync(rid, prompt, max_new, frames=frames,
                                 now=now)

    def _submit_sync(self, rid: str, prompt: np.ndarray, max_new: int,
                     frames: Optional[np.ndarray] = None,
                     now: float = 0.0, slo_class: str = STANDARD,
                     deadline: Optional[float] = None,
                     sampling: Optional[SamplingParams] = None,
                     session: Optional[str] = None) -> bool:
        """Synchronous admission (internal): enqueue and admit immediately;
        refuse (rather than queue) when no AW has capacity — the
        waiting-queue path is the serving loop's (run_serving drives the
        Gateway directly)."""
        self.gateway.enqueue(rid, prompt, max_new, now=now, frames=frames,
                             slo_class=slo_class, deadline=deadline,
                             sampling=sampling, session=session)
        admitted = self.scheduler.admit(now)
        if rid in admitted:
            return True
        self.gateway.drop(rid)
        if self.telemetry is not None:
            self.telemetry.on_drop(rid, now, "refused")
        return False

    # ------------------------------------------------------------------
    # decode step (delegates to the scheduler)
    # ------------------------------------------------------------------
    def active_requests(self) -> List[RequestState]:
        return [r for r in self.requests.values()
                if not r.done and not r.paused and not r.prefilling]

    def prefilling_requests(self) -> List[RequestState]:
        return [r for r in self.requests.values()
                if r.prefilling and not r.done and not r.paused]

    def step(self, now: Optional[float] = None) -> Dict[str, List[int]]:
        """One iteration: a budgeted slice of chunked prefill (when the
        plane is on) followed by one decode *segment* over all active slots
        (``decode_segment_len`` device steps per dispatch; 1 = classic
        per-step cadence). Returns {rid: new_tokens} — one entry per token
        the segment emitted for that request."""
        return self.scheduler.step(now)

    # ------------------------------------------------------------------
    # prefill accounting (virtual-clock work charging + metrics)
    # ------------------------------------------------------------------
    def prefill_tokens_done(self) -> int:
        """Total real prompt tokens prefilled so far, across the
        whole-prompt path and the chunked plane."""
        n = self.scheduler.stats.real_tokens
        if self.chunked is not None:
            n += self.chunked.stats.real_tokens
        return n

    def prefill_snapshot(self) -> dict:
        snap = self.scheduler.stats.snapshot()
        if self.chunked is not None:
            snap["chunked"] = self.chunked.stats.snapshot()
        return snap

    # ------------------------------------------------------------------
    # request lifecycle: preemption, cancellation, deadlines
    # (serving/api.py) — the recovery subsystem doubling as the
    # scheduling substrate: a preempted request is checkpointed out of
    # its slot and re-enters exactly like a crash-recovered one.
    # ------------------------------------------------------------------
    def _note_request_event(self, kind: str, rid: str, now: float,
                            detail: str = ""):
        ev = WorkerEvent(now, kind, rid, detail)
        self.request_log.append(ev)
        # publish-at-emission: the bus carries the same event for every
        # cursor-based consumer; the request_log stays as a legacy
        # destructive view for the orchestrator timeline
        self.bus.publish(ev)
        if self.telemetry is not None:
            self.telemetry.on_request_event(ev)

    def drain_request_events(self) -> List[WorkerEvent]:
        evs, self.request_log = self.request_log, []
        # placement-plane events (session_repinned) ride the same timeline
        evs = evs + self.gateway.drain_events()
        return evs

    @staticmethod
    def _remaining_work(r: RequestState) -> int:
        """Remaining-work estimate for victim selection: decode tokens
        still owed plus the prefill debt (un-prefilled prompt tokens) —
        a mid-prefill request has barely invested anything yet, so it is
        the cheapest to push aside."""
        debt = (len(r.prompt) - 1 - r.prefill_cursor) if r.prefilling else 0
        return (r.max_new - len(r.tokens)) + debt

    def _choose_victim(self, exclude: str = "", head=None,
                       now: float = 0.0) -> Optional[RequestState]:
        """Pick the preemption victim among preemptible-class requests
        resident on live AWs.

        ``victim_policy="remaining_work"`` (default): evict the request
        with the MOST work left (``max_new - emitted`` plus prefill debt)
        — it has invested the least and wastes the fewest finished
        tokens. ``victim_policy="youngest"``: the pre-PR-5 behavior — the
        latest arrival by ``t_enqueue`` (stable across restores, unlike
        ``t_admit``, which resets on every re-admission and would pin the
        same just-restored victim in an evict/restore ping-pong). Both
        policies prefer, among equals, the candidate evicted the fewest
        times (repeated preemptions rotate through a wave instead of
        starving one rid), with a final rid tie-break for determinism.

        ``victim_policy="controller"`` delegates to the control plane's
        deadline- and prefix-aware policy: batch work is evicted only when
        the blocked head's deadline is actually at risk, and the victim
        score prices in its exclusive paged-KV / resident-prefix value
        (an eviction tears that down and the restore path must rebuild
        it). The candidate filter is shared, so interactive work can
        never be a victim under ANY policy."""
        cands = [r for r in self.requests.values()
                 if r.slo_class in PREEMPTIBLE_CLASSES and not r.done
                 and not r.paused and not r.cancelled
                 and not r.queued_for_recovery and r.rid != exclude
                 and r._aw >= 0 and self.aws[r._aw].alive]
        if not cands:
            return None
        if self.ecfg.victim_policy == "controller":
            return self.controller.choose_victim(cands, head=head, now=now)
        if self.ecfg.victim_policy == "youngest":
            return max(cands, key=lambda r: (r.t_enqueue, -r.preemptions,
                                             r.rid))
        return max(cands, key=lambda r: (self._remaining_work(r),
                                         -r.preemptions, r.rid))

    def _preempt_for(self, head: QueuedRequest, now: float) -> bool:
        """Gateway preemptor hook: a blocked interactive head asks for a
        slot; evict a batch victim if one exists."""
        victim = self._choose_victim(exclude=head.rid, head=head, now=now)
        if victim is None:
            return False
        return self.preempt_request(victim.rid, now=now)

    def preempt_request(self, rid: str, now: float = 0.0) -> bool:
        """Planned eviction (preempt-and-requeue): commit the victim's
        resident KV to the checkpoint store through the bulk-segment path,
        release its slot, and requeue it as a recovery entry at the front
        of its class queue. On re-admission it restores the committed
        prefix and resumes from the cursor — decode requests rewind zero
        tokens (the watermark is flushed first), chunked-prefill requests
        resume mid-stream. Preemption is failure you chose: it rides
        §6.1/§6.2 unchanged, needs no health-mask flip, and triggers no
        new jit traces."""
        r = self.requests.get(rid)
        if r is None or r.done or r.paused or r.cancelled or \
                r.queued_for_recovery or r._aw < 0:
            return False
        aw = self.aws[r._aw]
        if not aw.alive:
            return False
        committed = self._commit_resident_kv(r)
        if self.chunked is not None:
            self.chunked.drop(rid)
        aw.prefills.pop(rid, None)
        if self.prefix_plane is not None:
            # an adopted prefix entry cannot outlive the eviction: the
            # slot is about to be cleared (the victim's own log carries
            # everything it needs to resume)
            self.prefix_plane.forget_slot(r._aw, r.slot)
        self._kv_clear_slot(r.slot)
        aw.slots.release(r.slot)
        r.paused = True
        r.queued_for_recovery = True
        r.preemptions += 1
        self.gateway.requeue_recovery([QueuedRequest(
            rid, r.prompt, r.max_new, frames=None, t_enqueue=now,
            slo_class=r.slo_class, deadline=r.deadline,
            completion_deadline=r.completion_deadline,
            completion_flagged=r.completion_flagged,
            sampling=r.sampling, session=r.session)])
        self.gateway.stats.preemptions += 1
        self.gateway.stats.bump(r.slo_class, "preempted")
        self._note_request_event(
            "preempted", rid, now,
            f"slot freed on aw{aw.aw_id}, resume@{committed + 1}")
        if self.telemetry is not None:
            self.telemetry.on_preempt(rid, now)
        return True

    def _commit_resident_kv(self, r: RequestState) -> int:
        """Bring the checkpoint store's commit watermark up to the
        victim's full resident state. Planned eviction *delivers* pending
        WRs (flush) — this is not a crash — and any resident KV beyond the
        watermark (e.g. the whole prefix on a checkpoint=False engine)
        streams out through the bulk-segment path
        (``KVCheckpointer.checkpoint_range``). Returns the committed token
        index the request will resume from."""
        ck = self.aws[r._aw].checkpointer
        n = len(r.prompt)
        if self.ecfg.checkpoint:
            ck.flush()
        else:
            # un-protected request: first eviction registers it with the
            # store (preemption turns checkpointing on for this rid alone)
            ck.register(r.rid, prompt_len=n)
        committed = self.store.committed_token(r.rid)
        last = (r.prefill_cursor if r.prefilling else r.pos) - 1
        if committed < last:
            self._bulk_checkpoint(r, committed + 1, last)
            ck.flush()
            committed = self.store.committed_token(r.rid)
        assert committed == last, (
            f"preempt {r.rid}: watermark {committed} != resident {last}")
        return committed

    def _bulk_checkpoint(self, r: RequestState, start: int, last: int):
        """Stream token segments [start, last] of the request's slot to
        the store via the bulk range extractor (chunk-shaped static counts
        keep jit keys O(log max_seq))."""
        if self._extract_range is None:
            # share the chunked plane's jitted extractor when it exists —
            # an identical second extractor would just double the traces
            self._extract_range = self.chunked._extract_range \
                if self.chunked is not None \
                else self.layout.make_slot_range_extractor()
        ck = self.aws[r._aw].checkpointer
        if self.chunked is not None:
            # the shared extractor was traced with the plane's shape set —
            # use the same cap so bulk segments never mint a new jit key
            max_shape = self.chunked.max_shape
        else:
            max_shape = 1
            while max_shape * 2 <= self.ecfg.max_seq:
                max_shape *= 2
        t = start
        while t <= last:
            count = min(last - t + 1, max_shape)
            shape = 1
            while shape < count:
                shape *= 2
            shape = min(shape, max_shape)
            base = max(0, min(t, self.ecfg.max_seq - shape))
            seg_stack = [np.asarray(a)[t - base:t - base + count]
                         for a in self._extract_range(
                             self.cache, r.slot, base, count=shape)]
            self._ck_range(ck, r.rid, t, seg_stack,
                           [self._ck_token_value(r, i)
                            for i in range(t, t + count)])
            t += count

    @staticmethod
    def _ck_token_value(r: RequestState, t: int) -> int:
        # the store hands back position t's *next decode input*: a prompt
        # token while t+1 is still in the prompt, else the generated token
        # whose sampling consumed position t
        n = len(r.prompt)
        if t + 1 < n:
            return int(r.prompt[t + 1])
        k = t - n + 1
        return int(r.tokens[k]) if 0 <= k < len(r.tokens) else -1

    def _bulk_checkpoint_group(self, items):
        """Segment-boundary checkpointing for MANY requests in one device
        gather (the per-segment analogue of the per-token batched
        extract): ``items`` is [(request, start, n_tokens)]. Requests are
        grouped by pow2 segment shape and rows pow2-padded, so one jitted
        multi-slot extract serves the whole decode segment; segments then
        fan out to each request's AW checkpointer host-side."""
        if self._extract_multi is None:
            self._extract_multi = self.layout.make_multi_slot_range_extractor()
        if self.chunked is not None:
            max_shape = self.chunked.max_shape
        else:
            max_shape = 1
            while max_shape * 2 <= self.ecfg.max_seq:
                max_shape *= 2
        groups: Dict[int, list] = {}
        for r, start, cnt in items:
            if cnt <= 0:
                continue
            if cnt > max_shape:    # oversized: the scalar path chunks it
                self._bulk_checkpoint(r, start, start + cnt - 1)
                continue
            shape = 1
            while shape < cnt:
                shape *= 2
            groups.setdefault(shape, []).append((r, start, cnt))
        for shape, ent in sorted(groups.items()):
            rows = 1
            while rows < len(ent):
                rows *= 2
            slots = np.zeros((rows,), np.int32)
            bases = np.zeros((rows,), np.int32)
            for i, (r, start, _) in enumerate(ent):
                slots[i] = r.slot
                bases[i] = max(0, min(start, self.ecfg.max_seq - shape))
            stacked = [np.asarray(a) for a in self._extract_multi(
                self.cache, jnp.asarray(slots), jnp.asarray(bases),
                count=shape)]
            for i, (r, start, cnt) in enumerate(ent):
                off = start - bases[i]
                seg_stack = [a[i][off:off + cnt] for a in stacked]
                self._ck_range(self.aws[r._aw].checkpointer,
                               r.rid, start, seg_stack,
                               [self._ck_token_value(r, t)
                                for t in range(start, start + cnt)])

    def _ck_range(self, ck, rid: str, start: int, seg_stack, token_values):
        """Bulk-range checkpointing, block-granular on a paged engine: WR
        batches split at physical page boundaries (checkpoint_blocks), so
        a page's worth of KV commits or dies together. The store's
        segments stay token-granular and layout-independent either way —
        a paged AW's checkpoints restore onto a contiguous engine and
        vice versa."""
        if self.pages is not None:
            ck.checkpoint_blocks(rid, start, seg_stack, token_values,
                                 self.pages.page_tokens)
        else:
            ck.checkpoint_range(rid, start, seg_stack, token_values)

    # ------------------------------------------------------------------
    # paged-KV facades: every clear / scrub / extend of a slot's resident
    # KV routes through here so contiguous and paged engines share call
    # sites (chunked planner, batching, recovery, preemption, release).
    # On a contiguous engine each facade is a pass-through to the layout;
    # on a paged engine it also runs the host allocator (refcounts, per-AW
    # free lists) and keeps the device block table in sync. All device
    # work goes through jitted-once helpers — zero new traces at runtime.
    # ------------------------------------------------------------------
    def _kv_sync_bt(self):
        """Upload the host block-table mirror when it drifted (a [B,nblk]
        int32 copy — the only per-allocation device traffic)."""
        if self.pages is not None and self.pages.dirty:
            self.cache = self.layout.set_block_table(self.cache,
                                                     self.pages.bt)
            self.pages.dirty = False

    def _kv_free_pages(self, pids):
        """Scrub freed pages' positions on device before they can
        recycle: a stale ``pos >= 0`` entry would leak the old mapper's
        KV into the next mapper's attention."""
        if pids:
            self.cache = self.layout.scrub_pages(self.cache, pids)

    def _kv_reclaim(self, aw: int):
        """Page pressure: evict cached prefixes on ``aw`` (tail pages
        first, exclusive pages only ever free — a page with refcount > 1
        survives its holder) until a page frees or nothing is evictable."""
        pc = self.aws[aw].prefix_cache
        evict = getattr(pc, "evict_pages", None)
        while self.pages.free_pages(aw) == 0 and evict is not None:
            freed = evict()
            if not freed:
                break
            self._kv_free_pages(freed)

    def _kv_ensure(self, slot: int, upto: int):
        """Pre-allocate pages so positions [0, upto) of ``slot`` are
        mapped before a prefill chunk / decode segment writes them.
        No-op on a contiguous engine (the slot owns its whole extent)."""
        if self.pages is None or upto <= 0:
            return
        pool = self.pages
        need = -(-min(upto, self.ecfg.max_seq) // pool.page_tokens)
        aw = pool.aw_of_slot(slot)
        for blk in range(need):
            if pool.bt[slot, blk] > 0:
                continue
            pid = pool.alloc(aw)
            if pid < 0:
                self._kv_reclaim(aw)
                pid = pool.alloc(aw)
            if pid < 0:
                raise RuntimeError(
                    f"AW{aw} out of KV pages: slot {slot} needs block "
                    f"{blk} ({need} total) and nothing is evictable")
            pool.map_block(slot, blk, pid)
        self._kv_sync_bt()

    def _kv_clear_slot(self, slot: int):
        """Release a slot's resident KV. Contiguous: scrub the slot's
        rows. Paged: unmap the block-table row and decref its pages —
        pages shared with a cached prefix entry (or another adopter)
        survive; exclusive pages scrub and return to the AW's free
        list."""
        if self.pages is None:
            self.cache = self.layout.clear_slot(self.cache, slot)
            return
        self._kv_free_pages(self.pages.release_slot(slot))
        self.cache = self.layout.clear_slot(self.cache, slot)
        self._kv_sync_bt()

    def _kv_scrub_slot(self, slot: int, valid_len: int):
        """Mask positions >= valid_len in the slot (prefix adoption keeps
        [0, valid_len) live). Paged writes to shared pages are value-
        identical by construction — a fully-shared page only holds
        positions below the hit."""
        self.cache = self.layout.scrub_slot(self.cache, slot, valid_len)

    def _kv_adopt(self, slot: int, pages, hit: int) -> int:
        """Map a cached prefix entry's pages into ``slot`` (copy-on-
        extend): pages fully below the hit are SHARED — the same physical
        page, refcount bumped, zero KV copied — and the boundary page
        (the one the adopter will extend past the hit) is duplicated into
        a private page. Returns the usable hit length: when no page is
        free for the boundary copy it degrades to the last full-page
        boundary rather than failing the adoption."""
        pool = self.pages
        pt = pool.page_tokens
        full = min(hit // pt, len(pages))
        aw = pool.aw_of_slot(slot)
        for b in range(full):
            pool.incref(pages[b])
            pool.map_block(slot, b, pages[b])
        rem = hit - full * pt
        if rem > 0 and full < len(pages):
            # pin the boundary source first: reclaim may trim the very
            # entry being adopted, and an unpinned boundary page could be
            # freed (and scrubbed) before the copy reads it
            src = int(pages[full])
            pool.incref(src)
            pid = pool.alloc(aw)
            if pid < 0:
                self._kv_reclaim(aw)
                pid = pool.alloc(aw)
            if pid < 0:
                hit = full * pt          # degrade: share whole pages only
            else:
                self.cache = self.layout.copy_page(self.cache, src, pid)
                pool.map_block(slot, full, pid)
            if pool.decref(src):
                self._kv_free_pages([src])
        elif rem > 0:
            hit = full * pt
        self._kv_sync_bt()
        return hit

    def _kv_snapshot(self, slot: int, n: int):
        """Pin the pages covering positions [0, n) of ``slot`` (one
        reference each) — the backing of a new prefix-cache entry. The
        entry's references keep the pages alive after the slot itself
        releases."""
        pool = self.pages
        blocks = -(-n // pool.page_tokens)
        pids = pool.slot_pages(slot, upto_blocks=blocks)
        for pid in pids:
            pool.incref(pid)
        return pids

    def cancel_request(self, rid: str, now: float = 0.0) -> bool:
        """Cancel a request anywhere in its lifecycle. Queued: the entry
        leaves its class queue. In flight: full teardown — the owning AW's
        slot is released, its pending checkpoint WRs and prefill cursor
        dropped, the chunk stream closed, and the store log freed.
        Preempted/paused: the recovery entry is dropped too. Other
        requests are untouched."""
        r = self.requests.get(rid)
        if r is None:
            entry = self.gateway.drop(rid)
            if entry is None:
                return False
            self.gateway.stats.bump(entry.slo_class, "cancelled")
            self._note_request_event("cancelled", rid, now, "while queued")
            if self.telemetry is not None:
                self.telemetry.on_drop(rid, now, "cancelled")
            return True
        if r.done:
            return False
        r.cancelled = True
        r.done = True
        self.gateway.stats.bump(r.slo_class, "cancelled")
        self._note_request_event("cancelled", rid, now, r.state)
        if self.telemetry is not None:
            self.telemetry.on_cancel(rid, now, "in_flight")
        self.release_request(rid)
        return True

    def _deadline_pass(self, now: float, *, completion: bool):
        """One flag-once sweep for one deadline kind, over both the
        Gateway queues and the resident requests. The kind differs only
        in which field/flag/counter it touches and in its met-SLO rule:
        first-token misses are excused when the first token landed in
        time (a crash-recovery entry of a request that already met its
        SLO is not a fresh miss), completion misses when the request is
        done."""
        attr = "completion_flagged" if completion else "deadline_flagged"
        counter = "completion_deadline_missed" if completion \
            else "deadline_missed"
        tag = "completion, " if completion else ""

        def deadline_of(x):
            return x.completion_deadline if completion else x.deadline

        for cls, q in self.gateway.queues.items():
            for e in q:
                dl = deadline_of(e)
                if dl is None or getattr(e, attr) or now <= dl:
                    continue
                setattr(e, attr, True)
                r = self.requests.get(e.rid)
                if r is not None:
                    if getattr(r, attr):
                        continue
                    if not completion and 0 <= r.t_first_token <= dl:
                        continue
                    setattr(r, attr, True)
                self.gateway.stats.bump(cls, counter)
                self._note_request_event("deadline_missed", e.rid, now,
                                         f"{tag}queued, deadline={dl:g}")
        for r in self.requests.values():
            dl = deadline_of(r)
            if dl is None or getattr(r, attr):
                continue
            if not completion and r.t_first_token >= 0:
                # admitted-late case: the first token itself arrived past
                # the deadline (possibly in the same tick as admission)
                if r.t_first_token <= dl:
                    continue
            elif r.done or now <= dl:
                continue
            setattr(r, attr, True)
            self.gateway.stats.bump(r.slo_class, counter)
            self._note_request_event("deadline_missed", r.rid, now,
                                     f"{tag}{r.state}, deadline={dl:g}")

    def check_deadlines(self, now: float):
        """Emit ``deadline_missed`` once per request whose first-token
        deadline passed — whether it is still queued at the Gateway or
        resident without a first token — and once per request whose
        **completion deadline** passed before its last token (counted
        separately as ``completion_deadline_missed``). The request is NOT
        dropped either way: deadlines are SLO signals (per-class counters
        in GatewayStats), not admission filters."""
        self._deadline_pass(now, completion=False)
        self._deadline_pass(now, completion=True)

    # ------------------------------------------------------------------
    # failure injection & recovery (delegates to the worker objects)
    # ------------------------------------------------------------------
    @property
    def failed_aws(self) -> set:
        return {w.aw_id for w in self.aws if not w.alive}

    @property
    def failed_ews(self) -> set:
        return {w.ew_id for w in self.ews if w.member and not w.alive}

    @property
    def live_ews(self) -> set:
        return {w.ew_id for w in self.ews if w.member and w.alive}

    @property
    def checkpointers(self) -> dict:
        return {w.aw_id: w.checkpointer for w in self.aws}

    def fail_ew(self, ew: int):
        self.route_state = self.ews[ew].fail(self.route_state)

    def fail_aw(self, aw: int):
        """AW crash: its slots (and un-checkpointed state) are gone; its
        requests pause until re-admitted through the Gateway. Requests
        with no checkpoint record (checkpoint=False) cannot be restored:
        they keep decoding against the dead worker's slot — the simulated
        data loss of a system without Tarragon's store — instead of being
        stranded in a paused state forever. Requests caught mid-prefill are
        preempted the same way: their chunk stream stops and recovery will
        resume it from the committed cursor."""
        if self.prefix_plane is not None:
            # snapshot the dying AW's cached prefixes before fail() clears
            # them: checkpoint-backed entries become restorable orphans
            self.prefix_plane.note_aw_failed(aw)
        if self.pages is not None:
            # the AW's physical pages die with it: drop the cache entries'
            # references first (orphan metadata is already snapshotted —
            # restoration replays from the store into fresh pages), then
            # unmap the partition's slots. Slots of UNRECOVERABLE requests
            # (no store record) keep their pages: those requests keep
            # decoding against the dead worker's state, mirroring the
            # contiguous engine's simulated-data-loss behaviour below.
            # Freed pages scrub so the clean-page invariant holds
            # unconditionally at re-provision.
            rec = set(self.store.active_requests_on(aw))
            keep = {r.slot for r in self.requests.values()
                    if r._aw == aw and not r.done and r.rid not in rec}
            freed = []
            pc = self.aws[aw].prefix_cache
            if pc is not None and hasattr(pc, "release_all_pages"):
                freed += pc.release_all_pages()
            per = self.slots.per_aw
            for s in range(aw * per, (aw + 1) * per):
                if s not in keep:
                    freed += self.pages.release_slot(s)
            self._kv_free_pages(freed)
            self._kv_sync_bt()
        self.route_state = self.aws[aw].fail(self.route_state)
        recoverable = set(self.store.active_requests_on(aw))
        if self.chunked is not None and self.ecfg.checkpoint:
            self.chunked.drop_aw(aw)
        for r in self.requests.values():
            if r._aw == aw and not r.done and r.rid in recoverable:
                r.paused = True

    def recover_aw_requests(self, now: float = 0.0) -> List[str]:
        """Per-request restoration (§6.2): requeue every affected request
        through the Gateway (front of the FIFO — they are the oldest work)
        and admit as many as current capacity allows; the rest stay queued
        and retry on subsequent ticks instead of being dropped. Returns the
        rids restored *now*."""
        entries = []
        for aw in sorted(self.failed_aws):
            for rid in self.store.active_requests_on(aw):
                r = self.requests.get(rid)
                if r is None or r.done or r.queued_for_recovery:
                    continue
                r.queued_for_recovery = True
                if self.telemetry is not None:
                    self.telemetry.on_failover(rid, now)
                # the recovery waiting spell starts now, not at arrival;
                # class/deadline/sampling survive the crash with the state
                entries.append(QueuedRequest(
                    rid, r.prompt, r.max_new, t_enqueue=now,
                    slo_class=r.slo_class, deadline=r.deadline,
                    completion_deadline=r.completion_deadline,
                    completion_flagged=r.completion_flagged,
                    sampling=r.sampling, session=r.session))
        self.gateway.requeue_recovery(entries)
        admitted = set(self.scheduler.admit(now))
        if self.prefix_plane is not None:
            # live requests took their slots first; now carry the dead
            # AWs' cached session prefixes over to healthy AWs (§6.2
            # applied to cache state) so future turns still hit
            self.prefix_plane.restore_orphans(now)
        return [q.rid for q in entries if q.rid in admitted]

    def provision_aw(self, aw: int):
        in_use = {r.slot for r in self.active_requests()}
        self.route_state = self.aws[aw].provision(self.route_state, in_use)

    def provision_ew(self, ew: int, repoint_protect: Optional[int] = None,
                     now: float = 0.0):
        self.route_state = self.ews[ew].provision(self.route_state)
        if self.placement_mgr is not None and \
                ew not in self.placement_mgr.members:
            self.placement_mgr.members = sorted(
                self.placement_mgr.members + [ew])
        if repoint_protect is not None:
            self.repoint_shadows(repoint_protect, now=now)

    def repoint_shadows(self, protect_ew: int, now: float = 0.0):
        """Background re-pointing of replica slots to protect ``protect_ew``
        (host-side weight push, off the failover critical path). With a
        placement manager this is a versioned plan install; the bank is
        gathered through ``slot_expert``, so no parameter surgery either
        way."""
        if self.api.placement is None or \
                self.api.placement.num_shadow_slots == 0:
            return
        if self.placement_mgr is not None:
            self.install_plan(
                self.placement_mgr.plan_reprotect(
                    protect_ew, dead_ews=tuple(self.failed_ews)), now=now)
        else:
            self.route_state = selfheal.repoint_shadows(
                self.route_state, self.api.placement, protect_ew)

    # ------------------------------------------------------------------
    # elastic expert plane (core/placement.py): versioned plan installs,
    # EW scale-out/scale-in, shadow promotion, load-aware rebalancing.
    # Every transition below is a pure RouteState array update — the jitted
    # decode/prefill steps never re-trace across placement generations.
    # ------------------------------------------------------------------
    def _plan_arrays(self, plan: PlacementPlan) -> dict:
        return dict(
            candidates=jnp.asarray(plan.candidates(), jnp.int32),
            slot_expert=jnp.asarray(plan.slot_expert, jnp.int32),
            slot_owner=jnp.asarray(plan.slot_owner, jnp.int32),
            split_slot=jnp.asarray(plan.split_slot, jnp.int32))

    def install_plan(self, plan: PlacementPlan, now: float = 0.0,
                     detail: str = ""):
        """Activate a placement generation (post-T_push: the orchestrator
        has already charged the weight-push time to the virtual clock)."""
        self.route_state = self.route_state._replace(
            **self._plan_arrays(plan))
        ev = WorkerEvent(now, "placement_changed", f"gen{plan.generation}",
                         detail or plan.reason)
        self.plan_log.append(ev)
        self.bus.publish(ev)
        if self.telemetry is not None:
            self.telemetry.registry.inc("placement.plans_installed")

    def drain_plan_events(self) -> List[WorkerEvent]:
        evs, self.plan_log = self.plan_log, []
        return evs

    @property
    def placement_generation(self) -> int:
        return self.placement_mgr.plan.generation \
            if self.placement_mgr is not None else 0

    def note_dispatch_load(self, slot_load):
        """Drain a device-side per-slot dispatch counter into the placement
        manager's EMA (the telemetry behind load-aware decisions)."""
        if self.placement_mgr is not None:
            self.placement_mgr.record_slot_load(np.asarray(slot_load))

    def choose_protect_ew(self, exclude=()) -> Optional[int]:
        if self.placement_mgr is None:
            return None
        return self.placement_mgr.choose_protect_ew(tuple(exclude))

    def add_ew(self, now: float = 0.0) -> int:
        """Scale-out: admit a spare EW into the pool (layer-aligned join —
        the plan installs between steps, after the orchestrator charged
        T_w + T_push)."""
        assert self.placement_mgr is not None, "elastic plane requires MoE"
        new_ew, plan = self.placement_mgr.plan_scale_out()
        self.route_state = self.ews[new_ew].provision(self.route_state)
        self.install_plan(plan, now=now)
        return new_ew

    def drain_ew(self, ew: int, now: float = 0.0):
        """Graceful scale-in: the EW's resident experts have been migrated
        (T_push already charged); it leaves the pool as a spare."""
        assert self.placement_mgr is not None
        plan = self.placement_mgr.plan_scale_in(ew)
        self.install_plan(plan, now=now)
        self.route_state = self.ews[ew].retire(self.route_state)

    def promote_shadows(self, dead_ew: int, now: float = 0.0):
        """Permanent shadow promotion: instead of waiting for revival, the
        dead EW's replicas become primaries and the pool shrinks. Instant
        and push-free — promotion is an ERT flip, the weights are already
        resident (§5.3 taken to its logical end)."""
        assert self.placement_mgr is not None
        plan = self.placement_mgr.promote_shadows(dead_ew)
        self.ews[dead_ew].member = False
        self.install_plan(plan, now=now)

    def rebalance(self, now: float = 0.0) -> Optional[PlacementPlan]:
        """Load-aware re-packing of experts over the currently *healthy*
        pool members (a failed EW awaiting revival must not be handed
        primaries it cannot serve)."""
        if self.placement_mgr is None:
            return None
        plan = self.placement_mgr.plan_rebalance(
            live=tuple(self.live_ews))
        self.install_plan(plan, now=now)
        return plan

    def release_request(self, rid: str):
        """Full teardown of one request's footprint across the stack: the
        chunk stream, any stale recovery entry, the owning AW's slot +
        prefill cursor + pending checkpoint WRs, and the store log. Safe
        for done, cancelled, preempted, and crash-paused requests alike
        (the slot is only released when this request still holds it).

        With the prefix-cache plane on, a *completed* request's slot is
        offered to the owning AW's cache instead of being cleared: the
        cache adopts the slot AND the store log (the entry's restoration
        backing), so neither is freed here on a successful offer."""
        r = self.requests.pop(rid, None)
        if r is None:
            return
        # deadline backstop: a request whose first token landed late and
        # which finished before the next check_deadlines tick still counts
        if r.deadline is not None and not r.deadline_flagged and \
                r.t_first_token > r.deadline:
            r.deadline_flagged = True
            self.gateway.stats.bump(r.slo_class, "deadline_missed")
            self._note_request_event("deadline_missed", rid,
                                     r.t_first_token,
                                     f"first token at {r.t_first_token:g} "
                                     f"> deadline {r.deadline:g}")
        # completion-deadline backstop: finished late, released before the
        # next check_deadlines tick
        if r.completion_deadline is not None and not r.completion_flagged \
                and r.t_done > r.completion_deadline:
            r.completion_flagged = True
            self.gateway.stats.bump(r.slo_class, "completion_deadline_missed")
            self._note_request_event(
                "deadline_missed", rid, r.t_done,
                f"completion at {r.t_done:g} > deadline "
                f"{r.completion_deadline:g}")
        if self.chunked is not None:
            self.chunked.drop(rid)
        if r.queued_for_recovery:
            # cancel the pending re-admission: a stale recovery entry must
            # not reach the scheduler after the request is gone
            self.gateway.drop(rid)
        cached = False
        if r._aw >= 0 and self.aws[r._aw].alive:
            aw = self.aws[r._aw]
            if not r.paused and self.prefix_plane is not None and \
                    r.done and not r.cancelled:
                # commit the resident tail, then offer the slot (with its
                # KV and store log) to the AW's prefix cache
                aw.checkpointer.flush()
                cached = self.prefix_plane.offer(r)
            # pending WRs and the prefill cursor die with the request, not
            # with the worker (they reference a log about to be released)
            aw.drop_request(rid)
            if not r.paused and (not cached or self.pages is not None):
                # paged: the slot ALWAYS releases, cached or not — a
                # successful offer pinned its own page references, so the
                # shared pages outlive the slot while exclusive pages
                # free. Contiguous: a cached slot is retained by the
                # entry (slot-level sharing) and must not be cleared.
                if self.prefix_plane is not None and not cached:
                    # e.g. a cancelled adopter: its slot's live cache
                    # entry must not survive the clear below
                    self.prefix_plane.forget_slot(r._aw, r.slot)
                self._kv_clear_slot(r.slot)
                aw.slots.release(r.slot)
        # always safe: a cached entry's backing log was renamed to its
        # reserved ~prefix key (release of the original rid is then a
        # no-op), and on checkpoint=False engines a cached slot may still
        # own a stale log a preemption created under this rid — leaving
        # it would corrupt a later submission reusing the rid
        self.store.release(rid)
        if self.telemetry is not None:
            self.telemetry.on_release(r)
        if self.flightrec is not None:
            self.flightrec.on_release(r)
        for hook in self._release_hooks:
            hook(r)

    # ------------------------------------------------------------------
    def generate(self, rid: str, prompt: np.ndarray, max_new: int
                 ) -> List[int]:
        """Convenience: run one request to completion."""
        assert self._submit_sync(rid, prompt, max_new)
        r = self.requests[rid]
        while not r.done:
            self.step()
        return r.tokens
