"""Typed client-facing request API: SLO classes, specs, handles, lifecycle.

This is the serving stack's public surface. The old
``InferenceEngine.submit(rid, prompt, max_new)`` bare positional call could
not express *anything* about a request beyond its prompt: no priority, no
deadline, no per-request sampling, no way to observe or cancel it in
flight. This module replaces it with a typed trio:

  * ``RequestSpec``   — everything the serving stack needs to know about a
    request: prompt (or a lazy token distribution to draw it from),
    ``max_new``, per-request ``SamplingParams``, an ``slo_class`` in
    {interactive, standard, batch}, an optional virtual-clock first-token
    ``deadline``, and an optional ``session`` key for affinity placement.
  * ``Client``        — submits specs into the Gateway's multi-class
    admission plane and hands back handles. Submission *queues*; it never
    refuses (the old sync-refuse behaviour lives only in the deprecated
    ``engine.submit`` shim).
  * ``RequestHandle`` — observe and steer one request: ``status()`` (the
    lifecycle state machine: queued → placed → prefilling → decoding →
    {done, preempted, cancelled}), incremental token streaming via
    ``new_tokens()``, and ``cancel()``.

The lifecycle states map 1:1 onto the scheduling substrate: ``preempted``
is Tarragon's recovery path exercised *on purpose* — a preempted request's
KV lives in the checkpoint store and it re-enters the Gateway as a
recovery entry that resumes from its committed cursor (planned eviction is
failure you chose). ``new_tokens()`` is therefore at-least-once across an
AW *crash*: tokens past the commit watermark are recomputed bit-identically
and re-delivered. Planned preemption flushes the watermark first, so it
never re-delivers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

# ---------------------------------------------------------------------------
# SLO classes
# ---------------------------------------------------------------------------

INTERACTIVE = "interactive"
STANDARD = "standard"
BATCH = "batch"

#: admission priority order (also the weighted-dequeue service order)
SLO_CLASSES = (INTERACTIVE, STANDARD, BATCH)

#: per-class weighted-dequeue credits per admission round
CLASS_WEIGHTS = {INTERACTIVE: 4, STANDARD: 2, BATCH: 1}

#: classes whose blocked head may evict a victim (preempt-and-requeue)
PREEMPTING_CLASSES = (INTERACTIVE,)

#: classes eligible to be checkpointed out of their slot
PREEMPTIBLE_CLASSES = (BATCH,)


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decode head configuration (overrides the engine-wide
    defaults in ``EngineConfig`` when attached to a spec). Carried as
    slot-indexed device arrays by the decode loop
    (serving/decode_loop.py) — changing them never re-traces."""
    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0                 # 0 = full distribution (greedy=False)
    seed: Optional[int] = None     # per-request stream seed for the
    #                                counter-based sampler; None = a stable
    #                                hash of the rid (recovery replays the
    #                                same stream in any slot)


@dataclass
class RequestSpec:
    """Typed request description. ``prompt`` may be given directly, or left
    ``None`` with (``prompt_len``, ``seed``, ``token_dist``) set, in which
    case the client draws it from the named token distribution — the same
    lazy-prompt convention as ``data.workloads.Request``."""
    rid: Optional[str] = None      # auto-assigned by the Client when None
    prompt: Optional[np.ndarray] = None
    max_new: int = 16
    sampling: Optional[SamplingParams] = None
    slo_class: str = STANDARD
    deadline: Optional[float] = None   # virtual-clock first-token deadline
    completion_deadline: Optional[float] = None  # virtual-clock deadline
    #                                    for the LAST token: overrun marks
    #                                    deadline_missed at completion time
    #                                    (the request is never dropped)
    session: Optional[str] = None      # affinity key (session_affinity)
    frames: Optional[np.ndarray] = None
    # lazy prompt generation (used when prompt is None)
    prompt_len: int = 8
    seed: int = 0
    token_dist: str = "uniform"        # "uniform" | "zipf"
    zipf_a: float = 1.3

    def __post_init__(self):
        if self.slo_class not in SLO_CLASSES:
            raise ValueError(
                f"unknown slo_class {self.slo_class!r}: "
                f"expected one of {SLO_CLASSES}")

    def resolve_prompt(self, vocab: int) -> np.ndarray:
        if self.prompt is not None:
            return np.asarray(self.prompt, np.int32)
        # delegate to the workload Request's generator so 'same seed =>
        # same prompt' holds between workload-driven and Client-driven runs
        from repro.data.workloads import Request
        return Request(self.rid or "", 0.0, self.prompt_len, self.max_new,
                       self.seed, token_dist=self.token_dist,
                       zipf_a=self.zipf_a).prompt_tokens(vocab)


# ---------------------------------------------------------------------------
# lifecycle states
# ---------------------------------------------------------------------------

QUEUED = "queued"
PLACED = "placed"
PREFILLING = "prefilling"
DECODING = "decoding"
PREEMPTED = "preempted"
DONE = "done"
CANCELLED = "cancelled"

LIFECYCLE_STATES = (QUEUED, PLACED, PREFILLING, DECODING, PREEMPTED, DONE,
                    CANCELLED)


@dataclass
class RequestStatus:
    """Point-in-time snapshot of one request's lifecycle."""
    rid: str
    state: str
    slo_class: str = STANDARD
    tokens_generated: int = 0
    prefill_cursor: int = 0
    preemptions: int = 0
    deadline: Optional[float] = None
    deadline_missed: bool = False
    completion_deadline: Optional[float] = None
    completion_deadline_missed: bool = False
    prefix_hit: int = 0                # prompt tokens adopted from the
    #                                    prefix cache at admission
    ttft: float = -1.0


class RequestHandle:
    """Observe and steer one submitted request.

    The handle resolves state lazily through the engine; once the engine
    releases a finished request, the final ``RequestState`` is pinned onto
    the handle by the client's release hook, so ``tokens()``/``status()``
    keep working after teardown."""

    def __init__(self, client: "Client", spec: RequestSpec):
        self._client = client
        self._engine = client.engine
        self.spec = spec
        self.rid: str = spec.rid
        self._state = None             # pinned RequestState (live or final)
        self._cancelled = False
        self._stream_cursor = 0

    # -- state resolution ---------------------------------------------------
    def _lookup(self):
        if self._state is not None and \
                (self._state.done or self._state.cancelled):
            # terminal state is pinned forever: if the rid is reused for a
            # new request, this handle must keep reporting ITS request
            return self._state
        r = self._engine.requests.get(self.rid)
        if r is not None:
            self._state = r
        return self._state

    def state(self) -> str:
        r = self._lookup()
        if r is None:
            if self._cancelled:
                return CANCELLED
            return QUEUED if self._engine.gateway.find(self.rid) is not None \
                else DONE
        return r.state        # the engine-side state machine is canonical

    def status(self) -> RequestStatus:
        r = self._lookup()
        st = RequestStatus(self.rid, self.state(),
                           slo_class=self.spec.slo_class,
                           deadline=self.spec.deadline,
                           completion_deadline=self.spec.completion_deadline)
        if r is not None:
            st.tokens_generated = len(r.tokens)
            st.prefill_cursor = r.prefill_cursor
            st.preemptions = r.preemptions
            st.deadline_missed = r.deadline_flagged
            st.completion_deadline_missed = r.completion_flagged
            st.prefix_hit = r.prefix_hit
            st.ttft = r.ttft
        return st

    # -- token access -------------------------------------------------------
    def tokens(self) -> List[int]:
        r = self._lookup()
        return list(r.tokens) if r is not None else []

    def new_tokens(self) -> List[int]:
        """Incremental streaming: tokens generated since the last call.
        After an AW *crash*, uncommitted tokens are recomputed
        bit-identically and re-delivered (at-least-once); planned
        preemption flushes the commit watermark first and never rewinds."""
        toks = self.tokens()
        self._stream_cursor = min(self._stream_cursor, len(toks))
        out = toks[self._stream_cursor:]
        self._stream_cursor = len(toks)
        return out

    def done(self) -> bool:
        return self.state() in (DONE, CANCELLED)

    # -- control ------------------------------------------------------------
    def cancel(self, now: float = 0.0) -> bool:
        ok = self._engine.cancel_request(self.rid, now=now)
        if ok:
            self._cancelled = True
        return ok

    def __repr__(self):
        return f"RequestHandle({self.rid!r}, state={self.state()!r})"


class Client:
    """Front door of the typed request API: submit specs, keep handles.

    Submission enqueues into the Gateway's multi-class admission plane and
    opportunistically runs one admission pass; if the pool is saturated the
    request *waits* (deadline-aware, weighted by class) instead of being
    refused. Drive progress with ``engine.step()`` / ``run_serving`` as
    before."""

    def __init__(self, engine):
        self.engine = engine
        self._handles: Dict[str, RequestHandle] = {}
        self._auto_rid = 0
        engine.add_release_hook(self._on_release)

    def _on_release(self, rstate):
        h = self._handles.get(rstate.rid)
        if h is not None:
            h._state = rstate          # pin the final state onto the handle

    def _next_rid(self) -> str:
        self._auto_rid += 1
        return f"req-{self._auto_rid}"

    def submit(self, spec: RequestSpec, now: float = 0.0) -> RequestHandle:
        if spec.rid is None:
            spec = dataclasses.replace(spec, rid=self._next_rid())
        live = self.engine.requests.get(spec.rid)
        if (live is not None and not live.done) or \
                self.engine.gateway.find(spec.rid) is not None:
            raise ValueError(f"request id {spec.rid!r} already in flight")
        if live is not None:
            # rid reuse after completion: free the finished request's slot
            # and store log before the new life begins (the old handle keeps
            # its pinned final state)
            self.engine.release_request(spec.rid)
        prompt = spec.resolve_prompt(self.engine.cfg.vocab_size)
        self.engine.gateway.enqueue(
            spec.rid, prompt, spec.max_new, now=now, frames=spec.frames,
            slo_class=spec.slo_class, deadline=spec.deadline,
            completion_deadline=spec.completion_deadline,
            sampling=spec.sampling, session=spec.session)
        handle = RequestHandle(self, spec)
        self._handles[spec.rid] = handle
        # opportunistic admission pass: the spec may be placed immediately;
        # otherwise it waits in its class queue and retries every tick
        self.engine.scheduler.admit(now)
        return handle

    def handle(self, rid: str) -> Optional[RequestHandle]:
        return self._handles.get(rid)

    def forget(self, rid: str) -> bool:
        """Drop a terminal request's handle (and its pinned final state).
        The client retains every handle until told otherwise so results
        stay readable after engine-side release; a long-running service
        should ``forget`` handles it has consumed, or memory grows with
        the total request count. Live requests are refused — cancel
        first."""
        h = self._handles.get(rid)
        if h is None:
            return False
        if not h.done():
            raise ValueError(f"request {rid!r} is still live; cancel() "
                             "before forget()")
        del self._handles[rid]
        return True

    def cancel(self, rid: str, now: float = 0.0) -> bool:
        h = self._handles.get(rid)
        if h is not None:
            return h.cancel(now=now)
        return self.engine.cancel_request(rid, now=now)
