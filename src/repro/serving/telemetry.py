"""Serving telemetry plane: streaming metrics, span tracing, stall
attribution, and exporters.

The resilience claims this repo reproduces are *measured* claims —
failure-induced stalls of ~64 s collapsing to 0.3–0.4 s — yet until this
plane the only way to audit them was to replay full per-request timestamp
lists through ``np.percentile`` after the run, and failure causality lived
in ad-hoc ``WorkerEvent`` drains only the orchestrator consumed. This
module makes observation first-class, in four pieces:

  * **StreamingHistogram / MetricsRegistry** — fixed log-bucket histograms
    (O(1) memory, mergeable) plus counters and gauges. p50/p95/p99 come
    from cumulative bucket counts with in-bucket interpolation, so a
    trace-scale soak never has to retain per-request latency lists; the
    streamed quantile is exact to within one bucket
    (``buckets_per_decade`` controls the bucket ratio).
  * **EventBus** — publish-at-emission event stream with per-consumer
    cursors. Every ``WorkerEvent`` (worker, placement, and request planes)
    is stamped with the virtual-clock time at the moment it happens and
    published once; any number of consumers (orchestrator audit log,
    ``core/events.py`` timelines, the exporters here) read the same
    stream through their own cursor without stealing from each other —
    the destructive ``drain_*`` lists survive only as legacy views.
  * **SpanTracer / TelemetryPlane** — per-request root spans over the
    lifecycle state machine (queued → placed → prefill chunks → decode →
    done) with queued/prefill/decode phase sub-spans (each queued spell
    tagged with its cause: fresh, preempt, failover), restore/preempt/
    prefix-adopt/cancel instants, failure-detection spans on the worker
    track, and per-step engine-track spans — all on the virtual clock.
  * **Stall attribution** — every TTFT/TBT gap above
    ``EngineConfig.stall_threshold`` is decomposed into
    {detection, restore, preemption, queue_wait, prefill, rebalance}
    components plus an ``execution`` residual, by clipping the per-cause
    intervals to the gap window in priority order; components always sum
    to the observed gap by construction.

Exporters: ``snapshot()`` (JSON, schema ``repro.telemetry.v1``),
``prometheus_text()`` (text exposition format), ``export_chrome()``
(Perfetto/Chrome ``trace_event`` JSON).

Invariants: the plane is host-side bookkeeping only — it never touches
device arrays and never calls into jax, so telemetry on/off is
bit-identical and adds zero new jit traces (asserted in
tests/test_telemetry.py, overhead measured in bench_steady_state).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.orchestrator import WorkerEvent

SCHEMA = "repro.telemetry.v1"

# ---------------------------------------------------------------------------
# percentile helpers (the one empty-array-guarded np.percentile block that
# used to be copy-pasted across every bench and driver)
# ---------------------------------------------------------------------------


def pct(values, q: float) -> float:
    """``np.percentile`` with the empty-array guard every caller needs."""
    a = np.asarray(values, dtype=float)
    return float(np.percentile(a, q)) if a.size else 0.0


def summarize_latency(values) -> dict:
    """p50/p95/p99/mean/max summary of a latency list (seconds), with the
    empty guard. The exact-list twin of ``StreamingHistogram.snapshot`` —
    benches use both and cross-check them."""
    a = np.asarray(values, dtype=float)
    if a.size == 0:
        return {"n": 0, "p50": 0.0, "p95": 0.0, "p99": 0.0,
                "mean": 0.0, "max": 0.0}
    return {"n": int(a.size),
            "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()),
            "max": float(a.max())}


# ---------------------------------------------------------------------------
# streaming histogram
# ---------------------------------------------------------------------------


class StreamingHistogram:
    """Fixed log-bucket histogram: O(1) memory, mergeable, quantiles from
    cumulative counts.

    Buckets are geometric between ``lo`` and ``hi`` with
    ``buckets_per_decade`` per factor of 10, plus an underflow bucket
    [0, lo] and an overflow bucket (hi, inf). A streamed quantile lands in
    the same bucket as the exact value, so its error is bounded by one
    bucket ratio (10^(1/buckets_per_decade), ~7.5% at the default 32)."""

    __slots__ = ("lo", "hi", "bpd", "n", "counts", "count", "total",
                 "vmin", "vmax", "_log_lo")

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 buckets_per_decade: int = 32):
        assert lo > 0 and hi > lo and buckets_per_decade >= 1
        self.lo, self.hi, self.bpd = float(lo), float(hi), buckets_per_decade
        self._log_lo = math.log10(lo)
        decades = math.log10(hi) - self._log_lo
        self.n = int(round(decades * buckets_per_decade)) + 2
        self.counts = np.zeros((self.n,), np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    # -- bucket geometry ----------------------------------------------------
    def bucket_index(self, v: float) -> int:
        v = max(float(v), 0.0)
        if v <= self.lo:
            return 0
        if v > self.hi:
            return self.n - 1
        i = int(math.floor((math.log10(v) - self._log_lo) * self.bpd)) + 1
        return min(max(i, 1), self.n - 2)

    def bucket_bounds(self, i: int) -> Tuple[float, float]:
        """(low, high] value bounds of bucket ``i``."""
        if i <= 0:
            return (0.0, self.lo)
        if i >= self.n - 1:
            return (self.hi, math.inf)
        return (self.lo * 10.0 ** ((i - 1) / self.bpd),
                self.lo * 10.0 ** (i / self.bpd))

    # -- ingest -------------------------------------------------------------
    def observe(self, v: float):
        v = max(float(v), 0.0)
        self.counts[self.bucket_index(v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def observe_n(self, v: float, n: int):
        """Observe the same value ``n`` times in O(1) (a decode segment's
        n-1 zero gaps land in one bucket update)."""
        if n <= 0:
            return
        v = max(float(v), 0.0)
        self.counts[self.bucket_index(v)] += n
        self.count += n
        self.total += v * n
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def merge(self, other: "StreamingHistogram"):
        assert (self.lo, self.hi, self.bpd) == \
            (other.lo, other.hi, other.bpd), "incompatible bucket configs"
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    # -- summary ------------------------------------------------------------
    def quantile(self, q: float) -> float:
        """Streamed quantile (q in [0, 1]): find the bucket holding the
        target rank, interpolate linearly inside it, clamp to the observed
        [min, max]."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i in range(self.n):
            c = int(self.counts[i])
            if c == 0:
                continue
            if cum + c >= target:
                blo, bhi = self.bucket_bounds(i)
                if not math.isfinite(bhi):          # overflow bucket
                    return self.vmax
                frac = (target - cum) / c
                v = blo + frac * (bhi - blo)
                return min(max(v, self.vmin), self.vmax)
            cum += c
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "mean": self.mean,
                "p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99),
                "lo": self.lo, "hi": self.hi,
                "buckets_per_decade": self.bpd,
                "buckets": {str(i): int(c)
                            for i, c in enumerate(self.counts) if c}}


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return "tarragon_" + out


class MetricsRegistry:
    """Counters, gauges, and streaming histograms under dotted names.
    ``snapshot()`` is the JSON export; ``prometheus_text()`` the text
    exposition format. Registries merge (multi-shard aggregation)."""

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 buckets_per_decade: int = 32):
        self._hist_cfg = (lo, hi, buckets_per_decade)
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, StreamingHistogram] = {}

    def inc(self, name: str, n: int = 1):
        self.counters[name] = self.counters.get(name, 0) + n

    def set_counter(self, name: str, v: int):
        """Pin a counter to an externally-accumulated value (mirrors of
        legacy stat structs like GatewayStats sync through this)."""
        self.counters[name] = int(v)

    def gauge(self, name: str, v: float):
        self.gauges[name] = float(v)

    def hist(self, name: str) -> StreamingHistogram:
        h = self.hists.get(name)
        if h is None:
            lo, hi, bpd = self._hist_cfg
            h = self.hists[name] = StreamingHistogram(lo, hi, bpd)
        return h

    def observe(self, name: str, v: float):
        self.hist(name).observe(v)

    def merge(self, other: "MetricsRegistry"):
        for k, v in other.counters.items():
            self.inc(k, v)
        for k, v in other.gauges.items():
            self.gauges[k] = v
        for k, h in other.hists.items():
            self.hist(k).merge(h)

    def snapshot(self) -> dict:
        return {"schema": SCHEMA,
                "counters": dict(sorted(self.counters.items())),
                "gauges": dict(sorted(self.gauges.items())),
                "histograms": {k: h.snapshot()
                               for k, h in sorted(self.hists.items())}}

    def prometheus_text(self) -> str:
        lines: List[str] = []
        for k in sorted(self.counters):
            n = _prom_name(k) + "_total"
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {self.counters[k]}")
        for k in sorted(self.gauges):
            n = _prom_name(k)
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {self.gauges[k]:g}")
        for k in sorted(self.hists):
            h = self.hists[k]
            n = _prom_name(k)
            lines.append(f"# TYPE {n} histogram")
            cum = 0
            for i in range(h.n):
                c = int(h.counts[i])
                if c == 0:
                    continue
                cum += c
                le = h.bucket_bounds(i)[1]
                le_s = "+Inf" if not math.isfinite(le) else f"{le:.9g}"
                lines.append(f'{n}_bucket{{le="{le_s}"}} {cum}')
            if cum != h.count or not h.counts[-1]:
                lines.append(f'{n}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{n}_sum {h.total:.9g}")
            lines.append(f"{n}_count {h.count}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# event bus: publish-at-emission, per-consumer cursors
# ---------------------------------------------------------------------------


class EventBus:
    """Multi-consumer event stream over ``WorkerEvent``s.

    Producers publish exactly once, at emission time, with the event
    already stamped with the virtual clock. Consumers call
    ``drain(consumer)`` with a name of their choosing and receive only the
    events past their own cursor — no consumer can steal another's view,
    which is what the old destructive ``drain_request_events`` /
    ``drain_plan_events`` lists could not guarantee. ``events`` is the
    full read-only history (bounded by ``max_events``; beyond that new
    events are counted in ``dropped`` instead of stored)."""

    def __init__(self, max_events: int = 1_000_000):
        self.max_events = max_events
        self._events: List[WorkerEvent] = []
        self._cursors: Dict[str, int] = {}
        self.dropped = 0

    def publish(self, ev: WorkerEvent):
        if len(self._events) >= self.max_events:
            self.dropped += 1
            return
        self._events.append(ev)

    def drain(self, consumer: str) -> List[WorkerEvent]:
        i = self._cursors.get(consumer, 0)
        evs = self._events[i:]
        self._cursors[consumer] = len(self._events)
        return list(evs)

    def cursor(self, consumer: str) -> int:
        return self._cursors.get(consumer, 0)

    @property
    def events(self) -> Tuple[WorkerEvent, ...]:
        return tuple(self._events)

    def __len__(self) -> int:
        return len(self._events)


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------


@dataclass
class Span:
    track: str                 # "req:<rid>" | "engine" | "workers"
    name: str
    t0: float
    t1: Optional[float] = None
    cat: str = "phase"
    args: Dict[str, object] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


class SpanTracer:
    """Virtual-clock span recorder with a Perfetto/Chrome ``trace_event``
    exporter. Memory is bounded: past ``max_spans`` closed spans, new ones
    are dropped and counted (``dropped``) rather than growing without
    limit — a soak run keeps its histograms exact and its trace a prefix."""

    def __init__(self, max_spans: int = 200_000):
        self.max_spans = max_spans
        self.spans: List[Span] = []
        self.instants: List[Span] = []
        self.dropped = 0

    def _room(self) -> bool:
        if len(self.spans) + len(self.instants) >= self.max_spans:
            self.dropped += 1
            return False
        return True

    def begin(self, track: str, name: str, t: float, cat: str = "phase",
              **args) -> Span:
        sp = Span(track, name, t, None, cat, dict(args))
        if self._room():
            self.spans.append(sp)
        return sp

    @staticmethod
    def end(span: Span, t: float, **args):
        span.t1 = t
        span.args.update(args)

    def complete(self, track: str, name: str, t0: float, t1: float,
                 cat: str = "phase", **args) -> Span:
        sp = Span(track, name, t0, t1, cat, dict(args))
        if self._room():
            self.spans.append(sp)
        return sp

    def instant(self, track: str, name: str, t: float, **args) -> Span:
        sp = Span(track, name, t, t, "instant", dict(args))
        if self._room():
            self.instants.append(sp)
        return sp

    # -- Perfetto / Chrome trace_event JSON ---------------------------------
    def chrome_trace(self, clock_end: Optional[float] = None) -> dict:
        """``{"traceEvents": [...]}``: one pid, one tid per track, complete
        ("X") events for spans, instants ("i"), thread-name metadata. Times
        are virtual seconds scaled to microseconds."""
        tids: Dict[str, int] = {}

        def tid_of(track: str) -> int:
            if track not in tids:
                tids[track] = len(tids)
            return tids[track]

        # stable track order: engine/workers first, then request tracks
        for sp in self.spans + self.instants:
            if not sp.track.startswith("req:"):
                tid_of(sp.track)
        for sp in self.spans + self.instants:
            tid_of(sp.track)

        events: List[dict] = [
            {"ph": "M", "pid": 1, "name": "process_name",
             "args": {"name": "tarragon-serving"}}]
        for track, tid in tids.items():
            events.append({"ph": "M", "pid": 1, "tid": tid,
                           "name": "thread_name", "args": {"name": track}})
        for sp in self.spans:
            t1 = sp.t1 if sp.t1 is not None else \
                (clock_end if clock_end is not None else sp.t0)
            events.append({
                "ph": "X", "pid": 1, "tid": tid_of(sp.track),
                "name": sp.name, "cat": sp.cat,
                "ts": sp.t0 * 1e6, "dur": max(t1 - sp.t0, 0.0) * 1e6,
                "args": sp.args})
        for sp in self.instants:
            events.append({
                "ph": "i", "pid": 1, "tid": tid_of(sp.track),
                "name": sp.name, "cat": sp.cat, "ts": sp.t0 * 1e6,
                "s": "t", "args": sp.args})
        return {"traceEvents": events, "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# stall attribution
# ---------------------------------------------------------------------------

#: attribution priority: an instant of wall time inside the gap window is
#: charged to the FIRST cause below whose interval covers it; whatever no
#: cause claims is ``execution`` (ordinary compute).
STALL_CAUSES = ("detection", "restore", "preemption", "queue_wait",
                "prefill", "rebalance")


@dataclass
class StallRecord:
    rid: str
    kind: str                  # "ttft" | "tbt"
    t0: float
    t1: float
    gap: float
    components: Dict[str, float] = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"rid": self.rid, "kind": self.kind, "t0": self.t0,
                "t1": self.t1, "gap": self.gap,
                "components": dict(self.components)}


def _subtract(piece: Tuple[float, float],
              claimed: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Remove every claimed interval from ``piece``; return the remaining
    disjoint fragments."""
    frags = [piece]
    for (c0, c1) in claimed:
        nxt = []
        for (a, b) in frags:
            if c1 <= a or c0 >= b:
                nxt.append((a, b))
                continue
            if a < c0:
                nxt.append((a, c0))
            if c1 < b:
                nxt.append((c1, b))
        frags = nxt
        if not frags:
            break
    return frags


def attribute_gap(t0: float, t1: float,
                  cause_intervals: Dict[str, List[Tuple[float, float]]]
                  ) -> Dict[str, float]:
    """Decompose the gap [t0, t1] over ``STALL_CAUSES`` (in priority
    order) plus an ``execution`` residual. Every component is the length
    of the cause's intervals clipped to the window and not already claimed
    by a higher-priority cause — so the components sum to the gap exactly,
    by construction."""
    comps = {c: 0.0 for c in STALL_CAUSES}
    claimed: List[Tuple[float, float]] = []
    for cause in STALL_CAUSES:
        for (a, b) in cause_intervals.get(cause, ()):
            a, b = max(a, t0), min(b, t1)
            if b <= a:
                continue
            for (fa, fb) in _subtract((a, b), claimed):
                comps[cause] += fb - fa
                claimed.append((fa, fb))
    comps["execution"] = (t1 - t0) - sum(comps.values())
    return comps


# ---------------------------------------------------------------------------
# the plane
# ---------------------------------------------------------------------------

#: phase name + queued-cause -> attribution cause
_PHASE_CAUSE = {("queued", "fresh"): "queue_wait",
                ("queued", "preempt"): "preemption",
                ("queued", "failover"): "restore",
                ("prefill", None): "prefill"}


class TelemetryPlane:
    """Per-engine observability plane: registry + tracer + stall
    attribution, fed by host-side hooks at every lifecycle transition.
    Created by the engine when ``EngineConfig.telemetry`` is True; every
    hook site guards on ``engine.telemetry is not None``, and nothing here
    ever touches device state — switching the plane off cannot change a
    single token or mint a jit trace."""

    def __init__(self, engine):
        self.engine = engine
        ecfg = engine.ecfg
        bpd = int(getattr(ecfg, "hist_buckets_per_decade", 32))
        self.registry = MetricsRegistry(buckets_per_decade=bpd)
        self.tracer = SpanTracer()
        self.stall_threshold = float(getattr(ecfg, "stall_threshold", 0.25))
        self.now = 0.0
        # per-request state
        self._root: Dict[str, Span] = {}
        self._phase: Dict[str, Span] = {}
        self._causes: Dict[str, List[Tuple[str, float, float]]] = {}
        self._last_token: Dict[str, float] = {}
        self._ttft_seen: set = set()
        self.closed_roots: Dict[str, int] = {}
        # global cause windows
        self._detect_windows: List[Tuple[float, float]] = []
        self._prefill_windows: List[Tuple[float, float]] = []
        self._stalls: List[StallRecord] = []
        self._attributed = False

    # -- internals ----------------------------------------------------------
    def _touch(self, t: float) -> float:
        if t > self.now:
            self.now = t
        return t

    def _open_phase(self, rid: str, name: str, t: float,
                    cause: Optional[str] = None, **args):
        self._close_phase(rid, t)
        label = f"{name}({cause})" if cause else name
        sp = self.tracer.begin(f"req:{rid}", label, t, cat="phase", **args)
        sp.args["_cause"] = cause
        self._phase[rid] = sp

    def _close_phase(self, rid: str, t: float, **args):
        sp = self._phase.pop(rid, None)
        if sp is None:
            return
        self.tracer.end(sp, t, **args)
        base = sp.name.split("(", 1)[0]
        cause = _PHASE_CAUSE.get((base, sp.args.get("_cause"))) or \
            _PHASE_CAUSE.get((base, None))
        if cause is not None and t > sp.t0:
            self._causes.setdefault(rid, []).append((cause, sp.t0, t))

    # -- request lifecycle hooks --------------------------------------------
    def on_enqueue(self, rid: str, t: float, slo_class: str):
        self._touch(t)
        if rid in self._root:
            # rid reuse after release: fall through and re-open below
            pass
        self._root[rid] = self.tracer.begin(
            f"req:{rid}", rid, t, cat="request", slo_class=slo_class)
        self._open_phase(rid, "queued", t, cause="fresh")

    def on_requeued(self, rid: str, t: float, cause: str):
        """Preempted/failover request re-entered its class queue."""
        self._touch(t)
        self._close_phase(rid, t)
        self._open_phase(rid, "queued", t, cause=cause)

    def on_admit(self, rid: str, t: float, aw: int, slot: int,
                 slo_class: str, recovery: bool, prefix_hit: int,
                 wait: float):
        self._touch(t)
        self._close_phase(rid, t, aw=aw, slot=slot)
        self.registry.observe("queue_delay", wait)
        self.registry.observe(f"queue_delay.{slo_class}", wait)
        if prefix_hit > 0:
            self.tracer.instant(f"req:{rid}", "prefix_adopt", t,
                                tokens=prefix_hit)
            self.registry.observe("prefix.hit_len", prefix_hit)

    def on_prefill_start(self, rid: str, t: float, cursor: int, n: int):
        self._touch(t)
        self._open_phase(rid, "prefill", t, cursor=cursor, prompt_len=n)

    def on_prefill_chunk(self, rid: str, t: float, take: int, shape: int):
        self._touch(t)
        self.registry.inc("prefill.chunk_tokens", take)
        self.registry.observe("prefill.chunk_take", take)

    def on_prefill_done(self, rid: str, t: float):
        self._touch(t)
        self._close_phase(rid, t)
        self._open_phase(rid, "decode", t)

    def on_whole_prefill(self, rid: str, t: float, n: int, scheme: str):
        """Whole-prompt (padded/exact) prefill: admission and prefill land
        in the same tick — a zero-length prefill span keeps the phase
        sequence uniform, then decode opens."""
        self._touch(t)
        self.tracer.complete(f"req:{rid}", "prefill", t, t, scheme=scheme,
                             prompt_len=n)
        self._open_phase(rid, "decode", t)

    def on_restore(self, rid: str, t: float, segments: int,
                   resumed_prefill: bool):
        self._touch(t)
        self.tracer.instant(f"req:{rid}", "restore", t, segments=segments,
                            resumed_prefill=resumed_prefill)
        self.registry.inc("recovery.restores")
        self.registry.observe("recovery.restored_segments", segments)
        if resumed_prefill:
            self._open_phase(rid, "prefill", t, cause=None, resumed=True)
        else:
            self._open_phase(rid, "decode", t, resumed=True)

    def on_preempt(self, rid: str, t: float):
        self._touch(t)
        self._close_phase(rid, t, outcome="preempted")
        self._open_phase(rid, "queued", t, cause="preempt")

    def on_failover(self, rid: str, t: float):
        """AW crash victim requeued for §6.2 restoration: the requeue
        sub-span (queued(failover)) starts here and its wait is attributed
        to ``restore`` except where the detection window overlaps."""
        self.on_requeued(rid, t, cause="failover")

    def on_cancel(self, rid: str, t: float, where: str):
        self._touch(t)
        self._close_phase(rid, t, outcome="cancelled")

    def on_drop(self, rid: str, t: Optional[float], outcome: str):
        """Request left the system straight from the queue (queued-cancel
        or synchronous-admission refusal): close its root span here, since
        no RequestState exists for ``on_release`` to see."""
        t = self._touch(t if t is not None else self.now)
        self._close_phase(rid, t, outcome=outcome)
        root = self._root.pop(rid, None)
        if root is not None:
            self.tracer.end(root, t, outcome=outcome)
            self.closed_roots[rid] = self.closed_roots.get(rid, 0) + 1
            self.registry.inc(f"requests.outcome.{outcome}")

    def on_release(self, r):
        """Close the request's root span exactly once (done, cancelled,
        preempted-and-released, and failover paths all funnel through
        ``engine.release_request``)."""
        t = self._touch(r.t_done if r.t_done >= 0 else self.now)
        rid = r.rid
        self._close_phase(rid, t, outcome=r.state)
        root = self._root.pop(rid, None)
        if root is not None:
            self.tracer.end(root, t, outcome=r.state,
                            tokens=len(r.tokens),
                            preemptions=r.preemptions,
                            prefix_hit=r.prefix_hit)
            self.closed_roots[rid] = self.closed_roots.get(rid, 0) + 1
        self.registry.inc("requests.released")
        self.registry.inc(f"requests.outcome.{r.state}")

    # -- failure / control-plane hooks --------------------------------------
    def on_failure_detected(self, kind: str, worker_id: int,
                            t_fail: float, t_detect: float):
        self._touch(t_detect)
        self.tracer.complete("workers", f"detect_{kind}{worker_id}",
                             t_fail, t_detect, cat="failure")
        self._detect_windows.append((t_fail, t_detect))
        self.registry.inc(f"failures.{kind}")
        self.registry.observe("failures.detection_latency",
                              t_detect - t_fail)

    def on_request_event(self, ev: WorkerEvent):
        """Generic request-plane event (``engine._note_request_event``):
        every kind becomes an instant on the rid track + a counter, so the
        trace carries preempted/cancelled/deadline_missed/prefix_restored
        markers without each site needing a dedicated hook."""
        self._touch(ev.t)
        self.registry.inc(f"events.{ev.kind}")
        self.tracer.instant(f"req:{ev.worker}", ev.kind, ev.t,
                            detail=ev.detail)

    # -- serving-loop hooks --------------------------------------------------
    def on_step(self, t0: float, t1: float, prefill_tokens: int,
                prefill_time: float, tokens_out: int):
        """One serving-loop tick [t0, t1]: an engine-track span, plus a
        global prefill window covering the slice of the tick charged to
        chunked prefill (the 'prefill budget' stall cause for co-resident
        decodes)."""
        self._touch(t1)
        self.registry.inc("engine.steps")
        self.tracer.complete("engine", "step", t0, t1, cat="step",
                             prefill_tokens=prefill_tokens,
                             tokens=tokens_out)
        if prefill_time > 0:
            w0 = max(t0, t1 - prefill_time)
            self._prefill_windows.append((w0, t1))

    def observe_tokens(self, rid: str, t: float, n: int,
                       slo_class: str = "standard"):
        """``n`` tokens for ``rid`` stamped at virtual time ``t`` (a
        decode segment lands several per step). Streams the same gap
        sequence ``ServeMetrics.tbt_values`` computes exactly: the gap
        from the previous stamp, then n-1 zeros."""
        self._touch(t)
        if n <= 0:
            return
        self.registry.inc("tokens.emitted", n)
        h = self.registry.hist("tbt")
        hc = self.registry.hist(f"tbt.{slo_class}")
        last = self._last_token.get(rid)
        zeros = n - 1
        if last is not None:
            gap = t - last
            h.observe(gap)
            hc.observe(gap)
            if gap > self.stall_threshold:
                self._stalls.append(StallRecord(rid, "tbt", last, t, gap))
        else:
            zeros = n - 1
        h.observe_n(0.0, zeros)
        hc.observe_n(0.0, zeros)
        self._last_token[rid] = t

    def observe_ttft(self, rid: str, v: float, slo_class: str,
                     t_enqueue: float):
        if rid in self._ttft_seen or v < 0:
            return
        self._ttft_seen.add(rid)
        self.registry.observe("ttft", v)
        self.registry.observe(f"ttft.{slo_class}", v)
        if v > self.stall_threshold:
            self._stalls.append(
                StallRecord(rid, "ttft", t_enqueue, t_enqueue + v, v))

    # -- stall attribution ---------------------------------------------------
    def _rebalance_windows(self) -> List[Tuple[float, float]]:
        """Pair rebalance_started -> rebalanced events off the bus (a
        second, non-stealing consumer of the same stream the orchestrator
        audit log reads)."""
        wins, open_t = [], None
        bus = getattr(self.engine, "bus", None)
        if bus is None:
            return wins
        for ev in bus.events:
            if ev.kind == "rebalance_started":
                open_t = ev.t
            elif ev.kind == "rebalanced" and open_t is not None:
                wins.append((open_t, ev.t))
                open_t = None
        return wins

    def stall_report(self) -> List[dict]:
        """Attribute every recorded stall: per-request cause intervals
        (queued spells by cause, prefill phases) + global windows
        (failure detection, chunked-prefill charges, rebalances), clipped
        to the gap window in priority order; the residual is
        ``execution``. Components sum to the gap by construction."""
        if not self._attributed:
            rebal = self._rebalance_windows()
            for s in self._stalls:
                per_cause: Dict[str, List[Tuple[float, float]]] = {}
                for cause, a, b in self._causes.get(s.rid, ()):
                    per_cause.setdefault(cause, []).append((a, b))
                per_cause["detection"] = list(self._detect_windows)
                per_cause.setdefault("prefill", []).extend(
                    self._prefill_windows)
                per_cause["rebalance"] = rebal
                s.components = attribute_gap(s.t0, s.t1, per_cause)
                for c, v in s.components.items():
                    if v > 0:
                        self.registry.hist(f"stall.{c}").observe(v)
                self.tracer.complete(
                    f"req:{s.rid}", f"stall({s.kind})", s.t0, s.t1,
                    cat="stall", **{k: round(v, 6)
                                    for k, v in s.components.items()})
            self._attributed = True
        return [s.to_dict() for s in self._stalls]

    # -- lifecycle -----------------------------------------------------------
    def finalize(self, t: Optional[float] = None):
        """End of a serving run: close still-open phases/roots (a request
        live at the duration cutoff still closes exactly one root span,
        with outcome ``unfinished``) and compute stall attribution."""
        t = self._touch(t if t is not None else self.now)
        for rid in list(self._phase):
            self._close_phase(rid, t, outcome="unfinished")
        for rid, root in list(self._root.items()):
            self.tracer.end(root, t, outcome="unfinished")
            self.closed_roots[rid] = self.closed_roots.get(rid, 0) + 1
            del self._root[rid]
        self.stall_report()
        path = getattr(self.engine.ecfg, "trace_export_path", "")
        if path:
            self.export_chrome(path)

    # -- export --------------------------------------------------------------
    def sync(self):
        """Mirror the legacy stat structs (GatewayStats, prefill planes,
        placement EMAs, jit-cache sizes) into the registry so one snapshot
        carries the whole stack's counters."""
        eng = self.engine
        gs = eng.gateway.stats
        for k in ("enqueued", "admitted", "requeued", "blocked_ticks",
                  "preemptions", "host_syncs", "prefix_hits",
                  "prefix_misses", "prefix_hit_tokens", "prefix_evictions",
                  "prefix_restored", "prefix_global_hits",
                  "prefix_migrated", "session_repins"):
            self.registry.set_counter(f"gateway.{k}", getattr(gs, k))
        for cls, counts in gs.by_class.items():
            for k, v in counts.items():
                self.registry.set_counter(f"gateway.{cls}.{k}", v)
        self.registry.gauge("gateway.queue_depth", eng.gateway.depth())
        self.registry.gauge("requests.active", len(eng.active_requests()))
        self.registry.gauge("requests.prefilling",
                            len(eng.prefilling_requests()))
        for w in eng.aws:
            used, total = w.slot_occupancy()
            self.registry.gauge(f"aw{w.aw_id}.slots_used", used)
            self.registry.gauge(f"aw{w.aw_id}.slots_total", total)
            self.registry.gauge(f"aw{w.aw_id}.alive", int(w.alive))
            ps = w.kv_page_stats()
            if ps is not None:
                self.registry.gauge(f"aw{w.aw_id}.pages_used", ps[0])
                self.registry.gauge(f"aw{w.aw_id}.pages_total", ps[1])
        pool = getattr(eng, "pages", None)
        if pool is not None:
            # paged KV-memory plane: physical occupancy + cross-request
            # page sharing, cluster-wide
            for k, v in pool.stats().items():
                self.registry.gauge(f"kv.{k}", v)
        self.registry.gauge("ew.live", len(eng.live_ews))
        if eng.placement_mgr is not None:
            self.registry.gauge("placement.generation",
                                eng.placement_generation)
            self.registry.gauge("placement.imbalance",
                                float(eng.placement_mgr.imbalance()))
            for ew, load in eng.placement_mgr.per_ew_load().items():
                self.registry.gauge(f"placement.ew{ew}.load_ema",
                                    float(load))
        sched = eng.scheduler.stats
        self.registry.set_counter("prefill.calls", sched.calls)
        self.registry.set_counter("prefill.real_tokens", sched.real_tokens)
        if eng.chunked is not None:
            cs = eng.chunked.stats
            self.registry.set_counter("prefill.chunked.calls", cs.calls)
            self.registry.set_counter("prefill.chunked.chunks", cs.chunks)
            self.registry.set_counter("prefill.chunked.real_tokens",
                                      cs.real_tokens)
            self.registry.set_counter("prefill.chunked.resumed", cs.resumed)
        ctl = getattr(eng, "controller", None)
        if ctl is not None:
            # control plane (serving/controller.py): decision counters +
            # live signals, alongside the per-decision events.controller_*
            # counters and req:controller trace instants emitted at
            # decision time
            for k, v in ctl.stats().items():
                if isinstance(v, float):
                    self.registry.gauge(f"controller.{k}", v)
                else:
                    self.registry.set_counter(f"controller.{k}", int(v))
        # the zero-new-traces invariant, as a gauge anyone can scrape
        traces = eng._decode._cache_size() + \
            eng.decode_plane.segment_traces()
        self.registry.gauge("jit.decode_traces", traces)
        bus = getattr(eng, "bus", None)
        if bus is not None:
            self.registry.gauge("bus.events", len(bus))
            self.registry.gauge("bus.dropped", bus.dropped)
            # cap-drop visibility as a first-class counter: silent event
            # loss during storms must show up in Prometheus scrapes
            # (events_dropped_total), not just the gauge twin above
            self.registry.set_counter("events.dropped", bus.dropped)
        fr = getattr(eng, "flightrec", None)
        if fr is not None:
            # forensics plane: recorder occupancy + watchdog trip counts
            self.registry.gauge("flightrec.records", len(fr.records))
            self.registry.set_counter("flightrec.records_total",
                                      fr.records_total)
            self.registry.set_counter("flightrec.records_dropped",
                                      fr.records_dropped)
            self.registry.gauge("flightrec.fingerprints", fr.fingerprints)
            wd = fr.watchdogs
            if wd is not None:
                self.registry.gauge("health.intervals", wd.intervals)
                self.registry.set_counter("health.trips", len(wd.trips))
                for k, v in wd.trip_counts.items():
                    self.registry.set_counter(f"health.trips.{k}", v)

    def snapshot(self) -> dict:
        self.sync()
        snap = self.registry.snapshot()
        snap["clock"] = self.now
        snap["stalls"] = self.stall_report()
        snap["spans"] = {"closed": len(self.tracer.spans),
                         "instants": len(self.tracer.instants),
                         "open_roots": len(self._root),
                         "dropped": self.tracer.dropped}
        return snap

    def prometheus_text(self) -> str:
        self.sync()
        return self.registry.prometheus_text()

    def export_chrome(self, path: Optional[str] = None) -> dict:
        self.stall_report()
        trace = self.tracer.chrome_trace(clock_end=self.now)
        if path:
            with open(path, "w") as f:
                json.dump(trace, f)
        return trace
