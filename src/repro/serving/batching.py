"""Continuous-batching scheduler: batched prefill + interleaved decode.

Sits between the Gateway (admission) and the InferenceEngine facade (device
arrays + jitted step functions). Each tick it:

  1. pulls admitted requests from the Gateway's FIFO queue,
  2. runs prefill for them in *length-bucketed padded batches* — one jitted
     call per bucket instead of one exact-shape call per request, so prompt
     lengths 6/9/12 share a single compilation keyed on (rows, bucket_len),
  3. restores preempted requests (``recovery=True``) from the checkpoint
     store instead of re-prefilling (paper §6.2 per-request restoration),
  4. runs one decode step over all active slots (``step``).

Two prefill schemes, chosen per model from the cache layout:

  * padded (pure full-attention caches) — prefill ``prompt[:-1]`` padded to
    the bucket length; pad entries are scrubbed from the merged slot by
    setting their cache ``pos`` to -1 (the decode kernels mask ``pos < 0``),
    and the prompt's last token is fed through the next *decode* step, which
    naturally interleaves the first generated token with ongoing decodes.
  * exact (ring-buffer / SSM / xLSTM / enc-dec caches, and 1-token prompts)
    — requests of identical prompt length share one unpadded call; the
    first token comes from the prefill's last-position logits. Padding is
    unsafe here because pad tokens would pollute recurrent state or evict
    ring-buffer entries.

Batch rows are padded up to the next power of two (row 0 repeated) so jit
compilations are keyed on O(log max_batch) row counts per bucket length
rather than every batch size ever seen.

When the chunked-prefill plane is enabled (``chunk_token_budget`` > 0,
serving/chunked.py), fresh paddable admissions bypass the whole-prompt
path entirely: their prompts stream through budgeted chunks interleaved
with decode, and recovery of a request preempted *mid-prefill* resumes
the stream from its committed cursor instead of re-prefilling.

Invariant note: pad tokens (length padding and repeated-row padding) are
flagged by a validity mask threaded through ``refe.route``, so they never
compete with real tokens for per-expert capacity ranks, and the prefill
capacity is derived from the REAL token count — a request's routing is
therefore independent of how much padding its batch carries, at any
capacity factor. Co-batched *real* tokens still share capacity cells under
a tight factor, exactly as co-batched decode slots always could.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serving.gateway import Gateway, QueuedRequest


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class PrefillStats:
    calls: int = 0                 # jitted prefill invocations
    requests: int = 0              # real requests prefilled
    rows: int = 0                  # batch rows launched (incl. row padding)
    real_tokens: int = 0           # true prompt tokens processed
    padded_tokens: int = 0         # rows * bucket_len launched
    batch_sizes: List[int] = field(default_factory=list)

    def occupancy(self) -> float:
        """Fraction of launched prefill FLOPs spent on real prompt tokens."""
        return self.real_tokens / self.padded_tokens if self.padded_tokens \
            else 0.0

    def mean_batch(self) -> float:
        return float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0

    def snapshot(self) -> dict:
        return {"calls": self.calls, "requests": self.requests,
                "occupancy": self.occupancy(),
                "mean_batch": self.mean_batch()}


class ContinuousBatchScheduler:
    """Drives admission, bucketed prefill, restoration, and decode over the
    engine's shared device state."""

    def __init__(self, engine, gateway: Gateway, bucket: int = 16):
        self.engine = engine
        self.gateway = gateway
        self.bucket = max(1, bucket)
        self.stats = PrefillStats()

    # ------------------------------------------------------------------
    # admission: gateway pop -> prefill/restore -> installed RequestState
    # ------------------------------------------------------------------
    def admit(self, now: float = 0.0) -> List[str]:
        """Admit as many queued requests as placement allows. Returns the
        rids installed this tick (fresh and recovered)."""
        eng = self.engine
        admitted = self.gateway.admit(now)
        fresh: List[Tuple[QueuedRequest, int, int]] = []
        installed: List[str] = []
        for q, aw, slot in admitted:
            if q.recovery:
                self._install_recovery(q, aw, slot, now)
            elif eng.chunked is not None and eng.prefill_paddable and \
                    len(q.prompt) >= 2:
                # chunked-prefill plane: the prompt streams through
                # budgeted chunks on subsequent ticks
                eng.chunked.start(q, aw, slot, now)
            else:
                fresh.append((q, aw, slot))
            installed.append(q.rid)
        for group in self._bucket_groups(fresh):
            self._prefill_group(group, now)
        return installed

    # -- grouping -----------------------------------------------------------
    def _bucket_groups(self, fresh):
        """Split fresh admissions into prefill groups: (padded, bucket_len)
        for the padded scheme, (exact, prompt_len) otherwise. Groups are
        capped at max_batch rows."""
        eng = self.engine
        groups: Dict[Tuple[bool, int], list] = {}
        for q, aw, slot in fresh:
            n = len(q.prompt)
            if eng.prefill_paddable and n >= 2:
                lb = -((n - 1) // -self.bucket) * self.bucket  # ceil bucket
                key = (True, lb)
            else:
                key = (False, n)
            groups.setdefault(key, []).append((q, aw, slot))
        out = []
        cap = eng.ecfg.max_batch
        for key, entries in sorted(groups.items(), key=lambda kv: kv[0]):
            for i in range(0, len(entries), cap):
                out.append((key, entries[i:i + cap]))
        return out

    # -- prefill ------------------------------------------------------------
    def _prefill_group(self, group, now: float):
        (padded, length), entries = group
        eng = self.engine
        n_real = len(entries)
        rows = _next_pow2(n_real)
        toks = np.zeros((rows, length), np.int32)
        pre_lens = []
        for i, (q, _, _) in enumerate(entries):
            pre = q.prompt[:-1] if padded else q.prompt
            toks[i, :len(pre)] = pre
            pre_lens.append(len(pre))
        for i in range(n_real, rows):           # row padding: repeat row 0
            toks[i] = toks[0]

        batch = {"tokens": jnp.asarray(toks)}
        capacity = None
        if eng.prefill_masked:
            # pad-free dispatch: flag real tokens (length pads AND repeated
            # row pads are excluded from expert-capacity competition) and
            # size capacity from the real token count
            mask = np.zeros((rows, length), bool)
            for i, n_pre in enumerate(pre_lens):
                mask[i, :n_pre] = True
            batch["mask"] = jnp.asarray(mask)
            capacity = eng.prefill_capacity(sum(pre_lens))
        if eng.cfg.is_encdec:
            frames = []
            for q, _, _ in entries:
                f = q.frames if q.frames is not None else np.zeros(
                    (eng.cfg.encoder_seq, eng.cfg.d_model), np.float32)
                frames.append(f)
            for _ in range(n_real, rows):
                frames.append(frames[0])
            batch["frames"] = jnp.asarray(np.stack(frames))

        # prefill runs on the request's own (healthy) AW: other AWs' health
        # must not mask its tokens; EW health still applies (shadow reroute)
        rs_pre = eng.route_state._replace(
            aw_health=jnp.ones_like(eng.route_state.aw_health))
        kw = {"capacity": capacity} if eng.prefill_masked else {}
        if eng.collect_load:
            last_logits, req_cache, load = eng._prefill(
                eng.params, batch, rs_pre, max_seq=eng.ecfg.max_seq,
                with_load=True, **kw)
            eng.note_dispatch_load(load)
        else:
            last_logits, req_cache = eng._prefill(
                eng.params, batch, rs_pre, max_seq=eng.ecfg.max_seq, **kw)
        firsts = None
        if not padded:
            # exact scheme: the first token comes from the prefill's last-
            # position logits, sampled on device with the same counter-based
            # head as decode (key pos = last prompt position, the position
            # a decode step would have consumed)
            firsts = eng.decode_plane.sample_rows(
                last_logits, [q for q, _, _ in entries],
                [len(q.prompt) - 1 for q, _, _ in entries])

        self.stats.calls += 1
        self.stats.requests += n_real
        self.stats.rows += rows
        self.stats.real_tokens += sum(pre_lens)
        self.stats.padded_tokens += rows * length
        self.stats.batch_sizes.append(n_real)

        for i, (q, aw, slot) in enumerate(entries):
            state = eng.layout.request_state(req_cache, i)
            if padded and pre_lens[i] < length:
                state = eng.layout.scrub_request_state(state, pre_lens[i])
            # paged engines map pages covering the prefilled prefix before
            # the scatter (writes beyond the mapped blocks are scrubbed
            # padding and drop harmlessly)
            eng._kv_ensure(slot, pre_lens[i])
            eng.cache = eng.layout.write_request_state(eng.cache, slot, state)
            first = int(firsts[i]) if not padded else None
            self._install_fresh(q, aw, slot, now, padded=padded, first=first,
                                n_prefilled=pre_lens[i])

    def _install_fresh(self, q: QueuedRequest, aw: int, slot: int,
                       now: float, *, padded: bool, first: Optional[int],
                       n_prefilled: int):
        eng = self.engine
        n = len(q.prompt)
        st = eng.make_request_state(q, slot)
        st._aw = aw
        st.t_admit = now
        if padded:
            # prompt's last token rides the next decode step; the first
            # generated token is sampled there (true continuous batching)
            st.pos = n - 1
            st.next_input = int(q.prompt[-1])
        else:
            st.tokens = [int(first)]
            st.pos = n
            st.next_input = int(first)
            st.t_first_token = now
            if len(st.tokens) >= st.max_new:   # max_new=1: done at prefill
                st.done = True
                st.t_done = now
        eng.requests[q.rid] = st
        if eng.telemetry is not None:
            eng.telemetry.on_whole_prefill(
                q.rid, now, n, "padded" if padded else "exact")

        if eng.ecfg.checkpoint:
            ck = eng.aws[aw].checkpointer
            ck.register(q.rid, prompt_len=n)
            if n_prefilled > 0:
                slots = jnp.full((n_prefilled,), slot, jnp.int32)
                tk = jnp.arange(n_prefilled, dtype=jnp.int32)
                stacked = [np.asarray(a)
                           for a in eng._extract(eng.cache, slots, tk)]
                for t in range(n_prefilled):
                    seg = [a[t] for a in stacked]
                    # token_value = next decode input after position t
                    tv = int(q.prompt[t + 1]) if t + 1 < n else int(first)
                    ck.checkpoint_token(q.rid, t, seg, token_value=tv)
            ck.flush()

    # -- per-request restoration (recovery admissions) ----------------------
    def _install_recovery(self, q: QueuedRequest, aw: int, slot: int,
                          now: float):
        """§6.2: inject the committed KV prefix into the new slot and rewind
        the request to the committed token. A request preempted mid-prefill
        re-enters the chunked plane with its cursor at the commit watermark
        — only the uncommitted tail of the prompt is recomputed, never the
        whole prompt."""
        eng = self.engine
        r = eng.requests.get(q.rid)
        if r is None:              # released while waiting for recovery
            eng.aws[aw].slots.release(slot)
            return
        committed, tok_val, segs = eng.store.restore_request(q.rid)
        eng._kv_clear_slot(slot)
        if segs:
            # paged: map pages covering the restored prefix first — the
            # committed segments then scatter through the block table
            eng._kv_ensure(slot, max(segs) + 1)
        cache = eng.cache
        for t, seg in segs.items():
            cache = eng.layout.write_token_segment(cache, slot, t, seg)
        eng.cache = cache

        r.slot = slot
        r._aw = aw
        r.paused = False
        r.queued_for_recovery = False
        r.t_admit = now
        eng.store.reassign(q.rid, aw)
        # re-bind sampling to the (possibly different) recovery slot; the
        # counter-based key is slot-independent, so the replayed stream is
        # bit-identical wherever the request lands
        eng.decode_plane.bind(r)
        if eng.telemetry is not None:
            eng.telemetry.on_restore(q.rid, now, len(segs), r.prefilling)
        if eng.flightrec is not None:
            eng.flightrec.on_restore(q.rid, now, len(segs), r.prefilling)

        if r.prefilling:
            # mid-prefill preemption: resume the chunk stream after the
            # restored prefix (cursor = committed + 1; committed may be -1
            # when the failure hit before any chunk was committed)
            assert eng.chunked is not None
            eng.chunked.stats.restored_tokens[q.rid] = \
                eng.chunked.stats.restored_tokens.get(q.rid, 0) + len(segs)
            eng.chunked.resume(r, aw, slot, committed + 1, now)
            return

        n_prompt = len(r.prompt)
        n_gen = max(0, committed + 2 - n_prompt)
        r.tokens = r.tokens[:n_gen]
        r.pos = committed + 1
        if committed + 1 < n_prompt:
            r.next_input = int(r.prompt[committed + 1])
        elif tok_val >= 0:
            r.next_input = int(tok_val)
        elif r.tokens:
            r.next_input = int(r.tokens[-1])

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def step(self, now: Optional[float] = None) -> Dict[str, List[int]]:
        """One iteration: an admission pass when anything is waiting (so
        Client-submitted and preempted requests re-enter without an
        external serving loop), deadline accounting, a budgeted slice of
        chunked prefill (when the plane is on), then one decode *segment*
        over all active slots — ``decode_segment_len`` device steps per
        dispatch (1 = per-step cadence). Returns {rid: new_tokens}."""
        eng = self.engine
        t_now = now if now is not None else float(eng.steps)
        if eng.controller is not None:
            # control-plane decision pass BEFORE admission: scale/rebalance
            # requests land on the orchestrator's virtual clock and the
            # chunk budget is set before this tick's planner slice runs
            eng.controller.tick(t_now)
        if self.gateway.depth():
            self.admit(t_now)
        eng.check_deadlines(t_now)
        if eng.flightrec is not None:
            # forensics plane: drain the bus through the recorder's own
            # cursor, fingerprint when due, advance the watchdogs —
            # host-side only, no effect on anything below
            eng.flightrec.tick(t_now)
        if eng.chunked is not None:
            eng.chunked.tick(t_now)
        act = eng.active_requests()
        if not act:
            return {}
        if eng.decode_plane.seg_len > 1:
            return self._step_segment(act, t_now)
        return self._step_single(act, t_now)

    def _step_single(self, act, t_now: float) -> Dict[str, List[int]]:
        """Per-step cadence (decode_segment_len=1): one jitted decode
        dispatch + device sampling; only the [B] token vector crosses to
        the host — the [B,V] logits never do."""
        eng = self.engine
        tokens = np.zeros((eng.ecfg.max_batch,), np.int32)
        # inactive rows carry pos -1: their cache writes are dropped, so a
        # decode step can never clobber a slot that is mid-chunked-prefill
        pos = np.full((eng.ecfg.max_batch,), -1, np.int32)
        for r in act:
            tokens[r.slot] = r.next_input
            pos[r.slot] = r.pos
            # paged: the step writes KV at r.pos — its page must be mapped
            eng._kv_ensure(r.slot, r.pos + 1)
        pos_dev = jnp.asarray(pos)
        if eng.collect_load:
            logits, eng.cache, load = eng._decode(
                eng.params, jnp.asarray(tokens), pos_dev, eng.cache,
                eng.route_state, capacity=eng.decode_capacity,
                with_load=True)
            eng.note_dispatch_load(load)
        else:
            logits, eng.cache = eng._decode(
                eng.params, jnp.asarray(tokens), pos_dev, eng.cache,
                eng.route_state, capacity=eng.decode_capacity)
        # sampling head stays on device (counter-based, slot-indexed
        # params); the drain below is the step's one host sync
        toks = np.asarray(eng.decode_plane.sample(logits, pos_dev))
        self.gateway.stats.host_syncs += 1

        ck_reqs = [r for r in act
                   if eng.ecfg.checkpoint and eng.aws[r.aw].alive]
        stacked = None
        if ck_reqs:
            # single batched device->host gather for all requests' segments
            slots = jnp.asarray([r.slot for r in ck_reqs], jnp.int32)
            tk = jnp.asarray([r.pos for r in ck_reqs], jnp.int32)
            stacked = [np.asarray(a) for a in eng._extract(eng.cache,
                                                           slots, tk)]
        ck_index = {r.rid: i for i, r in enumerate(ck_reqs)}

        out: Dict[str, List[int]] = {}
        t_log = t_now
        for r in act:
            nxt = int(toks[r.slot])
            written_pos = r.pos          # decode wrote KV at this position
            r.pos += 1
            r.tokens.append(nxt)
            r.next_input = nxt
            if r.t_first_token < 0:
                r.t_first_token = t_log
            out[r.rid] = [nxt]
            if r.rid in ck_index:
                seg = [a[ck_index[r.rid]] for a in stacked]
                eng.aws[r.aw].checkpointer.checkpoint_token(
                    r.rid, written_pos, seg, token_value=nxt)
            if len(r.tokens) >= r.max_new or r.pos >= eng.ecfg.max_seq - 1:
                r.done = True
                r.t_done = t_log
        for w in eng.aws:
            w.checkpointer.flush()
        eng.steps += 1
        return out

    def _step_segment(self, act, t_now: float) -> Dict[str, List[int]]:
        """Segmented cadence (decode_segment_len>1): ONE lax.scan dispatch
        runs up to seg_len decode+sample steps on device; the token ring
        drains to the host once, and each request's newly written KV range
        streams to the checkpoint store through the bulk-segment path
        (§6.1), so segment boundaries ARE checkpoint boundaries — a crash
        mid-segment rewinds at most seg_len tokens via the §6.2 restore."""
        eng = self.engine
        seg_len = eng.decode_plane.seg_len
        ring, loads = eng.decode_plane.run_segment(act, seg_len)
        self.gateway.stats.host_syncs += 1     # the per-segment drain
        if eng.collect_load:
            for i in range(seg_len):
                eng.note_dispatch_load(loads[i])

        out: Dict[str, List[int]] = {}
        max_seq = eng.ecfg.max_seq
        ck_items = []
        for r in act:
            # the device stop mask and this count are the same formula:
            # steps until max_new or the cache ceiling, capped by seg_len
            n_take = max(0, min(seg_len, r.max_new - len(r.tokens),
                                (max_seq - 1) - r.pos))
            col = ring[:, r.slot]
            start = r.pos
            toks = [int(c) for c in col[:n_take]]
            assert all(c >= 0 for c in toks), \
                f"{r.rid}: ring drained an inactive step"
            for nxt in toks:
                r.pos += 1
                r.tokens.append(nxt)
                r.next_input = nxt
            if toks and r.t_first_token < 0:
                r.t_first_token = t_now
            out[r.rid] = toks
            if toks and eng.ecfg.checkpoint and eng.aws[r.aw].alive:
                ck_items.append((r, start, len(toks)))
            if len(r.tokens) >= r.max_new or r.pos >= max_seq - 1:
                r.done = True
                r.t_done = t_now
        if ck_items:
            # checkpoint_range over exactly the segment's KV writes — one
            # multi-slot device gather for every request in the segment
            eng._bulk_checkpoint_group(ck_items)
        for w in eng.aws:
            w.checkpointer.flush()
        eng.steps += 1
        return out
