"""Serving simulation loop over the layered stack.

Drives the Gateway (admission + FIFO waiting queue), the
ContinuousBatchScheduler (bucketed prefill + decode), and the Orchestrator
(failure detection/provisioning) with a workload trace over a virtual
clock, collecting the §7.2/§7.3 measurement set: TTFT, TBT, queueing delay,
output tokens/s, and prefill-batch occupancy.

All request timestamps live on the virtual clock — TTFT is
(first token time - arrival), queueing delay is (admission - arrival) —
so benchmark numbers are internally consistent regardless of host speed.

Virtual time: each decode step advances the clock by a configurable step
time (default: measured wall time of the step, which is meaningful for
*relative* comparisons on CPU; benchmarks may pass a fixed model-based step
time for GPU-comparable absolute numbers).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.orchestrator import Orchestrator
from repro.data.workloads import Request
from repro.serving.engine import InferenceEngine


@dataclass
class TokenRecord:
    t: float
    rid: str


@dataclass
class ServeMetrics:
    token_log: List[TokenRecord] = field(default_factory=list)
    ttft: Dict[str, float] = field(default_factory=dict)
    queue_delay: Dict[str, float] = field(default_factory=dict)
    outputs: Dict[str, List[int]] = field(default_factory=dict)
    finished: List[str] = field(default_factory=list)
    duration: float = 0.0
    prefill: dict = field(default_factory=dict)  # scheduler PrefillStats
    slo_class: Dict[str, str] = field(default_factory=dict)  # rid -> class
    gateway: dict = field(default_factory=dict)  # GatewayStats snapshot
    telemetry: object = None   # the engine's TelemetryPlane (None = off):
    #                            streamed twins of the exact lists above,
    #                            spans, and per-cause stall attribution
    controller: dict = field(default_factory=dict)  # control-plane audit
    #                            (decision history + counters; {} = off)

    def throughput(self) -> float:
        return len(self.token_log) / self.duration if self.duration else 0.0

    def tbt_values(self, slo_class: str = None) -> np.ndarray:
        by_req: Dict[str, List[float]] = {}
        for rec in self.token_log:
            if slo_class is not None and \
                    self.slo_class.get(rec.rid) != slo_class:
                continue
            by_req.setdefault(rec.rid, []).append(rec.t)
        gaps = []
        for ts in by_req.values():
            ts = sorted(ts)
            gaps.extend(np.diff(ts))
        return np.asarray(gaps) if gaps else np.zeros((0,))

    def ttft_values(self, slo_class: str = None) -> np.ndarray:
        vals = [v for rid, v in self.ttft.items()
                if slo_class is None or
                self.slo_class.get(rid) == slo_class]
        return np.asarray(vals) if vals else np.zeros((0,))

    def max_stall(self, slo_class: str = None) -> float:
        v = self.tbt_values(slo_class)
        return float(v.max()) if v.size else 0.0

    def queue_delay_values(self) -> np.ndarray:
        return np.asarray(list(self.queue_delay.values())) \
            if self.queue_delay else np.zeros((0,))

    def throughput_timeline(self, dt: float = 0.5):
        if not self.token_log:
            return np.zeros((0,)), np.zeros((0,))
        ts = np.asarray([r.t for r in self.token_log])
        edges = np.arange(0.0, self.duration + dt, dt)
        hist, _ = np.histogram(ts, bins=edges)
        return edges[:-1], hist / dt


@dataclass
class FailurePlan:
    t: float
    kind: str      # "aw" | "ew"
    worker_id: int


@dataclass
class ScalePlan:
    """Elasticity event on the serving timeline: at virtual time ``t`` ask
    the orchestrator to grow/shrink/re-pack the EW pool (completion lands
    T_w/T_push later on the same clock)."""
    t: float
    kind: str           # "add_ew" | "drain_ew" | "rebalance"
    worker_id: int = -1  # only for drain_ew


def run_serving(engine: InferenceEngine, workload: List[Request],
                duration: float, *,
                orchestrator: Optional[Orchestrator] = None,
                failures: List[FailurePlan] = (),
                scale_events: List[ScalePlan] = (),
                step_time: Optional[float] = None,
                prefill_token_time: Optional[float] = None,
                max_steps: int = 100000) -> ServeMetrics:
    """``prefill_token_time`` charges prefill work to the virtual clock
    (seconds per real prompt token prefilled in the tick, on top of the
    decode step time) — whole-prompt prefill of a long prompt then shows
    up as the TBT stall it is for co-resident decodes, and the chunked
    plane's per-tick token budget bounds that stall."""
    m = ServeMetrics()
    gw = engine.gateway
    tel = engine.telemetry
    m.telemetry = tel
    fr = engine.flightrec
    if fr is not None:
        # forensics plane: pin the loop's replay parameters — a bundle
        # replays the incident only if it can re-run THIS loop verbatim
        fr.note_loop(duration=duration, step_time=step_time,
                     prefill_token_time=prefill_token_time,
                     max_steps=max_steps)
    clock = 0.0
    pending = sorted(workload, key=lambda r: r.arrival)
    qi = 0
    injected = [False] * len(failures)
    scaled = [False] * len(scale_events)
    steps = 0
    seen_first = set()
    while clock < duration and steps < max_steps:
        # failure injection
        for i, f in enumerate(failures):
            if not injected[i] and clock >= f.t:
                assert orchestrator is not None
                orchestrator.inject_failure(f.kind, f.worker_id, clock)
                if fr is not None:
                    fr.note_injection("failure", f)
                injected[i] = True
        # elasticity requests (completion is clocked by the orchestrator)
        for i, s in enumerate(scale_events):
            if not scaled[i] and clock >= s.t:
                assert orchestrator is not None
                if s.kind == "add_ew":
                    orchestrator.request_scale_out(clock)
                elif s.kind == "drain_ew":
                    orchestrator.request_scale_in(s.worker_id, clock)
                elif s.kind == "rebalance":
                    orchestrator.request_rebalance(clock)
                else:
                    raise ValueError(f"unknown scale event kind {s.kind!r}"
                                     " (add_ew | drain_ew | rebalance)")
                if fr is not None:
                    fr.note_injection("scale", s)
                scaled[i] = True
        if orchestrator is not None:
            orchestrator.tick(clock)
        # arrivals enter their SLO class's Gateway queue (never dropped);
        # admission + bucketed prefill happen in one scheduler pass
        while qi < len(pending) and pending[qi].arrival <= clock:
            r = pending[qi]
            # enqueue stamped with the true arrival: queueing delay and
            # TTFT are measured from arrival, not from the tick the loop
            # first noticed the request
            gw.enqueue(r.request_id, r.prompt_tokens(engine.cfg.vocab_size),
                       r.max_new_tokens, now=r.arrival,
                       slo_class=getattr(r, "slo_class", "standard"),
                       deadline=r.deadline if getattr(r, "deadline", -1.0)
                       >= 0 else None,
                       session=getattr(r, "session", "") or None)
            m.slo_class[r.request_id] = getattr(r, "slo_class", "standard")
            qi += 1
        pf0 = engine.prefill_tokens_done()
        # decode step: engine.step runs the admission pass itself (when
        # anything is queued), then a budgeted chunked-prefill slice when
        # the plane is on, then decode
        t0 = time.monotonic()
        out = engine.step(now=clock)
        dt = step_time if step_time is not None else time.monotonic() - t0
        if prefill_token_time is not None:
            dt += (engine.prefill_tokens_done() - pf0) * prefill_token_time
        if not out:
            # idle tick: quit once nothing can ever make progress again —
            # including scheduled failure/scale injections that have not
            # reached their trigger time yet, and requests (preempted or
            # fresh) still waiting in a Gateway class queue
            if qi >= len(pending) and not engine.active_requests() and \
                    not engine.prefilling_requests() and \
                    gw.depth() == 0 and \
                    all(injected) and all(scaled) and \
                    (orchestrator is None or orchestrator.outstanding == 0):
                break
            dt = max(dt, 1e-3)
        clock += dt
        if tel is not None:
            pf_done = engine.prefill_tokens_done() - pf0
            tel.on_step(clock - dt, clock, pf_done,
                        pf_done * (prefill_token_time or 0.0),
                        sum(len(t) for t in out.values()))
        for rid, toks in out.items():
            # one TokenRecord per emitted token: a decode segment
            # (decode_segment_len>1) lands several per step, all stamped
            # at the segment's end time
            for _ in toks:
                m.token_log.append(TokenRecord(clock, rid))
            if tel is not None and toks:
                # streamed twin of token_log: same stamps, same gap
                # sequence (n tokens at one stamp = gap + n-1 zeros)
                tel.observe_tokens(rid, clock, len(toks),
                                   m.slo_class.get(rid, "standard"))
            if rid not in seen_first and toks:
                seen_first.add(rid)
                r = engine.requests.get(rid)
                if r is not None:
                    # padded-prefill requests emit their first token
                    # through the decode step: stamp TTFT at the step's
                    # *end* time (exact-scheme requests got theirs at
                    # admission). Record immediately so still-running
                    # requests at the duration cutoff are not excluded
                    # from the TTFT distribution.
                    if len(r.tokens) == len(toks):
                        r.t_first_token = clock
                    m.ttft[rid] = r.ttft
                    if tel is not None:
                        tel.observe_ttft(rid, r.ttft,
                                         m.slo_class.get(rid, "standard"),
                                         r.t_enqueue)
        for r in list(engine.requests.values()):
            if r.done and r.rid not in m.finished:
                m.finished.append(r.rid)
                m.ttft[r.rid] = r.ttft
                if tel is not None:
                    tel.observe_ttft(r.rid, r.ttft,
                                     m.slo_class.get(r.rid, "standard"),
                                     r.t_enqueue)
                m.outputs[r.rid] = list(r.tokens)
                engine.release_request(r.rid)
        steps += 1
    m.duration = clock
    if tel is not None:
        tel.finalize(clock)
    m.queue_delay = dict(gw.stats.queue_delay)
    m.prefill = engine.prefill_snapshot()
    m.gateway = {"preemptions": gw.stats.preemptions,
                 "blocked_ticks": gw.stats.blocked_ticks,
                 "host_syncs": gw.stats.host_syncs,
                 "by_class": {c: dict(v)
                              for c, v in gw.stats.by_class.items()},
                 "prefix": {"hits": gw.stats.prefix_hits,
                            "misses": gw.stats.prefix_misses,
                            "hit_tokens": gw.stats.prefix_hit_tokens,
                            "evictions": gw.stats.prefix_evictions,
                            "restored": gw.stats.prefix_restored,
                            "global_hits": gw.stats.prefix_global_hits,
                            "migrated": gw.stats.prefix_migrated,
                            "repins": gw.stats.session_repins}}
    if engine.pages is not None:
        m.gateway["pages"] = engine.pages.stats()
    if engine.controller is not None:
        m.controller = engine.controller.snapshot()
    return m
