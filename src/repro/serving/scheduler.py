"""Serving simulation loop: cluster gateway + request scheduler.

Drives an InferenceEngine with a workload trace over a virtual clock,
coordinating admission (gateway -> least-loaded healthy AW), decode stepping,
failure injection via the orchestrator, and metric collection (TTFT, TBT,
output tokens/s) — the measurement harness behind the §7.2/§7.3 benchmarks.

Virtual time: each decode step advances the clock by a configurable step
time (default: measured wall time of the step, which is meaningful for
*relative* comparisons on CPU; benchmarks may pass a fixed model-based step
time for GPU-comparable absolute numbers).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.orchestrator import Orchestrator
from repro.data.workloads import Request
from repro.serving.engine import InferenceEngine


@dataclass
class TokenRecord:
    t: float
    rid: str


@dataclass
class ServeMetrics:
    token_log: List[TokenRecord] = field(default_factory=list)
    ttft: Dict[str, float] = field(default_factory=dict)
    finished: List[str] = field(default_factory=list)
    duration: float = 0.0

    def throughput(self) -> float:
        return len(self.token_log) / self.duration if self.duration else 0.0

    def tbt_values(self) -> np.ndarray:
        by_req: Dict[str, List[float]] = {}
        for rec in self.token_log:
            by_req.setdefault(rec.rid, []).append(rec.t)
        gaps = []
        for ts in by_req.values():
            ts = sorted(ts)
            gaps.extend(np.diff(ts))
        return np.asarray(gaps) if gaps else np.zeros((0,))

    def max_stall(self) -> float:
        v = self.tbt_values()
        return float(v.max()) if v.size else 0.0

    def throughput_timeline(self, dt: float = 0.5):
        if not self.token_log:
            return np.zeros((0,)), np.zeros((0,))
        ts = np.asarray([r.t for r in self.token_log])
        edges = np.arange(0.0, self.duration + dt, dt)
        hist, _ = np.histogram(ts, bins=edges)
        return edges[:-1], hist / dt


@dataclass
class FailurePlan:
    t: float
    kind: str      # "aw" | "ew"
    worker_id: int


def run_serving(engine: InferenceEngine, workload: List[Request],
                duration: float, *,
                orchestrator: Optional[Orchestrator] = None,
                failures: List[FailurePlan] = (),
                step_time: Optional[float] = None,
                max_steps: int = 100000) -> ServeMetrics:
    m = ServeMetrics()
    clock = 0.0
    pending = sorted(workload, key=lambda r: r.arrival)
    qi = 0
    injected = [False] * len(failures)
    steps = 0
    while clock < duration and steps < max_steps:
        # failure injection
        for i, f in enumerate(failures):
            if not injected[i] and clock >= f.t:
                assert orchestrator is not None
                orchestrator.inject_failure(f.kind, f.worker_id, clock)
                injected[i] = True
        if orchestrator is not None:
            orchestrator.tick(clock)
        # admission
        while qi < len(pending) and pending[qi].arrival <= clock:
            r = pending[qi]
            ok = engine.submit(r.request_id,
                               r.prompt_tokens(engine.cfg.vocab_size),
                               r.max_new_tokens)
            if not ok:
                break  # no capacity; retry next tick
            m.ttft[r.request_id] = clock - r.arrival
            qi += 1
        # decode step
        t0 = time.monotonic()
        out = engine.step()
        dt = step_time if step_time is not None else time.monotonic() - t0
        if not out and qi >= len(pending):
            break
        if not out:
            dt = max(dt, 1e-3)  # idle tick
        clock += dt
        for rid in out:
            m.token_log.append(TokenRecord(clock, rid))
        for r in list(engine.requests.values()):
            if r.done and r.rid not in m.finished:
                m.finished.append(r.rid)
                engine.release_request(r.rid)
        steps += 1
    m.duration = clock
    return m
