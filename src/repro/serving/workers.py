"""Worker objects for the layered serving stack (paper Fig. 5).

The paper's central claim is that Attention Workers and Expert Workers are
*distinct failure domains* behind a reconfigurable datapath. This module
makes that structural: each worker object owns exactly the state that dies
with it, and `fail()` / `provision()` are methods on the worker — the blast
radius of a failure is the worker's own attributes, not a flag on a global
engine.

  * ``AttentionWorker`` — owns its slice of the slot space (a
    ``SlotPartition`` over the shared cache pytree), its ``KVCheckpointer``
    stream into the checkpoint store, and its liveness bit. Killing it
    drops the slots and stops the checkpoint stream; everything else in the
    cluster keeps running.
  * ``ExpertWorker`` — owns its liveness bit; its experts' reachability is
    carried in-band by the RouteState health mask (core/selfheal.py), so
    `fail()`/`provision()` are pure RouteState transitions.

Workers never talk to each other: the Gateway places requests onto AWs, the
ContinuousBatchScheduler drives the shared jitted step, and the
InferenceEngine facade owns the device-side arrays (single-process
simulation of the multi-host datapath).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Set

from repro.core import selfheal
from repro.core.checkpoint import CheckpointStore, KVCheckpointer
from repro.core.refe import RouteState


class SlotPartition:
    """Free-list over one AW's contiguous slot range [lo, hi) of the shared
    batch dimension (data-parallel request ownership). The free list is a
    deque: alloc pops the front, release pushes the front (LIFO reuse keeps
    recently-cleared slots hot), both O(1) instead of list.pop(0) /
    list.insert(0, ...)'s O(n) shifting."""

    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi
        self._free: Deque[int] = deque(range(lo, hi))

    @property
    def capacity(self) -> int:
        return self.hi - self.lo

    def free_count(self) -> int:
        return len(self._free)

    def owns(self, slot: int) -> bool:
        return self.lo <= slot < self.hi

    def alloc(self) -> int:
        return self._free.popleft()

    def release(self, slot: int):
        assert self.owns(slot)
        self._free.appendleft(slot)

    def drop(self):
        """The partition's slots become unusable (worker crash)."""
        self._free = deque()

    def restore(self, in_use: Set[int]):
        self._free = deque(s for s in range(self.lo, self.hi)
                           if s not in in_use)


class AttentionWorker:
    """One AW: cache partition + checkpoint stream + liveness.

    RouteState is the cluster-wide routing array consumed by the jitted
    step; transitions return the updated state for the engine to install
    (the device arrays themselves are shared in this single-process
    simulation).
    """

    def __init__(self, aw_id: int, lo: int, hi: int, store: CheckpointStore,
                 reorder_window: int = 0):
        self.aw_id = aw_id
        self.slots = SlotPartition(lo, hi)
        self.checkpointer = KVCheckpointer(store, aw_id,
                                           reorder_window=reorder_window,
                                           seed=aw_id)
        # in-flight chunked-prefill streams this AW owns: rid ->
        # prefill_cursor (prompt tokens already written to its slot).
        # Dies with the worker like the slot partition does.
        self.prefills: dict = {}
        # per-AW prefix cache (serving/prefixcache.py), attached by the
        # engine's PrefixCachePlane when the plane is enabled. Cached
        # slots are *this worker's* retained KV: they count as evictable
        # capacity and die with the worker (metadata is orphaned to the
        # checkpoint store by the plane before fail()). On paged engines
        # the cache is page-level (PagedAWPrefixCache): entries pin pages
        # rather than slots, so evictable_count() is 0 and free_slots()
        # is the raw partition free count.
        self.prefix_cache = None
        # paged engines install the engine's PagePool here: this AW's
        # page partition is its second capacity axis (telemetry gauges
        # ride kv_page_stats like slot gauges ride slot_occupancy)
        self.page_pool = None
        self.alive = True

    # -- placement view -----------------------------------------------------
    def free_slots(self) -> int:
        if not self.alive:
            return 0
        free = self.slots.free_count()
        if self.prefix_cache is not None:
            free += self.prefix_cache.evictable_count()
        return free

    def has_capacity(self) -> bool:
        return self.alive and self.free_slots() > 0

    def slot_occupancy(self) -> tuple:
        """(slots in use, partition capacity) — cached-prefix slots count
        as occupied (they hold retained KV) until evicted. Telemetry-plane
        gauge feed; a dead worker reports full occupancy of nothing
        usable."""
        cap = self.slots.capacity
        if not self.alive:
            return (cap, cap)
        return (cap - self.slots.free_count(), cap)

    def kv_page_stats(self):
        """(pages in use, partition pages) over this AW's slice of the
        physical page pool, or None on contiguous engines. A dead worker
        reports full occupancy of nothing usable, mirroring
        slot_occupancy."""
        if self.page_pool is None:
            return None
        total = self.page_pool.pages_per_aw
        if not self.alive:
            return (total, total)
        return (total - self.page_pool.free_pages(self.aw_id), total)

    def take_slot(self, prompt=None, now: float = 0.0):
        """Allocate a slot for an admission. With a prefix cache, a
        matching cached prefix is adopted by reference (returning its
        matched length); otherwise a free-list slot, else the cache's LRU
        entry is evicted. Returns (slot, matched_prefix_len)."""
        if self.prefix_cache is not None:
            return self.prefix_cache.take_slot(prompt, now)
        return self.slots.alloc(), 0

    def drop_request(self, rid: str) -> int:
        """Planned teardown of one request's residency on this AW (cancel,
        release, preemption): forget its in-flight prefill cursor and
        discard its pending checkpoint WRs. Unlike ``fail()``, the slot
        partition is untouched — the caller releases the slot explicitly.
        Returns the number of pending WRs discarded."""
        self.prefills.pop(rid, None)
        return self.checkpointer.drop_request(rid)

    # -- lifecycle ----------------------------------------------------------
    def fail(self, route_state: RouteState) -> RouteState:
        """Crash: slots (and any un-checkpointed KV) are gone — checkpoint
        WRs still pending on the AW side never reach the store, so the
        commit watermark freezes at the last delivered contiguous prefix."""
        self.alive = False
        self.slots.drop()
        self.prefills.clear()
        if self.prefix_cache is not None:
            self.prefix_cache.clear()
        self.checkpointer.drop_pending()
        return selfheal.fail_aw(route_state, self.aw_id)

    def provision(self, route_state: RouteState,
                  in_use: Set[int]) -> RouteState:
        """Background re-provisioning (§5.4): fresh slots join the pool."""
        self.alive = True
        self.slots.restore(in_use)
        return selfheal.recover_aw(route_state, self.aw_id)

    def __repr__(self):
        return (f"AW{self.aw_id}(alive={self.alive}, "
                f"free={self.slots.free_count()}/{self.slots.capacity})")


class ExpertWorker:
    """One EW: liveness + pool membership — expert reachability lives in the
    RouteState (ERT candidates + ew_health), which the AW-side routing
    consumes on the next step without recompilation.

    ``member`` distinguishes the elastic pool states: a spare EW
    (member=False, alive=False) exists only as reserved health-mask
    capacity until a scale-out admits it; a drained/promoted-away EW
    returns to spare. ``fail()`` is only meaningful for members."""

    def __init__(self, ew_id: int, member: bool = True):
        self.ew_id = ew_id
        self.member = member
        self.alive = member

    def fail(self, route_state: RouteState) -> RouteState:
        self.alive = False
        return selfheal.fail_ew(route_state, self.ew_id)

    def provision(self, route_state: RouteState) -> RouteState:
        self.alive = True
        self.member = True
        return selfheal.recover_ew(route_state, self.ew_id)

    def retire(self, route_state: RouteState) -> RouteState:
        """Leave the pool (graceful drain or permanent shadow promotion):
        the worker becomes a spare, its slots' reachability drops out via
        the health mask."""
        self.alive = False
        self.member = False
        return selfheal.fail_ew(route_state, self.ew_id)

    def __repr__(self):
        return (f"EW{self.ew_id}(alive={self.alive}, "
                f"member={self.member})")


class ClusterSlotView:
    """Back-compat facade with the old engine-owned SlotManager API, backed
    by the per-worker partitions (tests/benchmarks read free counts)."""

    def __init__(self, workers: List[AttentionWorker], max_batch: int):
        self._workers = workers
        self.max_batch = max_batch
        self.num_aw = len(workers)
        self.per_aw = max_batch // len(workers)

    def aw_of(self, slot: int) -> int:
        return slot // self.per_aw

    def free_count(self, aw_id: int) -> int:
        return self._workers[aw_id].slots.free_count()

    def alloc(self, aw_id: int) -> int:
        return self._workers[aw_id].slots.alloc()

    def release(self, slot: int):
        self._workers[self.aw_of(slot)].slots.release(slot)
