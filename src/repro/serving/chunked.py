"""Chunked-prefill plane: token-budget scheduling + resumable prefill.

The third plane of the serving stack (after the Gateway's admission plane
and the ContinuousBatchScheduler's batching plane), and the first where
performance isolation and failure recovery are the same mechanism: prefill
is no longer an all-at-once batch operation but a budgeted, checkpointable
stream of chunks.

  * **Token-budget iteration planner** — each tick packs at most
    ``chunk_token_budget`` real prompt tokens of prefill work next to the
    decode step (Sarathi-style stall bounding): a long-prompt burst can no
    longer freeze every co-resident decode for a whole-prompt prefill.
  * **O(log) jit keys** — prompt slices are padded to a geometric set of
    chunk shapes (``chunk_min`` · 2^i); the jitted ``prefill_chunk`` call
    always runs over the full slot-partitioned cache, so compilations are
    keyed on the chunk shape alone. Rows not in the chunk (live decode
    slots, other requests) carry position -1 and are untouched.
  * **Resumable streams** — per-request progress lives in
    ``RequestState.prefill_cursor`` and mirrors into the owning
    AttentionWorker's ``prefills`` map (the worker owns its in-flight
    prefill work the way it owns its slots). Chunk-boundary KV segments
    stream to the CheckpointStore through the bulk-segment path
    (CacheLayout.make_slot_range_extractor + KVCheckpointer
    .checkpoint_range), extending the paper's §6.1 incremental decode
    checkpointing to prefill.
  * **Mid-prefill failure recovery** — when an AW dies mid-prefill, the
    request re-enters the Gateway as a recovery entry like any preempted
    decode; restoration injects the committed chunk prefix into a healthy
    slot and resumes prefill *from the cursor* instead of re-prefilling
    from token 0. Only segments past the commit watermark (WRs that died
    with the AW) are recomputed.
  * **Mid-prefill preemption** — planned eviction
    (``engine.preempt_request``, serving/api.py) reuses the same
    ``drop``/``resume`` pair: the stream's pending WRs are *flushed* (not
    dropped — eviction is not a crash), so the resume cursor equals the
    preemption cursor and zero chunk work is recomputed.

Only full-attention cache families expose ``prefill_chunk`` (cache slot ==
absolute position); recurrent/ring-buffer caches keep the exact
whole-prompt scheme in serving/batching.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class ChunkedPrefillStats:
    calls: int = 0                 # jitted chunk invocations
    chunks: int = 0                # (request, chunk) pairs processed
    requests: int = 0              # prefill streams started
    resumed: int = 0               # streams resumed after mid-prefill failure
    real_tokens: int = 0           # true prompt tokens prefilled (incl. any
    #                                recompute after recovery)
    launched_tokens: int = 0       # rows * shape launched per call
    shapes: List[int] = field(default_factory=list)   # distinct shapes used
    prefilled_tokens: Dict[str, int] = field(default_factory=dict)
    restored_tokens: Dict[str, int] = field(default_factory=dict)

    def occupancy(self) -> float:
        return self.real_tokens / self.launched_tokens \
            if self.launched_tokens else 0.0

    def snapshot(self) -> dict:
        return {"calls": self.calls, "chunks": self.chunks,
                "requests": self.requests, "resumed": self.resumed,
                "real_tokens": self.real_tokens,
                "occupancy": self.occupancy(),
                "shapes": sorted(self.shapes)}


@dataclass
class _PrefillJob:
    rid: str
    prompt: np.ndarray
    aw: int
    slot: int
    n_pre: int                     # tokens to prefill (= len(prompt) - 1;
    #                                the last token rides the decode step)


class ChunkedPrefillPlane:
    """Budgeted, resumable prefill over the engine's shared cache."""

    def __init__(self, engine, budget: int, min_chunk: int = 8):
        self.engine = engine
        self.budget = max(1, budget)
        # chunk shapes must fit the cache extent: the biggest shape is the
        # largest power of two <= max_seq, and per-tick takes are capped so
        # _shape_for never rounds past it
        self.max_shape = 1
        while self.max_shape * 2 <= engine.ecfg.max_seq:
            self.max_shape *= 2
        self.min_chunk = max(1, min(min_chunk, self.max_shape))
        self.jobs: Dict[str, _PrefillJob] = {}   # rid -> job, FIFO order
        self.stats = ChunkedPrefillStats()
        self._extract_range = engine.layout.make_slot_range_extractor()

    def set_budget(self, budget: int) -> int:
        """Control-plane actuator: retarget the per-tick token budget. The
        budget is a host int the planner reads fresh each ``plan()`` pass;
        the chunk SHAPE set (pow2 buckets capped at ``max_shape``) never
        changes with it, so adjusting the budget at runtime introduces no
        new jit keys. Returns the clamped value now in effect."""
        self.budget = max(1, int(budget))
        return self.budget

    # ------------------------------------------------------------------
    # admission-side API
    # ------------------------------------------------------------------
    def outstanding_tokens(self) -> int:
        """Prefill tokens admitted but not yet processed — the Gateway's
        token-based admission signal."""
        eng = self.engine
        return sum(j.n_pre - eng.requests[j.rid].prefill_cursor
                   for j in self.jobs.values() if j.rid in eng.requests)

    def start(self, q, aw: int, slot: int, now: float):
        """Open a fresh prefill stream for an admitted request.

        Prefix-cache adoption (serving/prefixcache.py): when placement
        matched a cached prefix (``q.prefix_hit`` > 0), the slot already
        holds its KV — the stale tail is scrubbed instead of clearing the
        slot, the stream starts at ``prefill_cursor = matched_len``, and
        the adopted prefix is re-checkpointed into THIS request's store
        log through the bulk-segment path, so a later crash restores the
        hit just like any committed chunk prefix (the recovery entry
        resumes with the hit intact). A fully-cached prompt skips the
        chunk stream entirely and goes straight to decode."""
        eng = self.engine
        n = len(q.prompt)
        hit = min(getattr(q, "prefix_hit", 0), n - 1)
        if hit > 0:
            # adoption already holds the prefix (by slot reference on a
            # contiguous engine, by shared pages on a paged one): mask the
            # stale tail, keep [0, hit)
            eng._kv_scrub_slot(slot, hit)
        else:
            eng._kv_clear_slot(slot)
        r = eng.make_request_state(q, slot)
        r._aw = aw
        r.t_admit = now
        r.prefilling = True
        r.prefill_cursor = hit
        eng.requests[q.rid] = r
        if eng.ecfg.checkpoint:
            eng.aws[aw].checkpointer.register(q.rid, prompt_len=n)
            if hit > 0:
                # the adopted prefix becomes this request's own
                # checkpointed state — its recovery never depends on the
                # donor entry (whose log was released at adoption)
                eng._bulk_checkpoint(r, 0, hit - 1)
                eng.aws[aw].checkpointer.flush()
        self.stats.requests += 1
        self.stats.prefilled_tokens.setdefault(q.rid, 0)
        if eng.telemetry is not None:
            eng.telemetry.on_prefill_start(q.rid, now, hit, n)
        if hit >= n - 1:
            # whole prompt prefix cached: first decode step emits the
            # first token — warm-turn TTFT is one step
            self._finalize(r)
            return
        self.jobs[q.rid] = _PrefillJob(q.rid, np.asarray(q.prompt), aw, slot,
                                       n_pre=n - 1)
        eng.aws[aw].prefills[q.rid] = hit

    def resume(self, r, aw: int, slot: int, cursor: int, now: float):
        """Re-open a stream after mid-prefill failure recovery: the
        committed prefix [0, cursor) is already restored in the slot; only
        [cursor, n_pre) remains to compute."""
        n_pre = len(r.prompt) - 1
        r.prefill_cursor = cursor
        self.stats.resumed += 1
        if cursor >= n_pre:        # the whole prompt prefix was committed
            self._finalize(r)
            return
        r.prefilling = True
        self.jobs[r.rid] = _PrefillJob(r.rid, np.asarray(r.prompt), aw, slot,
                                       n_pre=n_pre)
        self.engine.aws[aw].prefills[r.rid] = cursor

    def drop(self, rid: str):
        job = self.jobs.pop(rid, None)
        if job is not None:
            self.engine.aws[job.aw].prefills.pop(rid, None)

    def drop_aw(self, aw_id: int):
        """AW crash: its in-flight prefill streams die with it (they are
        re-opened by recovery entries through the Gateway)."""
        for rid in [r for r, j in self.jobs.items() if j.aw == aw_id]:
            del self.jobs[rid]
        self.engine.aws[aw_id].prefills.clear()

    # ------------------------------------------------------------------
    # the iteration planner
    # ------------------------------------------------------------------
    def _shape_for(self, take: int) -> int:
        return min(max(self.min_chunk, _pow2_at_least(take)),
                   self.max_shape)

    def plan(self) -> List[Tuple[_PrefillJob, int]]:
        """Pack (job, take) pairs under the token budget, FIFO over the
        in-flight streams. Every planned job advances by at least one
        token, so a budget smaller than one chunk still makes progress."""
        eng = self.engine
        out: List[Tuple[_PrefillJob, int]] = []
        left = self.budget
        for job in list(self.jobs.values()):
            if left <= 0:
                break
            r = eng.requests.get(job.rid)
            if r is None or r.paused:
                continue
            rem = job.n_pre - r.prefill_cursor
            if rem <= 0:
                continue
            take = min(rem, left, self.max_shape)
            out.append((job, take))
            left -= take
        return out

    def tick(self, now: float) -> int:
        """Run one iteration of budgeted prefill. Returns the number of
        real prompt tokens processed this tick."""
        planned = self.plan()
        if not planned:
            return 0
        by_shape: Dict[int, List[Tuple[_PrefillJob, int]]] = {}
        for job, take in planned:
            by_shape.setdefault(self._shape_for(take), []).append((job, take))
        done = 0
        for shape in sorted(by_shape):
            done += self._run_chunk_call(shape, by_shape[shape], now)
        return done

    # ------------------------------------------------------------------
    # one jitted chunk call (one shape, >= 1 requests)
    # ------------------------------------------------------------------
    def _run_chunk_call(self, shape: int,
                        entries: List[Tuple[_PrefillJob, int]],
                        now: float) -> int:
        eng = self.engine
        rows = eng.ecfg.max_batch
        toks = np.zeros((rows, shape), np.int32)
        pos = np.full((rows, shape), -1, np.int32)
        real = 0
        for job, take in entries:
            r = eng.requests[job.rid]
            c = r.prefill_cursor
            toks[job.slot, :take] = job.prompt[c:c + take]
            pos[job.slot, :take] = np.arange(c, c + take, dtype=np.int32)
            real += take
            # paged: map pages covering the chunk's write range before the
            # dispatch (page allocation is host bookkeeping + one tiny
            # block-table upload — the jitted chunk call is untouched)
            eng._kv_ensure(job.slot, c + take)

        # prefill runs on the request's own (healthy) AW: other AWs'
        # health must not mask its tokens; EW health still applies
        rs_pre = eng.route_state._replace(
            aw_health=jnp.ones_like(eng.route_state.aw_health))
        if eng.collect_load:
            eng.cache, load = eng._prefill_chunk(
                eng.params, jnp.asarray(toks), jnp.asarray(pos), eng.cache,
                rs_pre, capacity=eng.prefill_capacity(real), with_load=True)
            eng.note_dispatch_load(load)
        else:
            eng.cache = eng._prefill_chunk(
                eng.params, jnp.asarray(toks), jnp.asarray(pos), eng.cache,
                rs_pre, capacity=eng.prefill_capacity(real))

        self.stats.calls += 1
        self.stats.chunks += len(entries)
        self.stats.real_tokens += real
        self.stats.launched_tokens += rows * shape
        if shape not in self.stats.shapes:
            self.stats.shapes.append(shape)

        for job, take in entries:
            r = eng.requests[job.rid]
            c = r.prefill_cursor
            self._checkpoint_chunk(job, c, take, shape)
            r.prefill_cursor = c + take
            eng.aws[job.aw].prefills[job.rid] = r.prefill_cursor
            self.stats.prefilled_tokens[job.rid] = \
                self.stats.prefilled_tokens.get(job.rid, 0) + take
            if eng.telemetry is not None:
                eng.telemetry.on_prefill_chunk(job.rid, now, take, shape)
            if eng.flightrec is not None:
                eng.flightrec.on_chunk(job.rid, now, take, shape, c)
            if r.prefill_cursor >= job.n_pre:
                del self.jobs[job.rid]
                eng.aws[job.aw].prefills.pop(job.rid, None)
                self._finalize(r)
        return real

    def _checkpoint_chunk(self, job: _PrefillJob, start: int, take: int,
                          shape: int):
        """Stream the chunk's KV segments through the bulk path. The
        extractor's static count is the chunk *shape* (bounding jit keys);
        the real ``take`` segments are sliced out host-side."""
        eng = self.engine
        if not eng.ecfg.checkpoint:
            return
        sc = eng.ecfg.max_seq
        base = min(start, sc - shape)          # keep the slice in bounds
        seg_stack = [np.asarray(a)[start - base:start - base + take]
                     for a in self._extract_range(eng.cache, job.slot, base,
                                                  count=shape)]
        token_values = job.prompt[start + 1:start + take + 1]
        eng._ck_range(eng.aws[job.aw].checkpointer,
                      job.rid, start, seg_stack, list(token_values))

    def _finalize(self, r):
        """Prefill complete: hand the request to the decode plane. Like
        the padded whole-prompt scheme, the prompt's last token rides the
        next decode step, which emits the first generated token."""
        n = len(r.prompt)
        r.prefilling = False
        r.pos = n - 1
        r.next_input = int(r.prompt[-1])
        eng = self.engine
        if eng.telemetry is not None:
            eng.telemetry.on_prefill_done(r.rid, eng.telemetry.now)
