"""Pallas TPU chunked selective state-space (Mamba2/SSD) scan kernel.

TPU adaptation of the SSD "chunked" algorithm: the GPU version leans on warp
shuffles for the intra-chunk scan; on TPU we recast both intra-chunk and
inter-chunk work as MXU matmuls over [T, T] / [T, N] tiles and carry the
[P, N] state across chunks in a VMEM scratch buffer (the chunk axis is the
innermost, sequential grid dimension).

Per (batch, head, chunk) with chunk length T:
  seg[i]   = cumsum(dt * a)[i]                      (log-decay within chunk)
  L[i,j]   = exp(seg[i] - seg[j]) * (i >= j)        (decay matrix)
  y_intra  = ((C B^T) ∘ L ∘ dt[j]) @ x              [T,P]
  y_state  = (C @ h_in^T) * exp(seg[i])             [T,P]
  h_out    = exp(seg[T-1]) h_in + x^T (dt exp(seg[T-1]-seg)) B   [P,N]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
                *, chunk: int):
    ci = pl.program_id(2)
    nchunks = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0]                                     # scalar (this head)
    x = x_ref[0, :, 0].astype(jnp.float32)           # [T, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [T]
    bmat = b_ref[0].astype(jnp.float32)              # [T, N]
    cmat = c_ref[0].astype(jnp.float32)              # [T, N]

    seg = jnp.cumsum(dt) * a                         # [T] (a constant/head)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask in log space: exp of a positive (j > i) decay overflows to inf
    # before the causal zeroing (inf * 0 = NaN)
    diff = jnp.where(ii >= jj, seg[:, None] - seg[None, :], -jnp.inf)
    ldec = jnp.exp(diff)

    g = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [T,T]
    w = g * ldec * dt[None, :]
    y_intra = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    h_in = h_scr[...]                                # [P, N]
    y_state = jax.lax.dot_general(cmat, h_in, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_state = y_state * jnp.exp(seg)[:, None]
    y_ref[0, :, 0] = (y_intra + y_state).astype(y_ref.dtype)

    seg_total = seg[-1]
    carry_w = dt * jnp.exp(seg_total - seg)          # [T]
    dh = jax.lax.dot_general(x * carry_w[:, None], bmat,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [P, N]
    h_new = jnp.exp(seg_total) * h_in + dh
    h_scr[...] = h_new

    @pl.when(ci == nchunks - 1)
    def _finalize():
        hout_ref[0, 0] = h_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(x, dt, a, b, c, *, chunk: int = 64, interpret: bool = False):
    """Chunk-parallel SSD scan. Same contract as ``ref.ssm_scan_ref`` with
    h0 = 0. x: [B,S,H,P]; dt: [B,S,H]; a: [H]; b,c: [B,S,N]."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    t = min(chunk, s)
    while s % t:
        t //= 2
    t = max(t, 1)

    grid = (bs, h, s // t)
    kernel = functools.partial(_ssm_kernel, chunk=t)
    y, hf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, t, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, t, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, t, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, t, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bs, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), x, dt, b, c)
    return y, hf
