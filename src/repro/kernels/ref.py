"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for kernel tests (assert_allclose, interpret=True)
and the CPU execution path of the framework.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# decode attention (GQA, one query token vs cached K/V + current token)
# --------------------------------------------------------------------------

def decode_attention_partial_ref(q, ck, cv, cpos, pos, *, window: int = 0,
                                 softcap: float = 0.0):
    """Online-softmax partials of q against the cache (pure jnp).

    §Perf iteration 3: every reduction here contracts over the cache
    sequence axis (max / sum / dot), so when the KV cache is seq-sharded
    (long_500k) GSPMD lowers to small psum-combines instead of gathering
    the cache — the distributed flash-decode pattern. The current token is
    folded in afterwards (ops.combine_decode_partials), never concatenated
    along the sharded axis.
    Returns (m [B,Hkv,G], l [B,Hkv,G], acc [B,Hkv,G,Dh]) fp32.
    """
    b, h, dh = q.shape
    hkv = ck.shape[2]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qs = q.reshape(b, hkv, g, dh).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bshd->bhgs", qs, ck.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = (cpos >= 0) & (cpos <= pos[:, None])
    if window:
        mask &= cpos > (pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, cv.astype(jnp.float32))
    return m, l, acc


def decode_attention_ref(q, ck, cv, cpos, k1, v1, pos, *, window: int = 0,
                         softcap: float = 0.0):
    """q: [B,H,Dh]; ck/cv: [B,Sc,Hkv,Dh]; cpos: [B,Sc]; k1/v1: [B,Hkv,Dh];
    pos: [B]. Returns [B,H,Dh] (fp32 accumulate, cast back to q.dtype).
    """
    b, h, dh = q.shape
    hkv = ck.shape[2]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qs = q.reshape(b, hkv, g, dh).astype(jnp.float32) * scale

    s = jnp.einsum("bhgd,bshd->bhgs", qs, ck.astype(jnp.float32))
    s_self = jnp.einsum("bhgd,bhd->bhg", qs, k1.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
        s_self = jnp.tanh(s_self / softcap) * softcap
    mask = (cpos >= 0) & (cpos <= pos[:, None])
    if window:
        mask &= cpos > (pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)

    s_all = jnp.concatenate([s, s_self[..., None]], axis=-1)
    p = jax.nn.softmax(s_all, axis=-1)
    v_all = jnp.concatenate(
        [cv.astype(jnp.float32),
         v1.astype(jnp.float32)[:, None]], axis=1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_all)
    return out.reshape(b, h, dh).astype(q.dtype)


# --------------------------------------------------------------------------
# grouped MoE expert FFN
# --------------------------------------------------------------------------

def moe_gemm_ref(x, w_gate, w_up, w_down, act: str = "silu"):
    """x: [P,...,D]; w_gate/w_up: [P,D,F]; w_down: [P,F,D] -> [P,...,D].

    SwiGLU-style gated FFN applied independently per expert slot, fp32
    accumulation. ``w_gate`` may be None for ungated FFNs. Ellipsis dims
    (e.g. the [G, C] of grouped dispatch) pass through untouched, keeping
    their sharding."""
    fn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    x32 = x.astype(jnp.float32)
    up = jnp.einsum("p...d,pdf->p...f", x32, w_up.astype(jnp.float32))
    if w_gate is not None:
        up = fn(jnp.einsum("p...d,pdf->p...f", x32,
                           w_gate.astype(jnp.float32))) * up
    else:
        up = fn(up)
    y = jnp.einsum("p...f,pfd->p...d", up, w_down.astype(jnp.float32))
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# Mamba2-style chunked selective state-space scan
# --------------------------------------------------------------------------

def ssm_scan_chunked_ref(x, dt, a, b, c, chunk: int = 64):
    """Chunk-parallel SSD (same math as kernels/ssm_scan.py, pure jnp).

    §Perf iteration 2: the sequential scan carries the [B,H,P,N] state
    through every timestep (HBM traffic ~ S * state bytes); the chunked
    form recasts intra-chunk work as [T,T]/[T,N] matmuls and carries state
    only once per chunk — S/chunk x less state traffic and MXU-shaped
    compute. Exact (not approximate); zero initial state.
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    t = min(chunk, s)
    while s % t:
        t //= 2
    nch = s // t

    xs = x.reshape(bs, nch, t, h, p).astype(jnp.float32)
    dts = dt.reshape(bs, nch, t, h).astype(jnp.float32)
    bm = b.reshape(bs, nch, t, n).astype(jnp.float32)
    cm = c.reshape(bs, nch, t, n).astype(jnp.float32)

    seg = jnp.cumsum(dts, axis=2) * a[None, None, None, :]  # [B,NC,T,H]
    ii = jnp.arange(t)
    causal = ii[:, None] >= ii[None, :]
    # intra-chunk: y_intra[i] = sum_j exp(seg_i - seg_j) dt_j (C_i.B_j) x_j
    # mask in LOG space: for j > i the difference is positive and exp()
    # overflows before the causal zeroing (inf * 0 = NaN)
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]    # [B,NC,T,T,H]
    diff = jnp.where(causal[None, None, :, :, None], diff, -jnp.inf)
    ldec = jnp.exp(diff)
    g = jnp.einsum("bgin,bgjn->bgij", cm, bm)               # [B,NC,T,T]
    w = g[..., None] * ldec * dts[:, :, None, :, :]         # [B,NC,T,T,H]
    y_intra = jnp.einsum("bgijh,bgjhp->bgihp", w, xs)

    # inter-chunk state carry (sequential over NC only)
    seg_tot = seg[:, :, -1, :]                              # [B,NC,H]
    carry_w = dts * jnp.exp(seg_tot[:, :, None, :] - seg)   # [B,NC,T,H]
    dh = jnp.einsum("bgth,bgthp,bgtn->bghpn", carry_w, xs, bm)

    def chunk_step(hstate, inp):
        dh_g, decay_g = inp                                  # [B,H,P,N],[B,H]
        h_out = hstate * jnp.exp(decay_g)[..., None, None] + dh_g
        return h_out, hstate                                 # emit h_in

    h0 = jnp.zeros((bs, h, p, n), jnp.float32)
    hf, h_ins = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(dh, 1, 0), jnp.moveaxis(seg_tot, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)                        # [B,NC,H,P,N]

    y_state = jnp.einsum("bgtn,bghpn->bgthp", cm, h_ins)
    y_state = y_state * jnp.exp(seg)[..., None]
    y = (y_intra + y_state).reshape(bs, s, h, p).astype(x.dtype)
    return y, hf


def ssm_scan_ref(x, dt, a, b, c, h0=None):
    """Sequential reference of the SSD recurrence.

    x:  [B,S,H,P]   per-head input
    dt: [B,S,H]     softplus'd step sizes (>0)
    a:  [H]         negative decay rates (A = -exp(a_log) outside; here a<0)
    b:  [B,S,N]     input projection (shared across heads, Mamba2 style)
    c:  [B,S,N]     output projection
    h0: [B,H,P,N]   initial state (zeros if None)
    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bs, h, p, n), jnp.float32)

    def step(hstate, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a)                         # [B,H]
        dbx = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        hstate = hstate * decay[..., None, None] + dbx
        yt = jnp.einsum("bhpn,bn->bhp", hstate, ct)
        return hstate, yt

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    hf, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    return y, hf
