"""Pallas TPU flash-decode kernel (GQA, one query token vs a long KV cache).

This is the latency-critical op of the decode phase (§2 of the paper: TBT is
the user-visible metric; decode dominates recovery concern). The kernel
streams the KV cache HBM->VMEM in blocks and keeps an online-softmax running
(m, l, acc) per (batch, kv-head) so live VMEM is O(block) regardless of the
32k/500k cache length.

Layout / tiling decisions (TPU-native, not a CUDA port):
  * grid = (B, Hkv, Sc // block_k); the kv-block axis is innermost, i.e. the
    sequential accumulation axis on TPU.
  * q block [G, Dh] (G = H/Hkv grouped queries) hits the MXU as a skinny
    matmul against [block_k, Dh] key tiles; Dh is padded to 128 by layout.
  * two variants share the block loop: ``decode_attention_partial`` emits
    the softmax partials (m, l, acc) for callers that combine externally
    (seq-sharded caches psum-combine them), and ``decode_attention_fused``
    — the serving decode step's kernel — keeps the partials in VMEM
    scratch and, on the last kv block, folds the current token's
    self-attention term and the final normalization in-kernel, so one
    pallas_call returns the finished [B,H,Dh] attention output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(pos_ref, q_ref, k_ref, v_ref, cpos_ref,
                        m_ref, l_ref, acc_ref,
                        *, window: int, softcap: float, block_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [G, Dh] (pre-scaled)
    k = k_ref[0, :, 0].astype(jnp.float32)       # [bk, Dh]
    v = v_ref[0, :, 0].astype(jnp.float32)       # [bk, Dh]
    cpos = cpos_ref[0]                           # [bk] int32
    pos = pos_ref[0]                             # scalar int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, bk]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = (cpos >= 0) & (cpos <= pos)
    if window:
        mask &= cpos > (pos - window)
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_ref[0, 0]                         # [G]
    l_prev = l_ref[0, 0]
    acc_prev = acc_ref[0, 0]                     # [G, Dh]

    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new
    acc_ref[0, 0] = acc_new


@functools.partial(jax.jit, static_argnames=("window", "softcap", "block_k",
                                             "interpret"))
def decode_attention_partial(q, ck, cv, cpos, pos, *, window: int = 0,
                             softcap: float = 0.0, block_k: int = 512,
                             interpret: bool = False):
    """Online-softmax partials of q against the KV cache.

    q: [B,H,Dh] (unscaled); ck/cv: [B,Sc,Hkv,Dh]; cpos: [B,Sc]; pos: [B].
    Returns (m, l, acc): [B,Hkv,G], [B,Hkv,G], [B,Hkv,G,Dh] — fp32.
    """
    b, h, dh = q.shape
    sc, hkv = ck.shape[1], ck.shape[2]
    g = h // hkv
    bk = min(block_k, sc)
    while sc % bk:
        bk //= 2
    bk = max(bk, 1)

    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qs = (q.astype(jnp.float32) * scale).reshape(b, hkv, g, dh)

    grid = (b, hkv, sc // bk)
    kernel = functools.partial(_decode_attn_kernel, window=window,
                               softcap=softcap, block_k=bk)
    m, l, acc = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ki: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk), lambda bi, hi, ki: (bi, ki)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ki: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(pos.astype(jnp.int32), qs, ck, cv, cpos)
    return m, l, acc


# --------------------------------------------------------------------------
# fused variant: cache partials + self-attention fold + normalize, one call
# --------------------------------------------------------------------------

def _decode_attn_fused_kernel(pos_ref, q_ref, k_ref, v_ref, cpos_ref,
                              k1_ref, v1_ref, o_ref,
                              m_ref, l_ref, acc_ref,
                              *, window: int, softcap: float, block_k: int,
                              nk: int):
    """Same online-softmax block loop as ``_decode_attn_kernel``, but the
    running (m, l, acc) live in VMEM scratch — persistent across the
    sequential kv-block axis — and the LAST block folds the current
    token's (k1, v1) contribution and writes the normalized output."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [G, Dh] (pre-scaled)
    k = k_ref[0, :, 0].astype(jnp.float32)       # [bk, Dh]
    v = v_ref[0, :, 0].astype(jnp.float32)       # [bk, Dh]
    cpos = cpos_ref[0]                           # [bk] int32
    pos = pos_ref[0]                             # scalar int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, bk]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = (cpos >= 0) & (cpos <= pos)
    if window:
        mask &= cpos > (pos - window)
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(ki == nk - 1)
    def _finalize():
        k1 = k1_ref[0, 0].astype(jnp.float32)    # [Dh]
        v1 = v1_ref[0, 0].astype(jnp.float32)    # [Dh]
        s_self = jax.lax.dot_general(
            q, k1, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # [G]
        if softcap:
            s_self = jnp.tanh(s_self / softcap) * softcap
        m_f = jnp.maximum(m_ref[...], s_self)
        corr_f = jnp.exp(m_ref[...] - m_f)
        p_self = jnp.exp(s_self - m_f)
        l_f = l_ref[...] * corr_f + p_self
        acc_f = acc_ref[...] * corr_f[:, None] + p_self[:, None] * v1[None]
        o_ref[0, 0] = acc_f / jnp.maximum(l_f[:, None], 1e-30)


# --------------------------------------------------------------------------
# paged variant: kv blocks gathered through a block table, one call
# --------------------------------------------------------------------------

def _decode_attn_paged_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref,
                              cpos_ref, k1_ref, v1_ref, o_ref,
                              m_ref, l_ref, acc_ref,
                              *, softcap: float, nk: int):
    """Fused decode-attention block loop over a PAGED cache: the kv-block
    grid axis walks the slot's block table (scalar-prefetched ``bt_ref``),
    and each block's index map resolves the physical page, so the pages
    stream HBM->VMEM in logical order without materializing a gathered
    copy. Unmapped blocks resolve to the null page whose positions are all
    -1 — they mask to an exact no-op, identical to an empty contiguous
    region. Math and accumulation order match ``_decode_attn_fused_kernel``
    with block_k == page_tokens, so the paged and contiguous kernels are
    bit-identical on identical logical content."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [G, Dh] (pre-scaled)
    k = k_ref[0, :, 0].astype(jnp.float32)       # [pt, Dh]
    v = v_ref[0, :, 0].astype(jnp.float32)       # [pt, Dh]
    cpos = cpos_ref[0]                           # [pt] int32
    pos = pos_ref[0]                             # scalar int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, pt]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = (cpos >= 0) & (cpos <= pos)
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(ki == nk - 1)
    def _finalize():
        k1 = k1_ref[0, 0].astype(jnp.float32)    # [Dh]
        v1 = v1_ref[0, 0].astype(jnp.float32)    # [Dh]
        s_self = jax.lax.dot_general(
            q, k1, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # [G]
        if softcap:
            s_self = jnp.tanh(s_self / softcap) * softcap
        m_f = jnp.maximum(m_ref[...], s_self)
        corr_f = jnp.exp(m_ref[...] - m_f)
        p_self = jnp.exp(s_self - m_f)
        l_f = l_ref[...] * corr_f + p_self
        acc_f = acc_ref[...] * corr_f[:, None] + p_self[:, None] * v1[None]
        o_ref[0, 0] = acc_f / jnp.maximum(l_f[:, None], 1e-30)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def decode_attention_paged(q, pk, pv, ppos, bt, k1, v1, pos, *,
                           softcap: float = 0.0, interpret: bool = False):
    """Fused GQA decode attention over a paged KV cache.

    q: [B,H,Dh] (unscaled); pk/pv: [P,pt,Hkv,Dh] physical page pools;
    ppos: [P,pt] stored positions (-1 = empty); bt: [B,nblk] int32 block
    table (0 = the reserved null page); k1/v1: [B,Hkv,Dh]; pos: [B].
    Full attention only (paged mode has no sliding-window layers).
    Returns [B,H,Dh] in q's dtype.
    """
    b, h, dh = q.shape
    pt, hkv = pk.shape[1], pk.shape[2]
    nk = bt.shape[1]
    g = h // hkv

    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qs = (q.astype(jnp.float32) * scale).reshape(b, hkv, g, dh)

    kernel = functools.partial(_decode_attn_paged_kernel, softcap=softcap,
                               nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ki, bt_ref: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh),
                         lambda bi, hi, ki, bt_ref: (bi, hi, 0, 0)),
            pl.BlockSpec((1, pt, 1, dh),
                         lambda bi, hi, ki, bt_ref:
                         (bt_ref[bi, ki], 0, hi, 0)),
            pl.BlockSpec((1, pt, 1, dh),
                         lambda bi, hi, ki, bt_ref:
                         (bt_ref[bi, ki], 0, hi, 0)),
            pl.BlockSpec((1, pt),
                         lambda bi, hi, ki, bt_ref: (bt_ref[bi, ki], 0)),
            pl.BlockSpec((1, 1, dh),
                         lambda bi, hi, ki, bt_ref: (bi, hi, 0)),
            pl.BlockSpec((1, 1, dh),
                         lambda bi, hi, ki, bt_ref: (bi, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bi, hi, ki, bt_ref: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),        # running max m
            pltpu.VMEM((g,), jnp.float32),        # running denom l
            pltpu.VMEM((g, dh), jnp.float32),     # running numerator acc
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), jnp.float32),
        interpret=interpret,
    )(bt.astype(jnp.int32), pos.astype(jnp.int32), qs, pk, pv, ppos, k1, v1)
    return out.reshape(b, h, dh).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "block_k",
                                             "interpret"))
def decode_attention_fused(q, ck, cv, cpos, k1, v1, pos, *, window: int = 0,
                           softcap: float = 0.0, block_k: int = 512,
                           interpret: bool = False):
    """Fully fused GQA decode attention: cache blocks + the current token's
    self-attention + normalization in ONE pallas_call.

    q: [B,H,Dh] (unscaled); ck/cv: [B,Sc,Hkv,Dh]; cpos: [B,Sc];
    k1/v1: [B,Hkv,Dh]; pos: [B]. Returns [B,H,Dh] in q's dtype.
    """
    b, h, dh = q.shape
    sc, hkv = ck.shape[1], ck.shape[2]
    g = h // hkv
    bk = min(block_k, sc)
    while sc % bk:
        bk //= 2
    bk = max(bk, 1)
    nk = sc // bk

    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qs = (q.astype(jnp.float32) * scale).reshape(b, hkv, g, dh)

    kernel = functools.partial(_decode_attn_fused_kernel, window=window,
                               softcap=softcap, block_k=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ki: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk), lambda bi, hi, ki: (bi, ki)),
            pl.BlockSpec((1, 1, dh), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, 1, dh), lambda bi, hi, ki: (bi, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),        # running max m
            pltpu.VMEM((g,), jnp.float32),        # running denom l
            pltpu.VMEM((g, dh), jnp.float32),     # running numerator acc
        ],
        interpret=interpret,
    )(pos.astype(jnp.int32), qs, ck, cv, cpos, k1, v1)
    return out.reshape(b, h, dh).astype(q.dtype)
