"""Pallas TPU flash attention for the full-sequence (train/prefill) path.

Closes the dominant §Roofline headroom: the pure-jnp blockwise path
materializes [bq, bk] score tiles in HBM; this kernel keeps the online-
softmax state (m, l, acc) in VMEM scratch across the (sequential, innermost)
kv-block grid axis, so scores never leave VMEM.

Grid = (B, Hkv, Sq//bq, Sk//bk) — kv innermost, q-block output revisited.
Supports GQA (q block [bq, G, Dh] vs kv [bk, Dh]), causal masking, sliding
windows and score softcap via position operands (same mask semantics as
``models.attention.blockwise_attention``, its oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, causal: bool, window: int, softcap: float):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0].astype(jnp.float32)       # [bq, G, Dh] (pre-scaled)
    k = k_ref[0, :, 0].astype(jnp.float32)       # [bk, Dh]
    v = v_ref[0, :, 0].astype(jnp.float32)       # [bk, Dh]
    qpos = qpos_ref[0]                           # [bq]
    kpos = kpos_ref[0]                           # [bk]

    bq, g, dh = q.shape
    s = jax.lax.dot_general(q.reshape(bq * g, dh), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(bq, g, -1)                     # [bq, G, bk]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = kpos[None, :] >= 0
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[:, None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask[:, None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p.reshape(bq * g, -1), v,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_new = acc_prev * corr[..., None] + pv.reshape(bq, g, dh)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ki == nk - 1)
    def _finalize():
        out = acc_new / jnp.maximum(l_new[..., None], 1e-30)
        out = jnp.where((l_new > 0)[..., None], out, 0.0)
        o_ref[0, :, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, q_pos, k_pos, *, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q: [B,Sq,H,Dh]; k,v: [B,Sk,Hkv,Dh]; *_pos: [B,Sq]/[B,Sk] int32
    (-1 = invalid). Returns [B,Sq,H,Dh]."""
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv

    def fit(block, s):
        blk = min(block, s)
        while s % blk:
            blk //= 2
        return max(blk, 1)

    bq, bk = fit(block_q, sq), fit(block_k, sk)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qs = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, dh)
    qs = qs.astype(q.dtype)

    grid = (b, hkv, sq // bq, sk // bk)
    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, g, dh),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda bi, hi, qi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda bi, hi, qi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bq), lambda bi, hi, qi, ki: (bi, qi)),
            pl.BlockSpec((1, bk), lambda bi, hi, qi, ki: (bi, ki)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, g, dh),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, hkv, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, g), jnp.float32),
            pltpu.VMEM((bq, g), jnp.float32),
            pltpu.VMEM((bq, g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qs, k, v, q_pos.astype(jnp.int32), k_pos.astype(jnp.int32))
    return out.reshape(b, sq, h, dh)
