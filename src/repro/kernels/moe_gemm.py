"""Pallas TPU grouped MoE expert-FFN kernel.

Computes, independently per expert slot p:
    y[p] = (act(x[p] @ w_gate[p]) * (x[p] @ w_up[p])) @ w_down[p]

This is the EW-side hot loop (App. B of the paper: expert GEMM efficiency vs
batch size is what motivates layer-wise batching). TPU-native tiling:

  * grid = (P, C // block_c, F // block_f); the ff-tile axis is innermost and
    accumulates into the output block (output index map ignores the f axis,
    so the block is revisited and we += across f tiles).
  * every matmul tile is MXU-shaped: [block_c, D] @ [D, block_f] and
    [block_c, block_f] @ [block_f, D], with block_c/block_f multiples of 128
    when the shapes allow.
  * the gate/up intermediate only ever exists as a [block_c, block_f] VMEM
    tile — the full [C, F] hidden activation is never materialized.

Empty slots (shadow experts with zero routed tokens) contribute zero compute
*work* on real hardware via the zero one-hot rows — the kernel itself is
shape-static, matching the dry-run FLOP accounting discussed in DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_ffn_kernel(counts_ref, x_ref, wg_ref, wu_ref, wd_ref, y_ref,
                    *, act: str, gated: bool):
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    # Inactive shadow / padded slots receive zero routed tokens: skip their
    # MXU work entirely (the paper's "shadows consume no compute", §5.3 /
    # App. D). counts is scalar-prefetched per slot.
    pi = pl.program_id(0)

    @pl.when(counts_ref[pi] > 0)
    def _compute():
        _moe_ffn_body(x_ref, wg_ref, wu_ref, wd_ref, y_ref, act=act,
                      gated=gated)


def _moe_ffn_body(x_ref, wg_ref, wu_ref, wd_ref, y_ref, *, act: str,
                  gated: bool):
    x = x_ref[0].astype(jnp.float32)             # [bc, D]
    wu = wu_ref[0].astype(jnp.float32)           # [D, bf]
    up = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    fn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    if gated:
        wg = wg_ref[0].astype(jnp.float32)
        gate = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        hidden = fn(gate) * up
    else:
        hidden = fn(up)
    wd = wd_ref[0].astype(jnp.float32)           # [bf, D]
    y_ref[0] += jax.lax.dot_general(hidden, wd, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("act", "block_c", "block_f",
                                             "interpret"))
def moe_gemm(x, w_gate, w_up, w_down, *, counts=None, act: str = "silu",
             block_c: int = 128, block_f: int = 512,
             interpret: bool = False):
    """x: [P,C,D]; w_gate/w_up: [P,D,F]; w_down: [P,F,D] -> y [P,C,D].

    ``counts`` [P] int32: routed tokens per slot — slots with 0 skip all
    compute (inactive shadows / pad slots). None = assume all active."""
    p_slots, c, d = x.shape
    f = w_up.shape[-1]
    if counts is None:
        counts = jnp.ones((p_slots,), jnp.int32)
    bc = min(block_c, c)
    while c % bc:
        bc //= 2
    bc = max(bc, 1)
    bf = min(block_f, f)
    while f % bf:
        bf //= 2
    bf = max(bf, 1)

    gated = w_gate is not None
    kernel = functools.partial(_moe_ffn_kernel, act=act, gated=gated)
    if not gated:
        w_gate = w_up  # placeholder operand, never read

    grid = (p_slots, c // bc, f // bf)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # counts [P]
            pl.BlockSpec((1, bc, d), lambda pi, ci, fi: (pi, ci, 0)),
            pl.BlockSpec((1, d, bf), lambda pi, ci, fi: (pi, 0, fi)),
            pl.BlockSpec((1, d, bf), lambda pi, ci, fi: (pi, 0, fi)),
            pl.BlockSpec((1, bf, d), lambda pi, ci, fi: (pi, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda pi, ci, fi: (pi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((p_slots, c, d), jnp.float32),
        interpret=interpret,
    )(counts.astype(jnp.int32), x, w_gate, w_up, w_down)
    return y.astype(x.dtype)
