"""Jitted public wrappers around the Pallas kernels with pure-jnp fallbacks.

Dispatch policy:
  * TPU backend        -> Pallas kernels (compiled).
  * CPU (this container) -> jnp reference path by default (fast, exact);
    tests exercise the Pallas bodies via interpret=True explicitly.
  * ``REPRO_KERNELS=pallas_interpret`` forces interpret-mode Pallas everywhere
    (used by the kernel smoke suite / CI).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref


def _mode() -> str:
    env = os.environ.get("REPRO_KERNELS", "auto")
    if env != "auto":
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def use_pallas() -> bool:
    return _mode() in ("pallas", "pallas_interpret")


def _interpret() -> bool:
    return _mode() == "pallas_interpret"


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------

def combine_decode_partials(q, m, l, acc, k1, v1, *, softcap: float = 0.0):
    """Fold the current token's self-attention into cache partials (m,l,acc)
    and normalize. q: [B,H,Dh]; k1/v1: [B,Hkv,Dh]."""
    b, h, dh = q.shape
    hkv = k1.shape[1]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qs = (q.astype(jnp.float32) * scale).reshape(b, hkv, g, dh)
    s_self = jnp.einsum("bhgd,bhd->bhg", qs, k1.astype(jnp.float32))
    if softcap:
        s_self = jnp.tanh(s_self / softcap) * softcap
    m_new = jnp.maximum(m, s_self)
    corr = jnp.exp(m - m_new)
    p_self = jnp.exp(s_self - m_new)
    l_new = l * corr + p_self
    acc_new = acc * corr[..., None] + p_self[..., None] * \
        v1.astype(jnp.float32)[:, :, None, :]
    out = acc_new / jnp.maximum(l_new[..., None], 1e-30)
    return out.reshape(b, h, dh).astype(q.dtype)


def decode_attention(q, ck, cv, cpos, k1, v1, pos, *, window: int = 0,
                     softcap: float = 0.0):
    """Single-token GQA decode attention over cache + current token.

    q: [B,H,Dh]; ck/cv: [B,Sc,Hkv,Dh]; cpos: [B,Sc]; k1/v1: [B,Hkv,Dh];
    pos: [B]. Returns [B,H,Dh].
    """
    if use_pallas():
        # fused variant: self-attention fold + normalize happen in-kernel,
        # so the decode step is ONE pallas_call (no separate combine HLO)
        from repro.kernels.decode_attention import decode_attention_fused
        return decode_attention_fused(
            q, ck, cv, cpos, k1, v1, pos, window=window, softcap=softcap,
            interpret=_interpret())
    else:
        # partial+combine (not monolithic softmax): keeps every reduction
        # contracting over the cache axis so seq-sharded caches lower to
        # psum-combines (§Perf iteration 3 / distributed flash-decode)
        m, l, acc = kref.decode_attention_partial_ref(
            q, ck, cv, cpos, pos, window=window, softcap=softcap)
    return combine_decode_partials(q, m, l, acc, k1, v1, softcap=softcap)


def decode_attention_paged(q, pk, pv, ppos, bt, k1, v1, pos, *,
                           softcap: float = 0.0):
    """Single-token GQA decode attention over a PAGED cache + current token.

    q: [B,H,Dh]; pk/pv: [P,pt,Hkv,Dh] page pools; ppos: [P,pt];
    bt: [B,nblk] block table (page 0 = reserved null page, pos all -1);
    k1/v1: [B,Hkv,Dh]; pos: [B]. Returns [B,H,Dh]. Full attention only.

    On TPU the Pallas kernel walks the block table inside the pallas_call
    (the kv-block grid axis indexes physical pages); elsewhere the pages
    are gathered into the contiguous view and the exact same reference
    partial+combine runs, so paged and contiguous engines produce
    bit-identical floats on every backend.
    """
    if use_pallas():
        from repro.kernels.decode_attention import (
            decode_attention_paged as paged_kernel)
        return paged_kernel(q, pk, pv, ppos, bt, k1, v1, pos,
                            softcap=softcap, interpret=_interpret())
    b, nblk = bt.shape
    pt = pk.shape[1]
    flat = bt.reshape(-1)
    ck = pk[flat].reshape(b, nblk * pt, *pk.shape[2:])
    cv = pv[flat].reshape(b, nblk * pt, *pv.shape[2:])
    cpos = ppos[flat].reshape(b, nblk * pt)
    m, l, acc = kref.decode_attention_partial_ref(
        q, ck, cv, cpos, pos, window=0, softcap=softcap)
    return combine_decode_partials(q, m, l, acc, k1, v1, softcap=softcap)


def full_attention(q, k, v, q_pos, k_pos, *, causal: bool = True,
                   window: int = 0, softcap: float = 0.0,
                   block_k: int = 0):
    """Full-sequence (train/prefill) attention: Pallas flash kernel on TPU
    (scores stay in VMEM), blockwise-jnp elsewhere. ``block_k`` pins the
    KV block size of the online softmax (0 = auto): prefill/chunk callers
    use it to keep the accumulation order — and hence the float result —
    independent of the padded KV extent. The Pallas kernel has its own
    fixed tiling (already extent-independent)."""
    if use_pallas():
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, q_pos, k_pos, causal=causal,
                               window=window, softcap=softcap,
                               interpret=_interpret())
    from repro.models.attention import blockwise_attention
    return blockwise_attention(q, k, v, q_pos, k_pos, window=window,
                               softcap=softcap, causal=causal,
                               block_k=block_k)


# --------------------------------------------------------------------------
# grouped expert FFN
# --------------------------------------------------------------------------

def expert_ffn(x, w_gate, w_up, w_down, *, act: str = "silu", counts=None):
    """x: [P,...,D] per-slot token batches -> [P,...,D]."""
    if use_pallas():
        from repro.kernels.moe_gemm import moe_gemm
        shape = x.shape
        if x.ndim > 3:  # flatten grouped dims for the kernel grid
            x = x.reshape(shape[0], -1, shape[-1])
        y = moe_gemm(x, w_gate, w_up, w_down, act=act, counts=counts,
                     interpret=_interpret())
        return y.reshape(shape)
    return kref.moe_gemm_ref(x, w_gate, w_up, w_down, act=act)


# --------------------------------------------------------------------------
# SSM scan
# --------------------------------------------------------------------------

def ssm_scan(x, dt, a, b, c, *, chunk: int = 64):
    """Full-sequence SSD scan (zero initial state). The chunk-parallel form
    is used on every backend (§Perf iteration 2): per-timestep state carry
    is S/chunk x more HBM traffic and no MXU work."""
    if use_pallas():
        from repro.kernels.ssm_scan import ssm_scan as pallas_scan
        return pallas_scan(x, dt, a, b, c, chunk=chunk,
                           interpret=_interpret())
    if x.shape[1] > 1:
        return kref.ssm_scan_chunked_ref(x, dt, a, b, c, chunk=chunk)
    return kref.ssm_scan_ref(x, dt, a, b, c)
