"""Quickstart: serve a small MoE model with Tarragon resilience enabled.

    PYTHONPATH=src python examples/quickstart.py [--arch mixtral_8x7b]

Builds a reduced-size variant of the chosen architecture, starts the
inference engine (2 AWs x 2 EWs), submits a few typed requests, and
decodes with incremental KV checkpointing on. This is the smallest
end-to-end use of the public API:
ModelConfig -> InferenceEngine -> client.submit(RequestSpec) ->
RequestHandle (status / streaming / cancel) -> step.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.serving.api import RequestSpec
from repro.serving.engine import EngineConfig, InferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x7b")
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.moe.enabled:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    print(f"model: {cfg.name} ({cfg.param_count/1e6:.1f}M params reduced)")

    ecfg = EngineConfig(max_batch=8, max_seq=96, num_aw=2, num_ew=2,
                        tarragon=True, checkpoint=True)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    handles = []
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=(8,)).astype(np.int32)
        # classes: "interactive" preempts, "batch" is preemptible
        h = eng.client.submit(RequestSpec(
            rid=f"req{i}", prompt=prompt, max_new=args.tokens,
            slo_class="standard"))
        handles.append(h)
        print(f"{h.rid}: {h.state()} on AW{eng.requests[h.rid].aw}")

    while not all(h.done() for h in handles):
        eng.step()

    for h in handles:
        print(f"{h.rid}: {h.status().tokens_generated} tokens -> "
              f"{h.tokens()[:8]}...")
        eng.release_request(h.rid)  # teardown closes the lifecycle span
    st = eng.store.stats
    print(f"checkpoint store: {st.updates} segment writes, "
          f"{st.bytes_written/1024:.1f} KiB")

    # telemetry is on by default: stream percentiles without per-request
    # lists, and export a Perfetto trace of every request's lifecycle.
    # Open the file at https://ui.perfetto.dev (or chrome://tracing).
    tel = eng.telemetry
    snap = tel.snapshot()
    qd = snap["histograms"]["queue_delay"]
    print(f"telemetry: {snap['counters'].get('requests.released', 0)} "
          f"requests released, queue delay p50={qd['p50']*1e3:.1f}ms "
          f"p99={qd['p99']*1e3:.1f}ms ({qd['count']} obs)")
    tel.export_chrome("quickstart_trace.json")
    print("wrote quickstart_trace.json (load in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
