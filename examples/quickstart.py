"""Quickstart: serve a small MoE model with Tarragon resilience enabled.

    PYTHONPATH=src python examples/quickstart.py [--arch mixtral_8x7b]

Builds a reduced-size variant of the chosen architecture, starts the
inference engine (2 AWs x 2 EWs), submits a few requests, and decodes with
incremental KV checkpointing on. This is the smallest end-to-end use of the
public API: ModelConfig -> InferenceEngine -> submit/step.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.serving.engine import EngineConfig, InferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x7b")
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.moe.enabled:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    print(f"model: {cfg.name} ({cfg.param_count/1e6:.1f}M params reduced)")

    ecfg = EngineConfig(max_batch=8, max_seq=96, num_aw=2, num_ew=2,
                        tarragon=True, checkpoint=True)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=(8,)).astype(np.int32)
        eng.submit(f"req{i}", prompt, args.tokens)
        print(f"req{i}: submitted on AW{eng.requests[f'req{i}'].aw}")

    while eng.active_requests():
        eng.step()

    for i in range(args.requests):
        r = eng.requests[f"req{i}"]
        print(f"req{i}: {len(r.tokens)} tokens -> {r.tokens[:8]}...")
    st = eng.store.stats
    print(f"checkpoint store: {st.updates} segment writes, "
          f"{st.bytes_written/1024:.1f} KiB")


if __name__ == "__main__":
    main()
