"""End-to-end training example: train a ~100M-param dense model for a few
hundred steps on synthetic LM data and verify the loss goes down.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]

``--small`` uses the reduced config (seconds on CPU); the default builds a
~100M-parameter qwen2-family variant (minutes on CPU).
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.workloads import lm_batches
from repro.models import get_model
from repro.training import init_opt_state, make_train_step


def hundred_m_config():
    base = get_config("qwen2_1_5b")
    return dataclasses.replace(
        base, name="qwen2-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=32000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--small", action="store_true")
    args = ap.parse_args()

    cfg = get_config("qwen2_1_5b").reduced() if args.small \
        else hundred_m_config()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    rs = api.init_route_state()
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(api, lr=3e-4))

    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n/1e6:.1f}M params")

    t0 = time.time()
    first = last = None
    for i, batch in enumerate(lm_batches(cfg.vocab_size, args.batch,
                                         args.seq, args.steps, seed=0)):
        params, opt, loss = step_fn(params, opt, batch, rs)
        loss = float(loss)
        first = first if first is not None else loss
        last = loss
        if (i + 1) % 20 == 0:
            print(f"step {i+1:4d}  loss {loss:.4f}  "
                  f"{(time.time()-t0)/(i+1)*1e3:.0f} ms/step")
    print(f"loss: {first:.4f} -> {last:.4f}")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
