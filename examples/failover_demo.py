"""Failover demo (paper §7.2 at functional scale) on the layered serving
stack: inject an EW failure and an AW failure mid-decode and show that the
token streams are EXACTLY the ones a failure-free run produces —
shadow-expert rerouting and per-request KV restoration are lossless.

The demo drives the layers explicitly: requests enter through the Gateway's
FIFO queue, the ContinuousBatchScheduler prefills them in one bucketed
batch, and failures are worker methods whose blast radius is the worker's
own state.

    PYTHONPATH=src python examples/failover_demo.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.orchestrator import Orchestrator
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPTS = [np.arange(1, 9, dtype=np.int32),
           np.arange(3, 14, dtype=np.int32),
           np.arange(5, 11, dtype=np.int32)]
N_NEW = 16


def build(policy="least_loaded"):
    cfg = get_config("mixtral_8x7b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    ecfg = EngineConfig(max_batch=8, max_seq=64, num_aw=2, num_ew=2,
                        placement=policy)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(7))


def admit_all(eng, now=0.0):
    for i, p in enumerate(PROMPTS):
        eng.gateway.enqueue(f"req-{i}", p, N_NEW, now=now)
    eng.scheduler.admit(now)
    st = eng.scheduler.stats
    print(f"  admitted {st.requests} requests in {st.calls} batched "
          f"prefill call(s), occupancy={st.occupancy():.2f}")
    for i in range(len(PROMPTS)):
        r = eng.requests[f"req-{i}"]
        print(f"    req-{i} -> AW{r.aw} slot {r.slot}")


def decode_all(eng):
    while eng.active_requests():
        eng.step()
    return {r.rid: r.tokens for r in eng.requests.values()}


def main():
    print("=== reference (no failure) ===")
    eng = build()
    admit_all(eng)
    ref = decode_all(eng)
    print("tokens:", {k: v[:6] for k, v in sorted(ref.items())}, "...")

    print("\n=== EW failure at step 5 -> shadow-expert failover ===")
    eng = build()
    admit_all(eng)
    for _ in range(5):
        eng.step()
    print("killing EW0 (its experts are pre-loaded as shadows on EW1):",
          eng.ews[0])
    eng.fail_ew(0)
    print("after fail:", eng.ews[0])
    out = decode_all(eng)
    print("exact match:", out == ref)

    print("\n=== AW failure at step 5 -> per-request KV restoration ===")
    eng = build()
    orch = Orchestrator(eng, worker_init_time=2.0)
    admit_all(eng)
    for _ in range(5):
        eng.step()
    victims = [r.rid for r in eng.requests.values() if r.aw == 0]
    print(f"requests {victims} live on {eng.aws[0]}; killing it")
    orch.inject_failure("aw", 0, now=1.0)
    orch.tick(1.0 + orch.detection_latency())
    for rid in victims:
        r = eng.requests[rid]
        print(f"  {rid} restored onto AW{r.aw} (slot {r.slot})")
    print(f"  {eng.store.stats.bytes_restored}B restored; "
          f"gateway requeued={eng.gateway.stats.requeued}")
    out = decode_all(eng)
    print("exact match:", out == ref)
    orch.tick(5.0)
    print("events:", [(round(e.t, 2), e.kind, e.worker) for e in orch.events])

    print("\n=== session-affinity placement (same session -> same AW) ===")
    eng = build(policy="session_affinity")
    for i in range(3):
        eng.gateway.enqueue(f"sess42-{i}", PROMPTS[i], 4, now=0.0)
    eng.scheduler.admit(0.0)
    print("placements:", {r.rid: r.aw for r in eng.requests.values()})


if __name__ == "__main__":
    main()
