"""Failover demo (paper §7.2 at functional scale): inject an EW failure and
an AW failure mid-decode and show that the token streams are EXACTLY the
ones a failure-free run produces — shadow-expert rerouting and per-request
KV restoration are lossless.

    PYTHONPATH=src python examples/failover_demo.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.orchestrator import Orchestrator
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPT = np.arange(1, 9, dtype=np.int32)
N_NEW = 16


def build():
    cfg = get_config("mixtral_8x7b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    ecfg = EngineConfig(max_batch=8, max_seq=64, num_aw=2, num_ew=2)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(7))


def main():
    print("=== reference (no failure) ===")
    ref = build().generate("r", PROMPT, N_NEW)
    print("tokens:", ref)

    print("\n=== EW failure at step 5 -> shadow-expert failover ===")
    eng = build()
    eng.submit("r", PROMPT, N_NEW)
    for _ in range(5):
        eng.step()
    print("killing EW0 (its experts are pre-loaded as shadows on EW1)")
    eng.fail_ew(0)
    while not eng.requests["r"].done:
        eng.step()
    print("tokens:", eng.requests["r"].tokens)
    print("exact match:", eng.requests["r"].tokens == ref)

    print("\n=== AW failure at step 5 -> per-request KV restoration ===")
    eng = build()
    orch = Orchestrator(eng, worker_init_time=2.0)
    eng.submit("r", PROMPT, N_NEW)
    for _ in range(5):
        eng.step()
    print(f"request lives on AW{eng.requests['r'].aw}; killing it")
    orch.inject_failure("aw", 0, now=1.0)
    orch.tick(1.0 + orch.detection_latency())
    print(f"restored onto AW{eng.requests['r'].aw} "
          f"(slot {eng.requests['r'].slot}); "
          f"{eng.store.stats.bytes_restored}B restored")
    while not eng.requests["r"].done:
        eng.step()
    print("tokens:", eng.requests["r"].tokens)
    print("exact match:", eng.requests["r"].tokens == ref)
    orch.tick(5.0)
    print("events:", [(round(e.t, 2), e.kind, e.worker) for e in orch.events])


if __name__ == "__main__":
    main()
