"""End-to-end serving driver: Poisson request stream against the engine,
with an orchestrator handling a mid-run EW failure (paper Fig. 9 shape, at
functional CPU scale). Reports TTFT/TBT/throughput before/after failure.

    PYTHONPATH=src python examples/serve_workload.py --workload random \
        --rps 4 --fail-at 0.5
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.core.orchestrator import Orchestrator
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import FailurePlan, run_serving
from repro.serving.telemetry import pct


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload",
                    choices=("random", "sharegpt", "long_prompt_burst",
                             "skewed_expert_load", "mixed_slo",
                             "multi_turn_chat"),
                    default="random")
    ap.add_argument("--rps", type=float, default=4.0)
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--fail-at", type=float, default=0.5)
    ap.add_argument("--fail-kind", choices=("ew", "aw", "none"),
                    default="ew")
    ap.add_argument("--chunk-budget", type=int, default=0,
                    help="chunked-prefill token budget per tick "
                         "(0 = whole-prompt prefill)")
    ap.add_argument("--rebalance", action="store_true",
                    help="let the orchestrator rebalance expert placement "
                         "when dispatch load is imbalanced (pairs with "
                         "--workload skewed_expert_load)")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable preempt-and-requeue (pairs with "
                         "--workload mixed_slo: blocked interactive "
                         "requests then wait out the batch wave)")
    ap.add_argument("--controller", action="store_true",
                    help="SLO-driven closed-loop control plane: the "
                         "engine autoscales the EW pool, triggers "
                         "weighted rebalances off the load trajectory, "
                         "adapts the chunk budget to deadline headroom, "
                         "and gates preemption on deadline risk")
    ap.add_argument("--prefix-slots", type=int, default=0,
                    help="per-AW prefix-cache slot budget (pairs with "
                         "--workload multi_turn_chat; needs a chunk "
                         "budget; 0 = plane off)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable the telemetry plane (metrics registry, "
                         "span tracing, stall attribution); output is "
                         "bit-identical either way")
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto/Chrome trace_event JSON of "
                         "the run here (open at ui.perfetto.dev)")
    ap.add_argument("--postmortem", default="", metavar="PATH",
                    help="dump the flight-recorder postmortem bundle "
                         "here at exit (deterministically replayable: "
                         "python -m repro.launch.replay PATH)")
    ap.add_argument("--watchdogs", action="store_true",
                    help="continuous health watchdogs (leak / stall "
                         "regression / invariant probes); prints the "
                         "health summary at exit")
    args = ap.parse_args()
    if args.prefix_slots and not args.chunk_budget:
        args.chunk_budget = 16     # the prefix plane rides chunked prefill

    cfg = get_config("mixtral_8x7b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    placement = "session_affinity" if args.workload == "multi_turn_chat" \
        else "least_loaded"
    if args.controller and not args.chunk_budget:
        args.chunk_budget = 16     # the budget policy needs the plane on
    ecfg = EngineConfig(max_batch=8, max_seq=96, num_aw=2, num_ew=2,
                        max_ew=4 if args.controller else 0,
                        chunk_token_budget=args.chunk_budget,
                        prefill_token_cap=8 * args.chunk_budget,
                        preempt=not args.no_preempt,
                        placement=placement,
                        prefix_cache_slots=args.prefix_slots,
                        telemetry=not args.no_telemetry,
                        trace_export_path=args.trace_out,
                        controller="on" if args.controller else "off",
                        victim_policy="controller" if args.controller and
                        not args.no_preempt else "remaining_work",
                        watchdogs=args.watchdogs)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(0))
    orch = Orchestrator(eng, worker_init_time=1.0, weight_push_time=0.25,
                        auto_rebalance=args.rebalance)

    max_prompt = 64 if args.workload == "long_prompt_burst" else 16
    wl = make_workload(args.workload, args.rps, args.duration, seed=1,
                       max_prompt=max_prompt, max_new=24)
    wl = [dataclasses.replace(w, prompt_len=min(w.prompt_len, max_prompt),
                              max_new_tokens=min(w.max_new_tokens, 24))
          for w in wl]
    failures = [] if args.fail_kind == "none" else \
        [FailurePlan(args.fail_at, args.fail_kind, 0)]

    m = run_serving(eng, wl, duration=600.0, orchestrator=orch,
                    failures=failures, step_time=0.05,
                    prefill_token_time=0.002)

    tbt = m.tbt_values()
    print(f"requests: {len(wl)} submitted, {len(m.finished)} finished")
    print(f"tokens:   {len(m.token_log)}  "
          f"throughput: {m.throughput():.1f} tok/s (virtual)")
    if tbt.size:
        print(f"TBT: median={pct(tbt, 50)*1e3:.1f}ms "
              f"p95={pct(tbt, 95)*1e3:.1f}ms "
              f"max_stall={m.max_stall()*1e3:.1f}ms")
    if m.ttft:
        t = list(m.ttft.values())
        print(f"TTFT (virtual, from arrival): median={pct(t, 50)*1e3:.1f}ms")
    qd = m.queue_delay_values()
    if qd.size:
        print(f"queue delay: p50={pct(qd, 50)*1e3:.1f}ms "
              f"p99={pct(qd, 99)*1e3:.1f}ms "
              f"blocked_ticks={eng.gateway.stats.blocked_ticks}")
    if m.prefill:
        print(f"prefill: {m.prefill['calls']} batched calls for "
              f"{m.prefill['requests']} requests "
              f"(occupancy={m.prefill['occupancy']:.2f})")
        ch = m.prefill.get("chunked")
        if ch:
            print(f"chunked prefill: {ch['chunks']} chunks in "
                  f"{ch['calls']} calls for {ch['requests']} streams "
                  f"(shapes={ch['shapes']}, resumed={ch['resumed']})")
    pf = m.gateway.get("prefix", {})
    if pf.get("hits") or pf.get("misses"):
        print(f"prefix cache: {pf['hits']} hits / "
              f"{pf['hits'] + pf['misses']} lookups, "
              f"{pf['hit_tokens']} prompt tokens adopted, "
              f"{pf['evictions']} evictions, {pf['restored']} restored, "
              f"{pf['repins']} session repins")
    if m.gateway.get("by_class"):
        print(f"request plane: preemptions={m.gateway['preemptions']}")
        for cls, counts in sorted(m.gateway["by_class"].items()):
            ttft = m.ttft_values(cls)
            extra = f" ttft_p50={pct(ttft, 50)*1e3:.0f}ms " \
                    f"p99={pct(ttft, 99)*1e3:.0f}ms" \
                if ttft.size else ""
            print(f"  {cls}: {counts}{extra}")
    if eng.placement_mgr is not None:
        mgr = eng.placement_mgr
        print(f"expert plane: gen={mgr.plan.generation} "
              f"imbalance(max/mean)={mgr.imbalance():.2f} "
              f"per-EW load={ {k: round(v, 1) for k, v in mgr.per_ew_load().items()} }")
    for e in orch.events:
        print(f"  [orch t={e.t:.2f}s] {e.kind} {e.worker} {e.detail}")
    if eng.controller is not None:
        print(f"control plane: decisions={eng.controller.counts}")
        for d in eng.controller.decisions:
            print(f"  [ctl t={d['t']:.2f}s] {d['kind']} {d['detail']}")
    if m.telemetry is not None:
        stalls = m.telemetry.stall_report()
        for st in stalls:
            comps = ", ".join(f"{k}={v*1e3:.0f}ms"
                              for k, v in sorted(st["components"].items())
                              if v > 1e-6)
            print(f"  [stall {st['rid']} {st['kind']} "
                  f"{st['gap']*1e3:.0f}ms] {comps}")
        if args.trace_out:
            print(f"trace written to {args.trace_out} "
                  f"(open at ui.perfetto.dev)")
    fr = eng.flightrec
    if fr is not None and fr.watchdogs is not None:
        hs = fr.watchdogs.summary()
        print(f"health: {hs['trips']} watchdog trip(s) over "
              f"{hs['intervals']} interval(s) {dict(hs['by_kind'])}")
        for t in hs["last_trips"]:
            print(f"  [health t={t['t']:.2f}s] {t['kind']} "
                  f"{t['what']}: {t['detail']}")
    if args.postmortem and fr is not None:
        fr.dump(args.postmortem,
                reason="postmortem on demand (--postmortem)")
        print(f"postmortem bundle written to {args.postmortem} "
              f"(replay: python -m repro.launch.replay {args.postmortem})")


if __name__ == "__main__":
    main()
