"""Prefill+decode must reproduce the teacher-forced forward exactly (the KV
cache datapath is only correct if incremental execution matches full)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import all_arch_ids, make_batch, reduced
from repro.models import get_model

import jax


@pytest.mark.parametrize("arch", all_arch_ids())
def test_decode_matches_teacher_forcing(arch, key):
    cfg = reduced(arch, cap_factor=8.0)
    api = get_model(cfg, num_aw=2, num_ew=2)
    params = api.init_params(key)
    rs = api.init_route_state()
    b, s = 2, 10
    rng = np.random.default_rng(3)
    full = make_batch(cfg, b, s + 3, rng)
    toks = full["tokens"]
    pre = dict(full)
    pre["tokens"] = toks[:, :s]

    logits_full, _ = api.forward_train(params, full, rs)
    last, cache = api.prefill(params, pre, rs, max_seq=s + 4)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, s - 1]),
                               rtol=2e-4, atol=2e-4)
    # decode three steps, each must match the teacher-forced position
    for j in range(3):
        pos = jnp.full((b,), s + j, jnp.int32)
        lg, cache = api.decode(params, jnp.asarray(toks[:, s + j]), pos,
                               cache, rs)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, s + j]),
                                   rtol=2e-4, atol=2e-4)


def test_sliding_window_ring_buffer(key):
    """Windowed decode with ring cache == full cache with window mask."""
    import dataclasses
    cfg = reduced("h2o_danube_1_8b")
    cfg_win = dataclasses.replace(cfg, sliding_window=8)
    api = get_model(cfg_win, num_aw=1, num_ew=1)
    params = api.init_params(key)
    rs = api.init_route_state()
    b, s = 1, 12
    batch = make_batch(cfg_win, b, s)
    logits_full, _ = api.forward_train(params, batch, rs)
    last, cache = api.prefill(params, batch, rs, max_seq=32)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    # cache is ring-sized (window), not max_seq
    ring = cache["blocks"][0]["k"].shape
    assert ring[2] == 8, ring
