"""Self-healing policy unit tests (paper §5.2): EW-side sufficient-subset
batching and health-transition helpers."""
import jax.numpy as jnp
import numpy as np

from repro.core import ert as ert_lib
from repro.core import selfheal
from repro.core.refe import RouteState


def test_ew_starts_when_all_healthy_delivered():
    received = np.array([True, True, False])
    healthy = np.array([True, True, False])   # AW2 already declared dead
    assert selfheal.ew_should_start(received, healthy, batch_tokens=10,
                                    min_batch=256, probe_expired=False)


def test_ew_waits_for_healthy_straggler():
    received = np.array([True, False, True])
    healthy = np.array([True, True, True])
    assert not selfheal.ew_should_start(received, healthy, batch_tokens=10,
                                        min_batch=256, probe_expired=False)


def test_ew_starts_at_batch_knee_despite_missing_aw():
    """GPU-efficiency knee (App. B): a sufficiently large buffered batch
    starts without the straggler."""
    received = np.array([True, False, True])
    healthy = np.array([True, True, True])
    assert selfheal.ew_should_start(received, healthy, batch_tokens=300,
                                    min_batch=256, probe_expired=False)


def test_ew_starts_after_probe_window():
    received = np.array([True, False, True])
    healthy = np.array([True, True, True])
    assert selfheal.ew_should_start(received, healthy, batch_tokens=10,
                                    min_batch=256, probe_expired=True)


def test_health_transitions_roundtrip():
    p = ert_lib.default_placement(8, 4)
    rs = RouteState.healthy(p, num_aw=4)
    rs = selfheal.fail_ew(rs, 2)
    rs = selfheal.fail_aw(rs, 1)
    assert not bool(rs.ew_health[2]) and not bool(rs.aw_health[1])
    assert bool(rs.ew_health[0]) and bool(rs.aw_health[0])
    rs = selfheal.recover_ew(rs, 2)
    rs = selfheal.recover_aw(rs, 1)
    assert bool(rs.ew_health.all()) and bool(rs.aw_health.all())


def test_experts_without_replica_reported():
    p = ert_lib.default_placement(8, 4)
    rs = RouteState.healthy(p, num_aw=1)  # shadows protect EW0 by default
    assert selfheal.experts_without_healthy_replica(rs, p).size == 0
    rs = selfheal.fail_ew(rs, 1)          # EW1 has no shadows
    lost = selfheal.experts_without_healthy_replica(rs, p)
    owner = p.slot_owner()
    assert all(owner[e] == 1 for e in lost)
    assert lost.size == 2                 # EW1's two experts


def test_repoint_shadow_bank_contents():
    import jax
    from repro.core import shadow as shadow_lib
    p = ert_lib.default_placement(8, 4)
    rs = RouteState.healthy(p, num_aw=1)
    w = jax.random.normal(jax.random.PRNGKey(0), (p.primary_slots, 4, 4))
    rs2 = selfheal.repoint_shadows(rs, p, protect_ew=3)
    # the slot bank gathers through the re-pointed residency array: every
    # shadow slot serves its newly assigned expert's weights
    se = np.asarray(rs2.slot_expert)
    bank = shadow_lib.resident_slot_bank({"w": w}, rs2.slot_expert)
    np.testing.assert_array_equal(
        np.asarray(bank["w"][p.primary_slots:]),
        np.asarray(w[se[p.primary_slots:]]))
    # every protected expert now has an off-EW candidate
    cand = np.asarray(rs2.candidates)
    owner = p.slot_owner()
    for e in range(3 * p.experts_per_ew, 4 * p.experts_per_ew):
        if e < p.num_experts:
            assert cand[e, 1] >= 0 and owner[cand[e, 1]] != 3
