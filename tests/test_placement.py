"""Elastic expert-plane tests (PR-3 tentpole): versioned placement plans,
load-aware rebalancing, EW scale-out/in, shadow promotion — and the critical
invariant that every placement change is a pure array update (ZERO new jit
traces of the decode/prefill steps)."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced
from repro.configs import get_config
from repro.core import ert as ert_lib
from repro.core import refe
from repro.core.orchestrator import Orchestrator
from repro.core.placement import ExpertPlacementManager
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPT = np.arange(1, 9, dtype=np.int32)


def make_engine(num_ew=2, max_ew=0, num_experts=0, **kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    if num_experts:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, num_experts=num_experts))
    ecfg = EngineConfig(max_batch=8, max_seq=48, num_aw=2, num_ew=num_ew,
                        max_ew=max_ew, **kw)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(7))


# --------------------------------------------------------------------------
# manager unit tests (host-side plan computation)
# --------------------------------------------------------------------------

def _manager(e=8, num_ew=4, max_ew=0):
    p = ert_lib.default_placement(e, num_ew)
    return p, ExpertPlacementManager(p, num_ew, max_ew=max_ew)


def test_initial_plan_matches_legacy_layout():
    p, mgr = _manager()
    plan = mgr.plan
    assert plan.generation == 0
    np.testing.assert_array_equal(plan.slot_owner, p.slot_owner())
    assign = ert_lib.initial_shadow_assignment(p)
    np.testing.assert_array_equal(plan.slot_expert,
                                  ert_lib.initial_slot_expert(p, assign))
    np.testing.assert_array_equal(plan.candidates(),
                                  ert_lib.build_candidates(p, assign))


def _check_plan_invariants(p, plan, members):
    owner = plan.slot_owner
    # owners are members or parked; every expert has a primary on a member
    assert set(np.unique(owner)) <= set(members) | {-1}
    cand = plan.candidates()
    for e in range(p.num_experts):
        pr = plan.primary[e]
        assert pr >= 0 and plan.slot_expert[pr] == e
        assert owner[pr] in members
        # replica (if any) lives on a DIFFERENT EW than the primary
        if cand[e, 1] >= 0:
            assert owner[cand[e, 1]] != owner[pr]
            assert plan.slot_expert[cand[e, 1]] == e


def test_scale_out_in_roundtrip_keeps_experts_placed():
    p, mgr = _manager(e=8, num_ew=2, max_ew=4)
    new_ew, plan = mgr.plan_scale_out()
    assert new_ew == 2 and plan.generation == 1
    assert len(plan.slots_of_ew(new_ew)) > 0          # joiner got slots
    _check_plan_invariants(p, plan, {0, 1, 2})
    plan2 = mgr.plan_scale_in(2)
    assert plan2.generation == 2
    assert len(plan2.slots_of_ew(2)) == 0             # drained EW parked
    _check_plan_invariants(p, plan2, {0, 1})


def test_promotion_flips_primaries_to_replicas():
    p, mgr = _manager(e=8, num_ew=4)
    gen0 = mgr.plan
    cand0 = gen0.candidates()
    protected = [e for e in range(p.num_experts)
                 if gen0.slot_owner[gen0.primary[e]] == 0]
    plan = mgr.promote_shadows(0)
    assert plan.generation == 1 and 0 not in plan.members
    for e in protected:
        # shadow promoted to primary, permanently, on a live EW
        assert plan.primary[e] == cand0[e, 1]
        assert plan.slot_owner[plan.primary[e]] in plan.members
    # the dead EW's slots are parked (weights died with it)
    assert not np.any(plan.slot_owner == 0)


def test_rebalance_during_revival_avoids_dead_member():
    """A failed-but-member EW (revival in flight) must receive no primaries
    from a rebalance — and the plan must stay output-exact."""
    eng = make_engine(num_ew=2)
    eng.submit("r0", PROMPT, 20)
    for _ in range(4):
        eng.step()
    ref = list(eng.requests["r0"].tokens)
    eng.fail_ew(0)                       # revive policy: still a member
    plan = eng.rebalance(now=1.0)
    owner = plan.slot_owner
    assert all(owner[plan.primary[e]] == 1
               for e in range(eng.api.placement.num_experts))
    while not eng.requests["r0"].done:
        eng.step()
    assert eng.requests["r0"].tokens[:len(ref)] == ref


def test_reprotect_never_places_replicas_on_dead_ews():
    p, mgr = _manager(e=8, num_ew=4)
    plan = mgr.plan_reprotect(2, dead_ews=(1,))
    for s in range(plan.num_slots):
        ex = plan.slot_expert[s]
        if ex >= 0 and s != plan.primary[ex]:
            assert plan.slot_owner[s] != 1   # no fresh replica on dead EW1


def test_reprotect_keeps_failover_replicas_of_dead_ew():
    """Re-pointing replicas while another EW is down must not recycle the
    failover copies that are currently the only reachable path."""
    p, mgr = _manager(e=8, num_ew=4)
    plan0 = mgr.plan
    cand0 = plan0.candidates()
    covered = [e for e in range(p.num_experts) if cand0[e, 1] >= 0 and
               plan0.slot_owner[plan0.primary[e]] == 0]
    assert covered                                      # shadows protect EW0
    plan = mgr.plan_reprotect(2, dead_ews=(0,))
    cand = plan.candidates()
    for e in covered:                                   # EW0 is down: its
        assert cand[e, 1] == cand0[e, 1]                # replicas are pinned


def test_rebalance_spreads_skewed_load():
    p, mgr = _manager(e=16, num_ew=4)
    # synthetic skew: experts 0..3 are hot and all primaried on EW0
    load = np.zeros((p.num_slots,))
    load[0:4] = 100.0
    load[4:16] = 1.0
    for _ in range(20):
        mgr.record_slot_load(load)
    assert mgr.imbalance() > 2.0
    assert mgr.should_rebalance()
    plan = mgr.plan_rebalance()
    _check_plan_invariants(p, plan, set(range(4)))
    # the four hot experts end up on four different EWs
    hot_ews = {int(plan.slot_owner[plan.primary[e]]) for e in range(4)}
    assert len(hot_ews) == 4
    # heaviest-loaded member is the protect pick (no hardcoded neighbor)
    assert mgr.choose_protect_ew() == 0


# --------------------------------------------------------------------------
# engine-level: zero new traces + output invariance
# --------------------------------------------------------------------------

def test_placement_changes_never_retrace_decode():
    """Acceptance criterion: scale-out, rebalance, scale-in, and promotion
    each complete with ZERO new jit traces of the decode step."""
    eng = make_engine(num_ew=2, max_ew=4)
    eng.submit("r0", PROMPT, 40)
    eng.step()
    traces = eng._decode._cache_size()
    assert traces == 1
    new = eng.add_ew(now=1.0)
    eng.step()
    eng.rebalance(now=2.0)
    eng.step()
    eng.drain_ew(new, now=3.0)
    eng.step()
    eng.fail_ew(0)
    eng.promote_shadows(0, now=4.0)
    eng.step()
    eng.repoint_shadows(1, now=5.0)
    eng.step()
    assert eng._decode._cache_size() == traces
    assert eng.placement_generation == 5
    kinds = [e.kind for e in eng.plan_log]
    assert kinds == ["placement_changed"] * 5


def test_rebalance_is_output_invariant():
    """Replica slots serve identical weights: a mid-generation rebalance
    (and the traffic splitting it enables) must not change a single token."""
    ref = make_engine(num_experts=16).generate("r", PROMPT, 16)
    eng = make_engine(num_experts=16)
    eng.submit("r", PROMPT, 16)
    for _ in range(5):
        eng.step()
    plan = eng.rebalance(now=1.0)
    assert plan.generation == 1
    while not eng.requests["r"].done:
        eng.step()
    assert eng.requests["r"].tokens == ref


def test_scale_out_is_output_invariant():
    ref = make_engine(num_experts=16).generate("r", PROMPT, 16)
    eng = make_engine(num_experts=16, max_ew=3)
    eng.submit("r", PROMPT, 16)
    for _ in range(5):
        eng.step()
    eng.add_ew(now=1.0)
    while not eng.requests["r"].done:
        eng.step()
    assert eng.requests["r"].tokens == ref


def test_promotion_is_exact_for_covered_experts():
    """EW0 fails under the promote policy: shadows become primaries and the
    pool shrinks — bit-identical to the failure-free run."""
    ref = make_engine().generate("r0", PROMPT, 14)
    eng = make_engine()
    orch = Orchestrator(eng, worker_init_time=1.0, weight_push_time=0.2,
                        ew_policy="promote")
    eng.submit("r0", PROMPT, 14)
    for _ in range(4):
        eng.step()
    orch.inject_failure("ew", 0, now=1.0)
    fired = orch.tick(1.0 + orch.detection_latency() + 1e-6)
    assert any(e.kind == "detected" and "promoted" in e.detail
               for e in fired)
    assert eng.live_ews == {1}
    while not eng.requests["r0"].done:
        eng.step()
    assert eng.requests["r0"].tokens == ref
    # background re-protection lands T_push later, as a new generation
    fired = orch.tick(1.0 + orch.detection_latency() + 0.2 + 1e-6)
    assert any(e.kind == "reprotected" for e in fired)
    assert any(e.kind == "placement_changed" for e in fired)


# --------------------------------------------------------------------------
# device-side load counters + traffic splitting
# --------------------------------------------------------------------------

def test_dispatch_load_counter_matches_routing():
    e, k, t = 4, 2, 12
    p = ert_lib.default_placement(e, 2)
    rs = refe.RouteState.healthy(p, num_aw=1)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (t, 8))
    logits = jax.random.normal(jax.random.fold_in(key, 1), (t, e))
    r = refe.route(x, logits, rs, p, top_k=k, capacity_factor=4.0, batch=t)
    load = np.asarray(r["slot_load"])
    assert load.shape == (p.num_slots,)
    assert load.sum() == np.asarray(r["keep"]).sum()   # every kept dispatch
    np.testing.assert_array_equal(
        load, np.bincount(np.asarray(r["slot_idx"]).reshape(-1),
                          weights=np.asarray(r["keep"]).reshape(-1),
                          minlength=p.num_slots))


def test_split_slot_halves_expert_traffic():
    """A load-bearing replica takes the odd-parity half of its expert's
    tokens; outputs are unchanged because the weights are identical."""
    e, t = 4, 16
    p = ert_lib.default_placement(e, 2)
    rs = refe.RouteState.healthy(p, num_aw=1)
    cand = np.asarray(rs.candidates)
    target = next(ex for ex in range(e) if cand[ex, 1] >= 0)
    split = np.full((e,), -1, np.int32)
    split[target] = cand[target, 1]
    rs_split = rs._replace(split_slot=refe.jnp.asarray(split))
    # every token routes to the target expert with top_k=1
    logits = np.full((t, e), -10.0, np.float32)
    logits[:, target] = 10.0
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (t, 8)))
    r = refe.route(refe.jnp.asarray(x), refe.jnp.asarray(logits), rs_split,
                   p, top_k=1, capacity_factor=0.0, capacity=t, batch=t)
    load = np.asarray(r["slot_load"])
    assert load[cand[target, 0]] == t // 2
    assert load[cand[target, 1]] == t // 2
    # when the replica's EW dies, everything falls back to the primary
    dead = rs_split._replace(ew_health=refe.jnp.asarray(
        np.array([True, False])))
    r2 = refe.route(refe.jnp.asarray(x), refe.jnp.asarray(logits), dead,
                    p, top_k=1, capacity_factor=0.0, capacity=t, batch=t)
    assert np.asarray(r2["slot_load"])[cand[target, 0]] == t


def test_engine_drains_load_counters_into_ema():
    eng = make_engine()
    eng.submit("r0", PROMPT, 8)
    for _ in range(6):
        eng.step()
    mgr = eng.placement_mgr
    assert mgr.load.total_recorded > 0
    assert mgr.load.ema_expert.sum() > 0
    # load is attributed to the EWs that own the dispatched slots
    assert sum(mgr.per_ew_load().values()) > 0


def test_orchestrator_emits_placement_events():
    eng = make_engine(max_ew=3)
    orch = Orchestrator(eng, worker_init_time=0.1, weight_push_time=0.1)
    eng.submit("r0", PROMPT, 30)
    eng.step()
    orch.request_scale_out(now=0.0)
    fired = orch.tick(0.25)
    kinds = [e.kind for e in fired]
    assert "scaled_out" in kinds and "placement_changed" in kinds
    gen_ev = next(e for e in fired if e.kind == "placement_changed")
    assert gen_ev.worker == "gen1"
