"""Paged KV cache (serving/kvcache.py PagedCacheLayout/PagePool + the
block-table decode kernel + the paged prefix plane).

Acceptance bar (ISSUE 8):
  * a paged engine is bit-identical to the contiguous engine — warm
    prefix turns, preemption-free decode, and decode under AW failure all
    emit the same tokens;
  * random interleaved adopt/extend/evict/fail sequences never double-free
    or leak a physical page (seeded-random property test over the
    PagePool oracle, at both the allocator and the engine level);
  * placement changes, prefix hits, and failover add ZERO new jit traces
    on the paged engine;
  * the block-table Pallas decode kernel (interpret mode) is bitwise
    identical to the fused contiguous kernel at block_k = page_tokens,
    and the ops-level fallback matches the reference oracle;
  * the cluster-wide radix index routes new sessions to the AW holding
    their prefix, and migration carries a hot prefix to a free AW through
    the checkpoint-replay path.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced
from repro.kernels import ops, ref as kref
from repro.kernels.decode_attention import (decode_attention_fused,
                                            decode_attention_paged)
from repro.serving.api import RequestSpec
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.kvcache import PagePool


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    defaults = dict(max_batch=4, max_seq=64, num_aw=2, num_ew=2,
                    chunk_token_budget=8, placement="session_affinity",
                    prefix_cache_slots=2, checkpoint=True)
    defaults.update(kw)
    return InferenceEngine(cfg, EngineConfig(**defaults),
                           jax.random.PRNGKey(0))


def drain(eng, hs, max_steps=400):
    n = 0
    while not all(h.done() for h in hs) and n < max_steps:
        eng.step()
        for rid in [r.rid for r in eng.requests.values() if r.done]:
            eng.release_request(rid)
        n += 1
    assert all(h.done() for h in hs), "run did not finish"
    for rid in [r.rid for r in eng.requests.values() if r.done]:
        eng.release_request(rid)


def submit_run(eng, rid, prompt, max_new=4, session=None):
    h = eng.client.submit(RequestSpec(rid=rid, prompt=prompt,
                                      max_new=max_new, session=session))
    drain(eng, [h])
    return list(h.tokens())


def prompts_chain(seed=11, lens=(24, 8, 6), vocab=200):
    """Multi-turn chat shape: each prompt extends the previous one."""
    rng = np.random.default_rng(seed)
    out, cur = [], np.zeros((0,), np.int32)
    for n in lens:
        cur = np.concatenate(
            [cur, rng.integers(1, vocab, size=(n,)).astype(np.int32)])
        out.append(cur)
    return out


# --------------------------------------------------------------------------
# allocator property test: seeded-random interleavings, oracle-checked
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [7, 1234, 777777])
def test_pagepool_fuzz_never_leaks_or_double_frees(seed):
    """Random interleaved extend/adopt/snapshot/evict/fail sequences keep
    every allocator invariant (each page free exactly once XOR allocated,
    bt only references live pages), and a full drain returns the pool to
    empty — no leak, no double free. (hypothesis is not available in this
    environment; seeded random.Random plays the same role.)"""
    rng = random.Random(seed)
    num_slots, nblk = 4, 4
    pool = PagePool(num_slots, 2, nblk, 8)
    entries = []                      # prefix entries: lists of pages
    for _ in range(3000):
        op = rng.randrange(5)
        slot = rng.randrange(num_slots)
        aw = pool.aw_of_slot(slot)
        if op == 0:                   # extend: map one more block
            blk = pool.mapped_blocks(slot)
            if blk < nblk and pool.free_pages(aw):
                pool.map_block(slot, blk, pool.alloc(aw))
        elif op == 1:                 # snapshot: entry pins a slot's pages
            pages = pool.slot_pages(slot)
            if pages:
                k = rng.randrange(1, len(pages) + 1)
                for p in pages[:k]:
                    pool.incref(p)
                entries.append(list(pages[:k]))
        elif op == 2:                 # adopt: empty slot maps entry pages
            if entries and pool.mapped_blocks(slot) == 0:
                e = rng.choice(entries)
                for i, p in enumerate(e[:nblk]):
                    pool.incref(p)
                    pool.map_block(slot, i, p)
        elif op == 3:                 # evict: tail-first partial trim
            if entries:
                e = rng.choice(entries)
                if e:
                    pool.decref(e.pop())
                if not e:
                    entries.remove(e)
        else:                         # release / fail: unmap whole slot
            pool.release_slot(slot)
        pool.check()
    for s in range(num_slots):        # drain everything
        pool.release_slot(s)
    for e in entries:
        for p in e:
            pool.decref(p)
    pool.check()
    st = pool.stats()
    assert st["pages_used"] == 0 and st["pages_shared"] == 0


def test_paged_engine_fuzz_never_leaks(monkeypatch=None):
    """Engine-level interleaving: submissions (adoption), decode steps
    (copy-on-extend), releases (offers/evictions), and AW fail/provision
    cycles keep the pool oracle green; after a full drain + cache purge
    every physical page is free."""
    rng = random.Random(99)
    eng = make_engine(kv_page_tokens=8)
    chain = prompts_chain(seed=5, lens=(16, 6, 6, 6))
    sessions = ["a", "b", "c"]
    hs, counter = [], iter(range(10000))
    for _ in range(90):
        op = rng.random()
        if op < 0.3 and len(eng.requests) < 3:
            s = rng.choice(sessions)
            p = chain[rng.randrange(len(chain))]
            hs.append(eng.client.submit(RequestSpec(
                rid=f"{s}-{next(counter)}", prompt=p,
                max_new=rng.randrange(2, 5), session=s)))
        elif op < 0.4:
            dead = [w.aw_id for w in eng.aws if not w.alive]
            live = [w.aw_id for w in eng.aws if w.alive]
            if dead:
                eng.provision_aw(dead[0])
            elif len(live) > 1:
                eng.fail_aw(rng.choice(live))
                eng.recover_aw_requests(now=float(eng.steps))
        else:
            eng.step()
            for rid in [r.rid for r in eng.requests.values() if r.done]:
                eng.release_request(rid)
        eng.pages.check()
    for w in eng.aws:
        if not w.alive:
            eng.provision_aw(w.aw_id)
    drain(eng, hs)
    eng.pages.check()
    # purge the caches: every remaining reference is a prefix entry's
    for w in eng.aws:
        for eid in list(w.prefix_cache.entries):
            eng._kv_free_pages(w.prefix_cache.remove_entry(eid))
    eng.pages.check()
    assert eng.pages.stats()["pages_used"] == 0


# --------------------------------------------------------------------------
# bit-identity vs the contiguous engine
# --------------------------------------------------------------------------

def _warm_turn_tokens(**kw):
    eng = make_engine(**kw)
    chain = prompts_chain()
    out = [submit_run(eng, f"sess-{i}", p, session="sess")
           for i, p in enumerate(chain)]
    return eng, out


def test_paged_matches_contiguous_warm_turns():
    """Multi-turn prefix hits: the paged engine adopts shared pages by
    reference (copy-on-extend at the boundary) and emits exactly the
    contiguous engine's tokens, with real page sharing observed."""
    ceng, want = _warm_turn_tokens()
    peng, got = _warm_turn_tokens(kv_page_tokens=16)
    assert got == want
    cs, ps = ceng.gateway.stats, peng.gateway.stats
    assert (ps.prefix_hits, ps.prefix_hit_tokens) == \
        (cs.prefix_hits, cs.prefix_hit_tokens)
    assert ps.prefix_hits > 0
    peng.pages.check()
    assert peng.pages.stats()["pages_shared"] > 0


@pytest.mark.parametrize("seg_len", [1, 4])
def test_paged_matches_contiguous_under_aw_failure(seg_len):
    """AW0 dies mid-run (mid-segment at decode_segment_len=4) with
    requests in flight; recovery replays committed checkpoints into fresh
    pages and every request finishes with the contiguous engine's exact
    tokens."""
    results = {}
    for mode, kw in [("contig", {}), ("paged", dict(kv_page_tokens=16))]:
        eng = make_engine(decode_segment_len=seg_len, **kw)
        hs = []
        for i in range(3):
            p = np.random.default_rng(100 + i).integers(
                1, 200, size=(12 + 3 * i,)).astype(np.int32)
            hs.append(eng.client.submit(RequestSpec(
                rid=f"s{i}-0", prompt=p, max_new=6, session=f"s{i}")))
        for _ in range(6):
            eng.step()
        eng.fail_aw(0)
        eng.recover_aw_requests(now=float(eng.steps))
        if eng.pages is not None:
            eng.pages.check()
        drain(eng, hs)
        if eng.pages is not None:
            eng.pages.check()
        results[mode] = [list(h.tokens()) for h in hs]
    assert results["paged"] == results["contig"]


def test_paged_zero_new_traces():
    """The whole paged lifecycle — cold admission, warm prefix hits,
    AW failover + restoration — re-uses the first-turn jit traces: block
    tables are data, not structure."""
    eng = make_engine(kv_page_tokens=16)
    chain = prompts_chain()
    submit_run(eng, "sess-0", chain[0], session="sess")
    base = eng._decode._cache_size() + eng.decode_plane.segment_traces()
    submit_run(eng, "sess-1", chain[1], session="sess")      # warm hit
    h = eng.client.submit(RequestSpec(rid="sess-2", prompt=chain[2],
                                      max_new=4, session="sess"))
    for _ in range(2):
        eng.step()
    victim = next(w.aw_id for w in eng.aws
                  if any(r._aw == w.aw_id for r in eng.requests.values()))
    eng.fail_aw(victim)
    eng.recover_aw_requests(now=float(eng.steps))
    drain(eng, [h])
    assert eng._decode._cache_size() + \
        eng.decode_plane.segment_traces() == base


# --------------------------------------------------------------------------
# block-table decode kernel
# --------------------------------------------------------------------------

def _paged_case(seed, b, hkv, h, dh, nblk, pt, npages):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    pk = jax.random.normal(ks[0], (npages, pt, hkv, dh), jnp.float32)
    pv = jax.random.normal(ks[1], (npages, pt, hkv, dh), jnp.float32)
    q = jax.random.normal(ks[2], (b, h, dh), jnp.float32)
    k1 = jax.random.normal(ks[3], (b, hkv, dh), jnp.float32)
    v1 = jax.random.normal(ks[4], (b, hkv, dh), jnp.float32)
    rng = np.random.default_rng(seed)
    # rows share pages (the prefix-sharing layout) and may hold nulls
    bt = rng.integers(1, npages, size=(b, nblk)).astype(np.int32)
    bt[0, 0] = bt[1, 0] if b > 1 else bt[0, 0]     # a genuinely shared page
    pos = jnp.asarray(rng.integers(pt, nblk * pt, size=(b,)), jnp.int32)
    # physical pages carry their own positions; null page 0 is all -1
    ppos = np.full((npages, pt), -1, np.int32)
    for pid in range(1, npages):
        ppos[pid] = rng.integers(0, nblk * pt, size=(pt,))
    for i in range(b):                 # make each row's view causal-valid
        for j in range(nblk):
            ppos[bt[i, j]] = np.arange(j * pt, (j + 1) * pt)
    ppos[0] = -1
    return q, pk, pv, jnp.asarray(ppos), jnp.asarray(bt), k1, v1, pos


@pytest.mark.parametrize("b,hkv,h,dh", [(2, 2, 8, 64), (3, 1, 4, 32)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_kernel_matches_fused(b, hkv, h, dh, softcap):
    """Interpret-mode Pallas: the block-table kernel gathering pages
    through scalar prefetch is BITWISE identical to the fused contiguous
    kernel at block_k = page_tokens (same accumulation order)."""
    nblk, pt, npages = 4, 16, 9
    q, pk, pv, ppos, bt, k1, v1, pos = _paged_case(
        3, b, hkv, h, dh, nblk, pt, npages)
    got = decode_attention_paged(q, pk, pv, ppos, bt, k1, v1, pos,
                                 softcap=softcap, interpret=True)
    flat = np.asarray(bt).reshape(-1)
    ck = pk[flat].reshape(b, nblk * pt, hkv, dh)
    cv = pv[flat].reshape(b, nblk * pt, hkv, dh)
    cpos = ppos[flat].reshape(b, nblk * pt)
    want = decode_attention_fused(q, ck, cv, cpos, k1, v1, pos,
                                  window=0, softcap=softcap, block_k=pt,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_ops_fallback_matches_ref():
    """The non-Pallas dispatch (gather + reference partial/combine) agrees
    with the oracle on the gathered contiguous view."""
    b, hkv, h, dh, nblk, pt, npages = 2, 2, 8, 64, 4, 16, 9
    q, pk, pv, ppos, bt, k1, v1, pos = _paged_case(
        4, b, hkv, h, dh, nblk, pt, npages)
    got = ops.decode_attention_paged(q, pk, pv, ppos, bt, k1, v1, pos)
    flat = np.asarray(bt).reshape(-1)
    ck = pk[flat].reshape(b, nblk * pt, hkv, dh)
    cv = pv[flat].reshape(b, nblk * pt, hkv, dh)
    cpos = ppos[flat].reshape(b, nblk * pt)
    want = kref.decode_attention_ref(q, ck, cv, jnp.asarray(cpos), k1, v1,
                                     pos, window=0, softcap=0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# cluster-wide routing + migration
# --------------------------------------------------------------------------

def test_global_index_routes_new_session_to_cached_aw():
    """A brand-new session whose prompt extends another session's cached
    prefix routes to the AW that holds it (one global trie lookup), hits,
    and still emits the contiguous engine's tokens."""
    chain = prompts_chain()
    results = {}
    for mode, kw in [("contig", {}),
                     ("paged", dict(kv_page_tokens=16,
                                    prefix_global_index=True))]:
        eng = make_engine(**kw)
        t1 = submit_run(eng, "alpha-0", chain[0], session="alpha")
        t2 = submit_run(eng, "beta-0", chain[1], session="beta")
        results[mode] = (t1, t2)
        if eng.pages is not None:
            assert eng.gateway.stats.prefix_global_hits >= 1
            assert eng.gateway.stats.prefix_hits >= 1
            eng.pages.check()
    assert results["paged"] == results["contig"]


def test_prefix_migration_follows_demand():
    """When the home AW has no slot headroom, the matched prefix migrates
    to a free AW via checkpoint replay and the arrival routes there: the
    hit survives the move and the output is unchanged."""
    chain = prompts_chain()
    eng = make_engine(kv_page_tokens=16, prefix_global_index=True,
                      prefix_migrate=True)
    want = [submit_run(make_engine(), f"w{i}", p, session=f"w{i}")
            for i, p in enumerate(chain[:2])]
    t1 = submit_run(eng, "alpha-0", chain[0], session="alpha")
    assert t1 == want[0]
    home = eng.prefix_plane.global_index.match(chain[1])[1]
    # saturate the home AW's partition so the router must migrate
    held = [eng.aws[home].slots.alloc()
            for _ in range(eng.aws[home].slots.free_count())]
    t2 = submit_run(eng, "beta-0", chain[1], session="beta")
    for s in held:
        eng.aws[home].slots.release(s)
    assert t2 == want[1]
    st = eng.gateway.stats
    assert st.prefix_migrated == 1 and st.prefix_global_hits >= 1
    assert st.prefix_hits >= 1
    new_home = eng.prefix_plane.global_index.match(chain[1])[1]
    assert new_home != home
    eng.pages.check()


def test_paged_eviction_prices_exclusive_pages():
    """Satellite fix: under page pressure the victim is the LRU entry and
    shared pages are never freed — only the refcount drops; the page
    stays live for its other holders."""
    eng = make_engine(kv_page_tokens=8, max_batch=2, num_aw=1, max_seq=32)
    pool = eng.pages
    cache = eng.aws[0].prefix_cache
    chain = prompts_chain(seed=3, lens=(10, 6))
    submit_run(eng, "s-0", chain[0], session="s")
    submit_run(eng, "s-1", chain[1], session="s")
    assert len(cache.entries) >= 1
    shared = [p for e in cache.entries.values() for p in e.pages
              if pool.ref[p] > 1]
    before = {p: int(pool.ref[p]) for p in shared}
    # drain the free list, then ask the cache to relieve the pressure
    aw = 0
    held = []
    while pool.free_pages(aw):
        held.append(pool.alloc(aw))
    freed = cache.evict_pages()
    assert freed, "eviction could not free a page"
    for p in freed:
        assert pool.ref[p] == 0
        assert p not in before, "a shared page was freed"
    for p in held:
        pool.decref(p)
    pool.check()
