"""Recovery under sustained load: an AW fails while requests are still
waiting at the Gateway. Nothing may be lost — queued requests are admitted
onto healthy AWs, preempted ones restore from the checkpoint store, the
healthy part of the fleet keeps decoding through the outage, and every
request's tokens match the failure-free run exactly."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced
from repro.core.orchestrator import Orchestrator
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import FailurePlan, run_serving


N_REQ = 12          # > max_batch: a queue necessarily forms
STEP = 0.05


def workload():
    wl = make_workload("random", rate_rps=4.0, duration=3.0, seed=6)
    wl = [dataclasses.replace(w, arrival=0.0, prompt_len=6 + (i % 5),
                              max_new_tokens=10)
          for i, w in enumerate(wl)]
    assert len(wl) >= N_REQ
    return wl[:N_REQ]


def run(failures):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=8, max_seq=64, num_aw=2, num_ew=2)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(1))
    orch = Orchestrator(eng, worker_init_time=0.6)
    m = run_serving(eng, workload(), duration=200.0, orchestrator=orch,
                    failures=failures, step_time=STEP)
    return eng, orch, m


def test_aw_failure_while_queued_loses_nothing():
    eng_ref, _, m_ref = run([])
    eng, orch, m = run([FailurePlan(0.12, "aw", 0)])

    wl = workload()
    # no request lost: everything admitted and finished in both runs
    assert len(m_ref.finished) == len(wl)
    assert len(m.finished) == len(wl)
    assert eng.gateway.depth() == 0

    # failure forced a queue: some requests were admitted only after
    # capacity returned (recovery re-admissions and/or provisioning)
    t_detect = next(e.t for e in orch.events if e.kind == "detected")
    t_prov = next(e.t for e in orch.events if e.kind == "provisioned")
    assert eng.store.stats.restores >= 1
    assert eng.gateway.stats.requeued >= 1

    # healthy AW keeps making forward progress during the outage window
    in_window = [r for r in m.token_log if t_detect < r.t <= t_prov]
    assert len(in_window) > 0

    # decoded outputs are EXACTLY the failure-free ones for every request:
    # unaffected requests never notice; preempted requests resume from
    # committed tokens; queued requests land on healthy AWs
    assert set(m.outputs) == set(m_ref.outputs)
    for rid, toks in m_ref.outputs.items():
        assert m.outputs[rid] == toks, rid


def test_queued_requests_admitted_after_recovery_on_healthy_aw():
    """Requests still waiting when the AW dies must be admitted onto a
    healthy (or re-provisioned) AW — queueing delay shows the wait and the
    placement is a live worker."""
    eng, orch, m = run([FailurePlan(0.12, "aw", 0)])
    assert m.queue_delay            # Gateway recorded admission delays
    assert max(m.queue_delay_values()) > 0.0
    # every admission went to an AW that was alive at admission time;
    # at the end all finished requests were released cleanly
    assert not eng.requests
    assert sum(w.slots.free_count() for w in eng.aws) == 8
