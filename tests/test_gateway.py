"""Gateway unit tests: FIFO queue semantics, retry-not-drop, queueing-delay
metrics, and the pluggable placement policies — plus an engine-level check
that two policies produce different (but both correct) placements."""
import jax
import numpy as np
import pytest

from conftest import reduced
from repro.core.checkpoint import CheckpointStore
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.gateway import (Gateway, LeastLoadedPolicy,
                                   RoundRobinPolicy, SessionAffinityPolicy)
from repro.serving.workers import AttentionWorker

PROMPT = np.arange(1, 7, dtype=np.int32)


def make_pool(num_aw=2, per_aw=2):
    store = CheckpointStore()
    return [AttentionWorker(a, a * per_aw, (a + 1) * per_aw, store)
            for a in range(num_aw)]


def test_fifo_admission_and_retry_not_drop():
    aws = make_pool(num_aw=2, per_aw=2)   # 4 slots total
    gw = Gateway(aws)
    for i in range(6):
        gw.enqueue(f"r{i}", PROMPT, 4, now=float(i))
    admitted = gw.admit(now=10.0)
    assert [q.rid for q, _, _ in admitted] == ["r0", "r1", "r2", "r3"]
    # the two overflow requests stay queued in order, not dropped
    assert [q.rid for q in gw.queue] == ["r4", "r5"]
    assert gw.queue[0].retries == 1
    assert gw.stats.blocked_ticks == 1
    # queue delay is measured on the virtual clock
    assert gw.stats.queue_delay["r0"] == 10.0
    assert gw.stats.queue_delay["r3"] == 7.0
    # capacity frees -> FIFO head admitted on retry
    aws[0].slots.release(0)
    admitted = gw.admit(now=12.0)
    assert [q.rid for q, _, _ in admitted] == ["r4"]
    assert gw.stats.queue_delay["r4"] == 8.0


def test_recovery_entries_jump_the_queue():
    aws = make_pool()
    gw = Gateway(aws)
    gw.enqueue("fresh", PROMPT, 4, now=5.0)
    from repro.serving.gateway import QueuedRequest
    gw.requeue_recovery([QueuedRequest("old-a", PROMPT, 4, t_enqueue=1.0),
                         QueuedRequest("old-b", PROMPT, 4, t_enqueue=2.0)])
    assert [q.rid for q in gw.queue] == ["old-a", "old-b", "fresh"]
    assert all(q.recovery for q in list(gw.queue)[:2])
    assert gw.stats.requeued == 2


def test_least_loaded_skips_dead_and_full():
    aws = make_pool(num_aw=3, per_aw=2)
    pol = LeastLoadedPolicy()
    aws[1].fail(route_state=_dummy_rs(3))
    aws[0].slots.alloc()
    assert pol(aws, "x") == 2          # most free among alive
    aws[2].slots.alloc()
    aws[2].slots.alloc()
    assert pol(aws, "x") == 0          # AW2 full, AW1 dead
    aws[0].slots.alloc()
    assert pol(aws, "x") is None


def test_round_robin_cycles_over_healthy():
    aws = make_pool(num_aw=3, per_aw=4)
    pol = RoundRobinPolicy()
    assert [pol(aws, "x") for _ in range(4)] == [0, 1, 2, 0]
    aws[1].fail(route_state=_dummy_rs(3))
    assert [pol(aws, "x") for _ in range(3)] == [2, 0, 2]


def test_session_affinity_colocates_and_falls_back():
    aws = make_pool(num_aw=2, per_aw=2)
    pol = SessionAffinityPolicy()
    # the policy hashes the placement key verbatim; rid-derived keys share
    # the session prefix, so the session's requests share a home
    keys = [SessionAffinityPolicy.session_key(r)
            for r in ["sess7-0", "sess7-1", "sess7-2"]]
    homes = [pol(aws, k) for k in keys]
    assert len(set(homes)) == 1        # same session -> same AW
    home = homes[0]
    aws[home].slots.alloc()
    aws[home].slots.alloc()            # home full -> least-loaded fallback
    assert pol(aws, keys[0]) == 1 - home


def test_explicit_session_keys_with_hyphens_stay_distinct():
    """An explicit session key is hashed verbatim — hyphenated tenant ids
    must not collapse onto one home AW via rid-style prefix truncation."""
    from repro.serving.gateway import QueuedRequest
    keys = {QueuedRequest(f"r{i}", PROMPT, 4,
                          session=f"user-{i}").placement_key
            for i in range(8)}
    assert len(keys) == 8
    aws = make_pool(num_aw=4, per_aw=8)
    pol = SessionAffinityPolicy()
    homes = {k: pol(aws, k) for k in keys}
    assert len(set(homes.values())) > 1   # sessions spread over the ring


def _dummy_rs(num_aw):
    from repro.core.refe import RouteState
    import jax.numpy as jnp
    return RouteState(candidates=jnp.zeros((0, 2), jnp.int32),
                      ew_health=jnp.ones((2,), bool),
                      aw_health=jnp.ones((num_aw,), bool),
                      slot_expert=jnp.zeros((0,), jnp.int32),
                      slot_owner=jnp.zeros((0,), jnp.int32),
                      split_slot=jnp.zeros((0,), jnp.int32))


def test_fail_aw_without_checkpoint_does_not_strand_requests():
    """checkpoint=False means no restoration is possible: requests on the
    failed AW must keep decoding (simulated data loss) rather than being
    paused forever — generate() must terminate."""
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=4, max_seq=48, num_aw=2, num_ew=2,
                        checkpoint=False)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(4))
    assert eng.submit("r", PROMPT, 8)
    aw = eng.requests["r"].aw
    for _ in range(2):
        eng.step()
    eng.fail_aw(aw)
    assert not eng.requests["r"].paused
    assert eng.recover_aw_requests() == []   # nothing to restore
    while not eng.requests["r"].done:        # must terminate
        eng.step()
    assert len(eng.requests["r"].tokens) == 8


def test_multi_class_weighted_dequeue_prioritizes_interactive():
    """Under slot scarcity the interactive class is serviced first; within
    a class, FIFO holds. Weighted dequeue, not strict priority: batch is
    not starved when capacity remains."""
    aws = make_pool(num_aw=2, per_aw=2)   # 4 slots
    gw = Gateway(aws)
    for i in range(3):
        gw.enqueue(f"b{i}", PROMPT, 4, now=0.0, slo_class="batch")
    for i in range(2):
        gw.enqueue(f"i{i}", PROMPT, 4, now=0.0, slo_class="interactive")
    admitted = [q.rid for q, _, _ in gw.admit(now=1.0)]
    # interactive head served before batch despite arriving later
    assert admitted[:2] == ["i0", "i1"]
    assert set(admitted) == {"i0", "i1", "b0", "b1"}
    assert [q.rid for q in gw.queue] == ["b2"]


def test_deadline_orders_within_class_but_never_crosses_recovery():
    aws = make_pool(num_aw=2, per_aw=2)
    gw = Gateway(aws)
    gw.enqueue("late", PROMPT, 4, now=0.0)                 # no deadline
    gw.enqueue("soon", PROMPT, 4, now=1.0, deadline=5.0)
    gw.enqueue("sooner", PROMPT, 4, now=2.0, deadline=2.0)
    gw.enqueue("also-soon", PROMPT, 4, now=3.0, deadline=5.0)  # stable tie
    from repro.serving.gateway import QueuedRequest
    gw.requeue_recovery([QueuedRequest("old", PROMPT, 4, t_enqueue=0.5)])
    assert [q.rid for q in gw.queue] == \
        ["old", "sooner", "soon", "also-soon", "late"]


def test_deadlined_arrival_never_overtakes_blocked_head():
    """A head that has already been blocked (retries > 0) keeps its turn:
    deadline ordering applies among waiting entries, not over a starving
    head (e.g. a large prompt blocked on the prefill-token cap)."""
    aws = make_pool(num_aw=1, per_aw=1)
    gw = Gateway(aws)
    aws[0].slots.alloc()                      # pool full: heads block
    gw.enqueue("big", PROMPT, 4, now=0.0)     # no deadline
    gw.admit(now=1.0)
    assert gw.queue[0].retries == 1
    gw.enqueue("urgent", PROMPT, 4, now=2.0, deadline=3.0)
    assert [q.rid for q in gw.queue] == ["big", "urgent"]
    aws[0].slots.release(0)
    assert [q.rid for q, _, _ in gw.admit(now=4.0)] == ["big"]


def test_drop_searches_all_class_queues():
    aws = make_pool()
    gw = Gateway(aws)
    gw.enqueue("s", PROMPT, 4, slo_class="standard")
    gw.enqueue("b", PROMPT, 4, slo_class="batch")
    gw.enqueue("i", PROMPT, 4, slo_class="interactive")
    dropped = gw.drop("b")
    assert dropped is not None and dropped.slo_class == "batch"
    assert gw.drop("b") is None
    assert gw.depth() == 2 and gw.find("b") is None


def test_unknown_slo_class_rejected():
    gw = Gateway(make_pool())
    with pytest.raises(ValueError, match="slo_class"):
        gw.enqueue("x", PROMPT, 4, slo_class="urgent")


def test_policies_differ_but_both_decode_correctly():
    """Acceptance: two Gateway policies yield different placements; decode
    is correct (and identical) under both — placement is pure control
    plane."""
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)

    def build(policy):
        ecfg = EngineConfig(max_batch=8, max_seq=48, num_aw=2, num_ew=2,
                            placement=policy)
        return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(3))

    outs = {}
    placements = {}
    for policy in ("least_loaded", "session_affinity"):
        eng = build(policy)
        for i in range(3):
            assert eng.submit(f"sess1-{i}", PROMPT + i, 6)
        placements[policy] = tuple(eng.requests[f"sess1-{i}"].aw
                                   for i in range(3))
        while eng.active_requests():
            eng.step()
        outs[policy] = {r: eng.requests[r].tokens for r in eng.requests}
    # least-loaded spreads; session affinity pins the session to one AW
    assert len(set(placements["session_affinity"])) == 1
    assert len(set(placements["least_loaded"])) == 2
    assert placements["least_loaded"] != placements["session_affinity"]
    # same tokens either way: placement never changes results
    assert outs["least_loaded"] == outs["session_affinity"]
