"""Checkpoint store semantics (paper §6.1): async one-sided writes with
sequence numbers, out-of-order tolerance, commit-watermark prefix rule,
per-request restoration."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis (CI)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.checkpoint import CheckpointStore, KVCheckpointer


def _seg(i):
    return np.full((4,), i, np.float32)


def test_in_order_commit():
    s = CheckpointStore()
    s.register_request("r", aw_id=0)
    for i in range(5):
        s.async_update("r", i, _seg(i), seq_no=s.next_seq("r"),
                       token_value=100 + i)
    c, tv, segs = s.restore_request("r")
    assert c == 4 and tv == 104 and sorted(segs) == [0, 1, 2, 3, 4]


def test_out_of_order_waits_for_gap():
    """A later segment arriving before an earlier one must NOT advance the
    commit watermark past the gap (the 'async log + commit record' rule)."""
    s = CheckpointStore()
    s.register_request("r", aw_id=0)
    seqs = [s.next_seq("r") for _ in range(4)]
    s.async_update("r", 0, _seg(0), seqs[0], 100)
    s.async_update("r", 2, _seg(2), seqs[2], 102)   # seq 1 missing
    s.async_update("r", 3, _seg(3), seqs[3], 103)
    assert s.committed_token("r") == 0
    c, tv, segs = s.restore_request("r")
    assert c == 0 and sorted(segs) == [0]
    # gap fills -> watermark jumps over the whole contiguous range
    s.async_update("r", 1, _seg(1), seqs[1], 101)
    assert s.committed_token("r") == 3
    assert s.stats.out_of_order >= 2


@given(st.permutations(list(range(8))))
@settings(max_examples=30, deadline=None)
def test_any_arrival_order_full_prefix_restores_all(order):
    """Once every seq in a prefix has arrived (any order), the watermark
    covers it; segments beyond the last contiguous seq are never restored."""
    s = CheckpointStore()
    s.register_request("r", aw_id=0)
    seqs = [s.next_seq("r") for _ in range(8)]
    delivered = []
    for seq in order:
        s.async_update("r", seq, _seg(seq), seqs[seq], seq)
        delivered.append(seq)
        expect = -1
        got = set()
        for q in sorted(delivered):
            if q == expect + 1:
                expect = q
            got.add(q)
        assert s.committed_token("r") == expect
    c, tv, segs = s.restore_request("r")
    assert c == 7 and len(segs) == 8


def test_checkpointer_reorder_window_still_commits():
    s = CheckpointStore()
    ck = KVCheckpointer(s, aw_id=0, reorder_window=4, seed=1)
    ck.register("r")
    for i in range(16):
        ck.checkpoint_token("r", i, _seg(i), token_value=i)
    ck.flush()
    assert s.committed_token("r") == 15


def test_restore_accounting_bytes():
    s = CheckpointStore()
    s.register_request("r", aw_id=0)
    for i in range(3):
        s.async_update("r", i, [_seg(i), _seg(i)], s.next_seq("r"), i)
    before = s.stats.bytes_restored
    s.restore_request("r")
    assert s.stats.bytes_restored - before == 3 * 2 * 16


def test_reassign_and_release():
    s = CheckpointStore()
    s.register_request("a", aw_id=0)
    s.register_request("b", aw_id=0)
    s.register_request("c", aw_id=1)
    assert s.active_requests_on(0) == ["a", "b"]
    s.reassign("a", 1)
    assert s.active_requests_on(0) == ["b"]
    assert sorted(s.active_requests_on(1)) == ["a", "c"]
    s.release("a")
    assert s.active_requests_on(1) == ["c"]
