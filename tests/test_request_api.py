"""Typed request-lifecycle API tests (serving/api.py): RequestSpec/Client/
RequestHandle semantics — status state machine, incremental streaming,
per-request sampling, session affinity, cancellation teardown, deadline
accounting — plus the pinned behaviour of the deprecated
``InferenceEngine.submit`` shim."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced
from repro.serving.api import RequestSpec, SamplingParams
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPT = np.arange(1, 7, dtype=np.int32)


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    defaults = dict(max_batch=4, max_seq=48, num_aw=2, num_ew=2)
    defaults.update(kw)
    return InferenceEngine(cfg, EngineConfig(**defaults),
                           jax.random.PRNGKey(5))


def drain_done(eng):
    for rid in [r.rid for r in eng.requests.values() if r.done]:
        eng.release_request(rid)


# --------------------------------------------------------------------------
# lifecycle + streaming
# --------------------------------------------------------------------------

def test_handle_lifecycle_and_streaming():
    eng = make_engine()
    ref = eng.generate("ref", PROMPT, 8)
    eng.release_request("ref")

    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=8))
    assert h.state() == "placed"          # admitted, no tokens yet
    streamed = []
    while not h.done():
        eng.step()
        streamed.extend(h.new_tokens())
    assert h.state() == "done"
    assert streamed == ref == h.tokens()
    st = h.status()
    assert st.state == "done" and st.tokens_generated == 8
    assert st.preemptions == 0 and not st.deadline_missed

    # the handle survives engine-side release (final state is pinned)
    eng.release_request("r")
    assert "r" not in eng.requests
    assert h.tokens() == ref and h.state() == "done"


def test_queued_state_and_auto_rid():
    eng = make_engine()
    handles = [eng.client.submit(RequestSpec(prompt=PROMPT, max_new=30))
               for _ in range(4)]
    assert all(h.rid.startswith("req-") for h in handles)
    extra = eng.client.submit(RequestSpec(prompt=PROMPT, max_new=4))
    assert extra.state() == "queued"      # pool full: waits, not refused
    assert eng.gateway.depth() == 1
    # capacity frees -> admitted by the scheduler's own admission pass
    handles[0].cancel()
    eng.step()
    assert extra.state() in ("placed", "decoding")


def test_prefilling_state_via_chunked_plane():
    eng = make_engine(max_seq=64, chunk_token_budget=8, prefill_bucket=16)
    long_prompt = np.arange(1, 33, dtype=np.int32)
    h = eng.client.submit(RequestSpec(rid="r", prompt=long_prompt,
                                      max_new=4))
    eng.step()
    assert h.state() == "prefilling"
    assert h.status().prefill_cursor > 0
    while not h.done():
        eng.step()
    assert len(h.tokens()) == 4


# --------------------------------------------------------------------------
# per-request sampling + session affinity
# --------------------------------------------------------------------------

def test_per_request_sampling_overrides_engine_default():
    # engine default is NON-greedy; a spec pinning greedy=True must still
    # reproduce the engine-default greedy reference exactly
    ref = make_engine().generate("ref", PROMPT, 8)
    eng = make_engine(greedy=False, temperature=1.5, sample_seed=3)
    h_greedy = eng.client.submit(RequestSpec(
        rid="g", prompt=PROMPT, max_new=8,
        sampling=SamplingParams(greedy=True)))
    h_default = eng.client.submit(RequestSpec(
        rid="d", prompt=PROMPT, max_new=8))
    while not (h_greedy.done() and h_default.done()):
        eng.step()
    assert h_greedy.tokens() == ref
    assert h_default.tokens() != ref      # engine-wide sampling still on


def test_session_key_drives_affinity_placement():
    eng = make_engine(max_batch=8, placement="session_affinity")
    hs = [eng.client.submit(RequestSpec(
        rid=f"wildly-different-rid-{i}", prompt=PROMPT + i, max_new=4,
        session="tenant-7")) for i in range(3)]
    aws = {eng.requests[h.rid].aw for h in hs}
    assert len(aws) == 1                  # explicit session key co-locates


# --------------------------------------------------------------------------
# cancellation
# --------------------------------------------------------------------------

def test_cancel_queued_request():
    eng = make_engine()
    for i in range(4):
        eng.client.submit(RequestSpec(rid=f"b{i}", prompt=PROMPT,
                                      max_new=30))
    h = eng.client.submit(RequestSpec(rid="w", prompt=PROMPT, max_new=4))
    assert h.state() == "queued"
    assert h.cancel()
    assert h.state() == "cancelled"
    assert eng.gateway.depth() == 0 and "w" not in eng.requests
    assert eng.gateway.stats.class_count("standard", "cancelled") == 1


def test_cancel_in_flight_releases_slot_and_store():
    eng = make_engine()
    h1 = eng.client.submit(RequestSpec(rid="x", prompt=PROMPT, max_new=20))
    h2 = eng.client.submit(RequestSpec(rid="y", prompt=PROMPT + 1,
                                       max_new=6))
    ref_y = make_engine().generate("y", PROMPT + 1, 6)
    for _ in range(2):
        eng.step()
    aw = eng.requests["x"].aw
    free_before = eng.aws[aw].slots.free_count()
    assert h1.cancel(now=0.5)
    assert h1.state() == "cancelled"
    assert "x" not in eng.requests
    assert eng.aws[aw].slots.free_count() == free_before + 1
    assert "x" not in eng.store.active_requests_on(aw)
    # cancel is not a crash: the co-resident request is untouched
    while not h2.done():
        eng.step()
    assert h2.tokens() == ref_y
    assert any(e.kind == "cancelled" and e.worker == "x"
               for e in eng.request_log)


def test_cancel_mid_chunked_prefill_drops_stream():
    eng = make_engine(max_seq=64, chunk_token_budget=8, prefill_bucket=16)
    long_prompt = np.arange(1, 33, dtype=np.int32)
    h = eng.client.submit(RequestSpec(rid="r", prompt=long_prompt,
                                      max_new=4))
    eng.step()
    aw = eng.requests["r"].aw
    assert "r" in eng.aws[aw].prefills
    assert h.cancel()
    assert "r" not in eng.aws[aw].prefills      # cursor entry dropped
    assert "r" not in eng.chunked.jobs          # stream closed
    assert eng.aws[aw].slots.free_count() == eng.aws[aw].slots.capacity
    eng.step()                                   # plane keeps ticking


def test_cancel_unknown_rid_is_noop():
    eng = make_engine()
    assert not eng.cancel_request("nope")


def test_forget_drops_terminal_handles_only():
    eng = make_engine()
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=3))
    with pytest.raises(ValueError, match="still live"):
        eng.client.forget("r")
    while not h.done():
        eng.step()
    assert eng.client.forget("r")
    assert eng.client.handle("r") is None
    assert not eng.client.forget("r")
    assert h.tokens()                      # the caller's reference lives on


def test_rid_reuse_after_completion_leaks_nothing():
    eng = make_engine()
    free0 = sum(w.slots.free_count() for w in eng.aws)
    first_handle = first_tokens = None
    for _ in range(6):                    # > max_batch reuses of one rid
        h = eng.client.submit(RequestSpec(rid="same", prompt=PROMPT,
                                          max_new=3))
        while not h.done():
            eng.step()
        if first_handle is None:
            first_handle, first_tokens = h, h.tokens()
    # an old handle keeps ITS pinned result across rid reuse
    assert first_handle.done() and first_handle.tokens() == first_tokens
    assert len(h.tokens()) == 3
    eng.release_request("same")
    assert sum(w.slots.free_count() for w in eng.aws) == free0
    # an in-flight rid (queued or resident) still refuses reuse
    h2 = eng.client.submit(RequestSpec(rid="busy", prompt=PROMPT,
                                       max_new=10))
    with pytest.raises(ValueError, match="already in flight"):
        eng.client.submit(RequestSpec(rid="busy", prompt=PROMPT,
                                      max_new=2))


# --------------------------------------------------------------------------
# deadlines
# --------------------------------------------------------------------------

def test_deadline_missed_emitted_once_and_request_survives():
    eng = make_engine()
    for i in range(4):
        eng.client.submit(RequestSpec(rid=f"b{i}", prompt=PROMPT,
                                      max_new=12, slo_class="batch"))
    # queued past its deadline: flagged, not dropped
    h = eng.client.submit(RequestSpec(rid="d", prompt=PROMPT, max_new=4,
                                      slo_class="standard", deadline=0.1),
                          now=0.0)
    n = 0
    while not h.done() and n < 200:
        eng.step(now=1.0 + 0.02 * n)
        drain_done(eng)
        n += 1
    assert h.done() and len(h.tokens()) == 4
    assert eng.gateway.stats.class_count("standard", "deadline_missed") == 1
    assert sum(1 for e in eng.request_log
               if e.kind == "deadline_missed" and e.worker == "d") == 1


def test_crash_recovery_of_on_time_request_is_not_a_deadline_miss():
    """An AW crash requeues a recovery entry carrying the deadline; if the
    request's first token was delivered on time, the entry waiting out its
    deadline in the queue must NOT count as an SLO miss."""
    eng = make_engine()                    # 4 slots over 2 AWs
    fills = [eng.client.submit(RequestSpec(rid=f"f{i}", prompt=PROMPT + i,
                                           max_new=4)) for i in range(3)]
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=12,
                                      deadline=0.5), now=0.0)
    aw_r = eng.requests["r"].aw
    eng.step(now=0.1)                      # first tokens at 0.1 < 0.5
    assert 0 <= eng.requests["r"].t_first_token <= 0.5
    eng.fail_aw(aw_r)
    eng.recover_aw_requests(now=1.0)
    # the surviving AW is full: r waits in the queue past its deadline
    assert eng.gateway.find("r") is not None
    n = 0
    while not h.done() and n < 100:
        eng.step(now=1.1 + 0.02 * n)
        drain_done(eng)
        n += 1
    assert h.done()
    assert eng.gateway.stats.class_count("standard", "deadline_missed") == 0
    assert not any(e.kind == "deadline_missed" for e in eng.request_log)


# --------------------------------------------------------------------------
# the deprecated submit shim (pinned behaviour)
# --------------------------------------------------------------------------

def test_submit_shim_deprecated_but_compatible():
    eng = make_engine()
    with pytest.warns(DeprecationWarning, match="submit.*deprecated"):
        ok = eng.submit("r0", PROMPT, 6)
    assert ok is True and "r0" in eng.requests
    # historical sync-refuse semantics: a full pool refuses, leaves no
    # queue residue, and the rid can be resubmitted later
    for i in range(3):
        with pytest.warns(DeprecationWarning):
            assert eng.submit(f"f{i}", PROMPT, 6)
    with pytest.warns(DeprecationWarning):
        refused = eng.submit("over", PROMPT, 6)
    assert refused is False
    assert eng.gateway.depth() == 0 and "over" not in eng.requests
    # the shim rides the same plane: requests decode identically
    ref = make_engine().generate("r0", PROMPT, 6)
    while not eng.requests["r0"].done:
        eng.step()
    assert eng.requests["r0"].tokens == ref


def test_generate_does_not_warn():
    import warnings as w
    eng = make_engine()
    with w.catch_warnings():
        w.simplefilter("error", DeprecationWarning)
        eng.generate("r", PROMPT, 4)


# --------------------------------------------------------------------------
# completion deadlines (last-token SLO)
# --------------------------------------------------------------------------

def test_completion_deadline_missed_on_overrun():
    """A request still decoding past its completion deadline is flagged
    once, counted per-class, and NOT dropped — it still finishes with the
    full output."""
    eng = make_engine()
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=12,
                                      slo_class="batch",
                                      completion_deadline=0.1))
    n = 0
    while not h.done() and n < 100:
        eng.step(now=0.05 * (n + 1))   # crosses 0.1 mid-decode
        drain_done(eng)
        n += 1
    assert h.done()
    assert len(h.tokens()) == 12       # never dropped
    st = h.status()
    assert st.completion_deadline_missed and not st.deadline_missed
    assert eng.gateway.stats.class_count(
        "batch", "completion_deadline_missed") == 1
    evs = [e for e in eng.drain_request_events()
           if e.kind == "deadline_missed" and "completion" in e.detail]
    assert len(evs) == 1               # flagged exactly once


def test_completion_deadline_met_not_flagged():
    eng = make_engine()
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=3,
                                      completion_deadline=50.0))
    n = 0
    while not h.done() and n < 100:
        eng.step(now=0.05 * (n + 1))
        drain_done(eng)
        n += 1
    assert not h.status().completion_deadline_missed
    assert eng.gateway.stats.class_count(
        "standard", "completion_deadline_missed") == 0


def test_completion_deadline_backstop_at_release():
    """Finishing late and being released before the next check_deadlines
    tick still counts (the release-time backstop)."""
    eng = make_engine()
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=2,
                                      completion_deadline=0.5))
    while not h.done():
        eng.step(now=10.0)             # done past the deadline in one hop
    drain_done(eng)
    assert h.status().completion_deadline_missed
    assert eng.gateway.stats.class_count(
        "standard", "completion_deadline_missed") == 1


def test_completion_deadline_survives_preemption():
    """The completion deadline rides the recovery entry: a preempted
    victim keeps its last-token SLO, and an overrun after restore is
    still flagged exactly once."""
    eng = make_engine()
    h = eng.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=10,
                                      slo_class="batch",
                                      completion_deadline=0.2))
    for _ in range(2):
        eng.step(now=0.05)
    assert eng.preempt_request("r", now=0.1)
    n = 0
    while not h.done() and n < 100:
        eng.step(now=0.3 + 0.05 * n)   # past the deadline after restore
        drain_done(eng)
        n += 1
    assert h.done() and len(h.tokens()) == 10
    assert eng.gateway.stats.class_count(
        "batch", "completion_deadline_missed") == 1
