"""Pallas kernel validation: interpret=True execution of the TPU kernel body
vs the pure-jnp oracle in ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as kref
from repro.kernels.decode_attention import (decode_attention_fused,
                                            decode_attention_partial)
from repro.kernels.moe_gemm import moe_gemm
from repro.kernels.ssm_scan import ssm_scan


def _tols(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,hkv,dh,sc", [
    (1, 4, 1, 64, 128),
    (2, 8, 2, 64, 256),
    (3, 6, 6, 32, 96),     # MHA (no grouping), non-pow2 batch
    (2, 8, 1, 128, 512),   # MQA, granite-style
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (64, 0.0), (0, 30.0)])
def test_decode_attention_kernel(b, h, hkv, dh, sc, dtype, window, softcap):
    ks = jax.random.split(jax.random.PRNGKey(b * 1000 + h), 6)
    q = jax.random.normal(ks[0], (b, h, dh), dtype)
    ck = jax.random.normal(ks[1], (b, sc, hkv, dh), dtype)
    cv = jax.random.normal(ks[2], (b, sc, hkv, dh), dtype)
    pos = jnp.arange(b) * 7 + sc // 2
    cpos = jnp.where(jnp.arange(sc)[None] <= pos[:, None],
                     jnp.arange(sc)[None], -1).astype(jnp.int32)
    k1 = jax.random.normal(ks[3], (b, hkv, dh), dtype)
    v1 = jax.random.normal(ks[4], (b, hkv, dh), dtype)
    want = kref.decode_attention_ref(q, ck, cv, cpos, k1, v1, pos,
                                     window=window, softcap=softcap)
    m, l, acc = decode_attention_partial(q, ck, cv, cpos, pos, window=window,
                                         softcap=softcap, block_k=64,
                                         interpret=True)
    got = ops.combine_decode_partials(q, m, l, acc, k1, v1, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tols(dtype))


@pytest.mark.parametrize("b,h,hkv,dh,sc", [
    (1, 4, 1, 64, 128),
    (2, 8, 2, 64, 256),
    (3, 6, 6, 32, 96),     # MHA (no grouping), non-pow2 batch
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (64, 0.0), (0, 30.0)])
def test_decode_attention_fused_kernel(b, h, hkv, dh, sc, dtype, window,
                                       softcap):
    """The fused variant (self-attention fold + normalize in-kernel, VMEM
    scratch partials) matches the oracle over the same sweep."""
    ks = jax.random.split(jax.random.PRNGKey(b * 1000 + h + 1), 6)
    q = jax.random.normal(ks[0], (b, h, dh), dtype)
    ck = jax.random.normal(ks[1], (b, sc, hkv, dh), dtype)
    cv = jax.random.normal(ks[2], (b, sc, hkv, dh), dtype)
    pos = jnp.arange(b) * 7 + sc // 2
    cpos = jnp.where(jnp.arange(sc)[None] <= pos[:, None],
                     jnp.arange(sc)[None], -1).astype(jnp.int32)
    k1 = jax.random.normal(ks[3], (b, hkv, dh), dtype)
    v1 = jax.random.normal(ks[4], (b, hkv, dh), dtype)
    want = kref.decode_attention_ref(q, ck, cv, cpos, k1, v1, pos,
                                     window=window, softcap=softcap)
    got = decode_attention_fused(q, ck, cv, cpos, k1, v1, pos, window=window,
                                 softcap=softcap, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tols(dtype))


def test_decode_attention_fused_matches_partial_combine():
    """Fused and partial+combine paths agree bitwise-close: the serving
    decode step may use either depending on REPRO_KERNELS."""
    ks = jax.random.split(jax.random.PRNGKey(11), 6)
    b, h, hkv, dh, sc = 2, 8, 2, 64, 128
    q = jax.random.normal(ks[0], (b, h, dh), jnp.float32)
    ck = jax.random.normal(ks[1], (b, sc, hkv, dh), jnp.float32)
    cv = jax.random.normal(ks[2], (b, sc, hkv, dh), jnp.float32)
    pos = jnp.arange(b) * 5 + sc // 2
    cpos = jnp.where(jnp.arange(sc)[None] <= pos[:, None],
                     jnp.arange(sc)[None], -1).astype(jnp.int32)
    k1 = jax.random.normal(ks[3], (b, hkv, dh), jnp.float32)
    v1 = jax.random.normal(ks[4], (b, hkv, dh), jnp.float32)
    m, l, acc = decode_attention_partial(q, ck, cv, cpos, pos, block_k=64,
                                         interpret=True)
    two_call = ops.combine_decode_partials(q, m, l, acc, k1, v1)
    fused = decode_attention_fused(q, ck, cv, cpos, k1, v1, pos, block_k=64,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(two_call),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("p,c,d,f", [
    (4, 64, 128, 256),
    (6, 32, 96, 160),      # non-pow2 everything
    (1, 128, 64, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act,gated", [("silu", True), ("gelu", False)])
def test_moe_gemm_kernel(p, c, d, f, dtype, act, gated):
    ks = jax.random.split(jax.random.PRNGKey(p * 100 + c), 4)
    x = jax.random.normal(ks[0], (p, c, d), dtype)
    wg = (jax.random.normal(ks[1], (p, d, f), dtype) * 0.05) if gated else None
    wu = jax.random.normal(ks[2], (p, d, f), dtype) * 0.05
    wd = jax.random.normal(ks[3], (p, f, d), dtype) * 0.05
    want = kref.moe_gemm_ref(x, wg, wu, wd, act=act)
    got = moe_gemm(x, wg, wu, wd, act=act, block_c=32, block_f=64,
                   interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tols(dtype))


def test_moe_gemm_empty_slot_skip():
    """Inactive shadow / pad slots (count=0) produce zeros and skip MXU
    work; active slots are unaffected (paper §5.3 / App. D)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    p, c, d, f = 4, 32, 64, 128
    x = jax.random.normal(ks[0], (p, c, d))
    wg = jax.random.normal(ks[1], (p, d, f)) * 0.05
    wu = jax.random.normal(ks[2], (p, d, f)) * 0.05
    wd = jax.random.normal(ks[3], (p, f, d)) * 0.05
    want = kref.moe_gemm_ref(x, wg, wu, wd)
    counts = jnp.array([3, 0, 5, 0], jnp.int32)
    got = moe_gemm(x, wg, wu, wd, counts=counts, block_c=16, block_f=32,
                   interpret=True)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               rtol=2e-5, atol=2e-5)
    assert float(jnp.abs(got[1]).max()) == 0.0
    assert float(jnp.abs(got[3]).max()) == 0.0


@pytest.mark.parametrize("bs,s,h,p,n,chunk", [
    (2, 128, 3, 16, 32, 32),
    (1, 64, 2, 8, 16, 64),    # single chunk
    (2, 96, 1, 4, 8, 16),     # non-pow2 length
])
def test_ssm_scan_kernel(bs, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 5)
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    b = jax.random.normal(ks[3], (bs, s, n)) * 0.3
    c = jax.random.normal(ks[4], (bs, s, n)) * 0.3
    y_want, h_want = kref.ssm_scan_ref(x, dt, a, b, c)
    y_got, h_got = ssm_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_chunk_invariance():
    """Chunk size must not change the result (the chunked reformulation is
    exact, not an approximation)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    bs, s, h, p, n = 1, 64, 2, 8, 16
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    b = jax.random.normal(ks[3], (bs, s, n)) * 0.3
    c = jax.random.normal(ks[4], (bs, s, n)) * 0.3
    outs = [np.asarray(ssm_scan(x, dt, a, b, c, chunk=ch, interpret=True)[0])
            for ch in (8, 16, 64)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,s,h,hkv,dh", [
    (2, 128, 4, 2, 64),
    (1, 96, 6, 6, 32),     # MHA, non-pow2 seq
    (2, 64, 8, 1, 128),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap,causal", [
    (0, 0.0, True), (16, 0.0, True), (0, 50.0, True), (0, 0.0, False)])
def test_flash_attention_kernel(b, s, h, hkv, dh, dtype, window, softcap,
                                causal):
    """Prefill flash kernel vs the blockwise-jnp oracle."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    want = blockwise_attention(q, k, v, pos, pos, window=window,
                               softcap=softcap, causal=causal)
    got = flash_attention(q, k, v, pos, pos, window=window, softcap=softcap,
                          causal=causal, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tols(dtype))


@pytest.mark.parametrize("chunk", [8, 32, 96])
def test_mlstm_chunked_equals_recurrent(chunk):
    """§Perf iteration 4: chunkwise-parallel mLSTM must match the
    sequential recurrence exactly (incl. stabilizer and final state)."""
    from repro.configs import get_config
    from repro.models import xlstm as xl
    cfg = get_config("xlstm_350m").reduced()
    key = jax.random.PRNGKey(0)
    p = xl.mlstm_init(key, cfg)
    b, s = 2, 96
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, cfg.d_model))
    q, k, v, ig, fg = xl._mlstm_projections(cfg, p, x)
    st0 = xl.mlstm_state(cfg, b)
    h_rec, st_rec = xl._mlstm_recurrent(q, k, v, ig, fg, st0)
    h_chk, st_chk = xl._mlstm_chunked(q, k, v, ig, fg, st0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_rec),
                               rtol=1e-4, atol=1e-4)
    for kk in ("c", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_chk[kk]),
                                   np.asarray(st_rec[kk]),
                                   rtol=1e-4, atol=1e-4)


def test_blockwise_attention_vs_dense():
    """The pure-JAX flash-style prefill attention matches naive softmax."""
    from repro.models.attention import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, h, hkv, dh = 2, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    got = blockwise_attention(q, k, v, pos, pos, block_q=16, block_k=16)

    # naive reference
    g = h // hkv
    qq = q.reshape(b, s, hkv, g, dh) / np.sqrt(dh)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qq, k)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    want = jnp.einsum("bhgqk,bkhd->bqhgd", pr, v).reshape(b, s, h, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
