"""Device-resident decode loop (serving/decode_loop.py): jitted sampling,
multi-token lax.scan segments, and the host-sync accounting.

The bar extends the repo's standing invariants to the new plane:

  * device greedy sampling == host ``np.argmax`` (first-max tie-break);
  * a stochastic token at (request, pos) is reproducible regardless of
    batch composition, submission order, or slot assignment (counter-based
    keys derived from the rid, never the slot);
  * ``decode_segment_len=8`` is bit-identical to per-step decode — plain
    runs, mid-segment AW crash (uncommitted segment rewound and replayed),
    in-segment preemption victims, and prefix-cache warm turns alike;
  * segment tails, done rows, and SamplingParams changes mint zero new jit
    traces;
  * ``GatewayStats.host_syncs`` counts exactly one device->host drain per
    decode dispatch: per token at seg_len=1, per segment at seg_len=8.
"""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import reduced
from repro.serving.api import RequestSpec, SamplingParams
from repro.serving.decode_loop import _sample_tokens
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPT = np.arange(1, 9, dtype=np.int32)


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    defaults = dict(max_batch=4, max_seq=64, num_aw=2, num_ew=2)
    defaults.update(kw)
    return InferenceEngine(cfg, EngineConfig(**defaults),
                           jax.random.PRNGKey(7))


def run_to_done(eng, handles, max_steps=300, release=False):
    hs = handles if isinstance(handles, list) else [handles]
    n = 0
    while not all(h.done() for h in hs) and n < max_steps:
        eng.step()
        if release:
            for rid in [r.rid for r in eng.requests.values() if r.done]:
                eng.release_request(rid)
        n += 1
    assert all(h.done() for h in hs)


# --------------------------------------------------------------------------
# sampling head: device vs host
# --------------------------------------------------------------------------

def test_device_greedy_matches_host_argmax():
    """Greedy rows of the jitted sampler take np.argmax's answer exactly,
    including the first-max tie-break."""
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((5, 33)).astype(np.float32)
    logits[1, 7] = logits[1, 19] = 50.0       # tie: first index must win
    b = logits.shape[0]
    out = _sample_tokens(jax.random.PRNGKey(3), jnp.asarray(logits),
                         jnp.zeros((b,), jnp.int32),
                         jnp.ones((b,), bool),
                         jnp.ones((b,), jnp.float32),
                         jnp.zeros((b,), jnp.int32),
                         jnp.zeros((b,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), np.argmax(logits, -1))


def test_device_topk_masks_to_k_candidates():
    """Stochastic draws land inside the per-row top-k set; rows with k=0
    can land anywhere."""
    rng = np.random.default_rng(1)
    logits = rng.standard_normal((4, 64)).astype(np.float32)
    k = np.asarray([3, 1, 0, 8], np.int32)
    for pos in range(32):
        out = np.asarray(_sample_tokens(
            jax.random.PRNGKey(5), jnp.asarray(logits),
            jnp.full((4,), pos, jnp.int32), jnp.zeros((4,), bool),
            jnp.ones((4,), jnp.float32), jnp.asarray(k),
            jnp.arange(4, dtype=jnp.int32)))
        for i in range(4):
            if k[i]:
                top = np.argsort(-logits[i])[:k[i]]
                assert out[i] in top
        # k=1 collapses to the argmax regardless of the key
        assert out[1] == np.argmax(logits[1])


def test_stochastic_token_independent_of_batch_composition():
    """Same request (rid, prompt, pos) => same token, whatever else is in
    the batch and whichever slot the request lands on — the counter-based
    key depends only on (engine seed, rid-derived seed, pos)."""
    kw = dict(greedy=False, temperature=1.2, top_k=10, sample_seed=11)
    other = np.arange(3, 11, dtype=np.int32)

    eng_a = make_engine(**kw)                 # alpha alone
    ha = eng_a.client.submit(RequestSpec(rid="alpha", prompt=PROMPT,
                                         max_new=10))
    run_to_done(eng_a, ha)
    ref = ha.tokens()

    eng_b = make_engine(**kw)                 # alpha + two co-residents
    hs = [eng_b.client.submit(RequestSpec(rid="alpha", prompt=PROMPT,
                                          max_new=10)),
          eng_b.client.submit(RequestSpec(rid="beta", prompt=other,
                                          max_new=10)),
          eng_b.client.submit(RequestSpec(rid="gamma", prompt=other,
                                          max_new=6))]
    run_to_done(eng_b, hs)
    assert hs[0].tokens() == ref

    eng_c = make_engine(**kw)                 # alpha in a different slot
    hb = eng_c.client.submit(RequestSpec(rid="beta", prompt=other,
                                         max_new=8))
    ha2 = eng_c.client.submit(RequestSpec(rid="alpha", prompt=PROMPT,
                                          max_new=10))
    assert eng_c.requests["alpha"].slot != eng_a.requests["alpha"].slot
    run_to_done(eng_c, [hb, ha2])
    assert ha2.tokens() == ref


def test_per_request_sampling_params_respected():
    """Per-request SamplingParams override engine defaults row-by-row: a
    greedy request co-resident with stochastic ones still produces the
    engine-greedy reference stream."""
    ref = make_engine().generate("g", PROMPT, 10)
    eng = make_engine(greedy=False, temperature=2.0, sample_seed=3)
    hg = eng.client.submit(RequestSpec(
        rid="g", prompt=PROMPT, max_new=10,
        sampling=SamplingParams(greedy=True)))
    hs = eng.client.submit(RequestSpec(
        rid="s", prompt=PROMPT, max_new=10,
        sampling=SamplingParams(greedy=False, temperature=1.5, top_k=4,
                                seed=99)))
    run_to_done(eng, [hg, hs])
    assert hg.tokens() == ref
    assert hs.tokens() != ref


# --------------------------------------------------------------------------
# segmented decode: bit-identity vs per-step
# --------------------------------------------------------------------------

def _gen_all(eng, specs):
    handles = [eng.client.submit(RequestSpec(**s)) for s in specs]
    run_to_done(eng, handles)
    return {h.rid: h.tokens() for h in handles}


SPECS = [dict(rid="a", prompt=PROMPT, max_new=5),     # ends mid-segment
         dict(rid="b", prompt=np.arange(2, 12, dtype=np.int32),
              max_new=11),                            # ends mid-segment 2
         dict(rid="c", prompt=np.arange(5, 12, dtype=np.int32),
              max_new=16)]                            # two full segments


def test_segment_bit_identical_to_per_step():
    kw = dict(greedy=False, temperature=1.1, top_k=12, sample_seed=5)
    ref = _gen_all(make_engine(decode_segment_len=1, **kw), SPECS)
    seg = _gen_all(make_engine(decode_segment_len=8, **kw), SPECS)
    assert seg == ref
    for s in SPECS:                  # stop mask honored exactly
        assert len(seg[s["rid"]]) == s["max_new"]


def test_segment_mid_failure_rewinds_and_replays_bit_identical():
    """AW crash between a segment's device execution and its checkpoint
    commit: the un-flushed segment is rewound (<= seg_len tokens) and
    recomputed bit-identically through the ordinary §6.2 restore."""
    kw = dict(greedy=False, temperature=1.1, top_k=12, sample_seed=5,
              decode_segment_len=8)
    ref = make_engine(**kw).generate("r0", PROMPT, 22)

    eng = make_engine(**kw)
    h = eng.client.submit(RequestSpec(rid="r0", prompt=PROMPT, max_new=22))
    r = eng.requests["r0"]
    assert r.aw == 0
    eng.step()                        # segment 1: checkpointed + flushed
    committed_tokens = len(r.tokens)
    # simulate the crash window: the next segment drains to the host but
    # its checkpoint writes never commit
    eng.aws[0].checkpointer.flush = lambda: None
    eng.step()
    assert len(r.tokens) > committed_tokens
    eng.fail_aw(0)
    assert eng.recover_aw_requests() == ["r0"]
    assert r.aw == 1
    # restore rewound at most one segment, to the committed watermark
    assert len(r.tokens) == committed_tokens
    run_to_done(eng, h)
    assert h.tokens() == ref
    assert eng.store.stats.restores == 1


def test_segment_preempted_victim_bit_identical():
    """An in-segment preemption victim resumes from its committed cursor
    and finishes with the per-step reference stream."""
    kw = dict(greedy=False, temperature=1.1, top_k=12, sample_seed=5)
    ref = make_engine(decode_segment_len=1, **kw).generate("v", PROMPT, 20)
    eng = make_engine(decode_segment_len=8, **kw)
    h = eng.client.submit(RequestSpec(rid="v", prompt=PROMPT, max_new=20,
                                      slo_class="batch"))
    eng.step()                        # one full segment decoded
    n_before = len(h.tokens())
    assert 0 < n_before < 20
    assert eng.preempt_request("v", now=1.0)
    assert h.state() == "preempted"
    run_to_done(eng, h)
    assert h.tokens() == ref
    assert h.status().preemptions == 1


def test_segment_prefix_cache_warm_turn_bit_identical():
    """Second session turn rides a prefix-cache hit; segmented decode of
    the warm turn matches the per-step engine token-for-token."""
    def turns(seg):
        eng = make_engine(decode_segment_len=seg, chunk_token_budget=8,
                          placement="session_affinity",
                          prefix_cache_slots=2, greedy=False,
                          temperature=1.1, top_k=12, sample_seed=5)
        p1 = np.arange(1, 17, dtype=np.int32)
        h1 = eng.client.submit(RequestSpec(rid="t1", prompt=p1, max_new=6,
                                           session="s"))
        run_to_done(eng, h1, release=True)
        p2 = np.concatenate([p1, np.asarray([3, 1], np.int32)])
        h2 = eng.client.submit(RequestSpec(rid="t2", prompt=p2, max_new=12,
                                           session="s"))
        run_to_done(eng, h2, release=True)
        return h1.tokens(), h2.tokens(), eng.gateway.stats.prefix_hits

    t1_seg, t2_seg, hits_seg = turns(8)
    t1_ref, t2_ref, hits_ref = turns(1)
    assert hits_seg >= 1 and hits_ref >= 1
    assert (t1_seg, t2_seg) == (t1_ref, t2_ref)


# --------------------------------------------------------------------------
# trace discipline + host-sync accounting
# --------------------------------------------------------------------------

def test_segment_zero_new_traces():
    """Segment tails, finished rows, recovery re-binds, and per-request
    SamplingParams changes are array writes — the segment step and the
    sampling head never re-trace after warmup."""
    eng = make_engine(decode_segment_len=8, greedy=False, temperature=1.2,
                      top_k=6, sample_seed=2)
    h = eng.client.submit(RequestSpec(rid="w", prompt=PROMPT, max_new=6))
    run_to_done(eng, h)
    eng.release_request("w")
    base = eng.decode_plane.segment_traces()
    assert base >= 1
    for i, samp in enumerate([
            SamplingParams(greedy=True),
            SamplingParams(greedy=False, temperature=0.4, top_k=3, seed=7),
            None]):
        h = eng.client.submit(RequestSpec(
            rid=f"q{i}", prompt=PROMPT, max_new=3 + 5 * i, sampling=samp))
        run_to_done(eng, h)
        eng.release_request(f"q{i}")
    assert eng.decode_plane.segment_traces() == base


def test_host_sync_counter_per_step_and_per_segment():
    """seg_len=1: one drain per decode step. seg_len=8: one drain per
    segment, each yielding up to 8 tokens per request."""
    eng1 = make_engine(decode_segment_len=1)
    eng1.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=9))
    drains = 0
    while not eng1.requests["r"].done:
        out = eng1.step()
        assert sum(len(t) for t in out.values()) <= 1
        drains += 1
    assert eng1.gateway.stats.host_syncs == drains

    eng8 = make_engine(decode_segment_len=8)
    eng8.client.submit(RequestSpec(rid="r", prompt=PROMPT, max_new=9))
    out = eng8.step()
    assert eng8.gateway.stats.host_syncs == 1
    assert len(out["r"]) == 8         # whole segment in one drain
    eng8.step()
    assert eng8.gateway.stats.host_syncs == 2
    assert eng8.requests["r"].done


def test_segment_requires_model_support_flag():
    """decode_segment_len > 1 demands ModelApi.supports_decode_segments —
    built decoders advertise it."""
    eng = make_engine(decode_segment_len=8)
    assert eng.api.supports_decode_segments
