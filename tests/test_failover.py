"""End-to-end failover tests on the real engine (the paper's §7.2 claims at
functional level): exact-output recovery for both failure domains, EW-side
graceful degradation, orchestrator-driven detection/provisioning, and the
MegaScale-style baseline's behaviour for contrast."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced
from repro.configs import get_config
from repro.core.orchestrator import Orchestrator
from repro.serving.engine import EngineConfig, InferenceEngine


PROMPT = np.arange(1, 9, dtype=np.int32)


def make_engine(arch="mixtral_8x7b", tarragon=True, **kw):
    cfg = reduced(arch, cap_factor=4.0)
    ecfg = EngineConfig(max_batch=8, max_seq=48, num_aw=2, num_ew=2,
                        tarragon=tarragon, **kw)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(7))


@pytest.fixture(scope="module")
def ref_tokens():
    eng = make_engine()
    return eng.generate("r0", PROMPT, 14)


def test_ew_failure_shadow_failover_exact(ref_tokens):
    eng = make_engine()
    eng.submit("r0", PROMPT, 14)
    for _ in range(4):
        eng.step()
    eng.fail_ew(0)  # EW0's experts are covered by shadows on EW1
    while not eng.requests["r0"].done:
        eng.step()
    assert eng.requests["r0"].tokens == ref_tokens


def test_aw_failure_restore_exact(ref_tokens):
    eng = make_engine()
    eng.submit("r0", PROMPT, 14)
    for _ in range(4):
        eng.step()
    assert eng.requests["r0"].aw == 0
    eng.fail_aw(0)
    assert eng.recover_aw_requests() == ["r0"]
    assert eng.requests["r0"].aw == 1
    while not eng.requests["r0"].done:
        eng.step()
    assert eng.requests["r0"].tokens == ref_tokens
    assert eng.store.stats.restores == 1


def test_aw_failure_multi_request_only_affected_move(ref_tokens):
    eng = make_engine()
    eng.submit("a", PROMPT, 14)      # -> AW0
    eng.submit("b", PROMPT + 1, 14)  # -> AW1
    for _ in range(4):
        eng.step()
    slot_b = eng.requests["b"].slot
    eng.fail_aw(0)
    eng.recover_aw_requests()
    # unaffected request keeps its slot; affected one moved to AW1
    assert eng.requests["b"].slot == slot_b
    assert eng.requests["a"].aw == 1
    while eng.active_requests():
        eng.step()
    assert eng.requests["a"].tokens == ref_tokens


def test_ew_failure_without_shadow_degrades_not_crashes():
    """EW1's experts have no shadows by default -> tokens to them are
    dropped (reduced capacity), but decoding continues NaN-free."""
    eng = make_engine()
    eng.submit("r0", PROMPT, 12)
    eng.fail_ew(1)
    while not eng.requests["r0"].done:
        out = eng.step()
    toks = eng.requests["r0"].tokens
    assert len(toks) == 12
    assert all(0 <= t < eng.cfg.vocab_size for t in toks)


def test_megascale_baseline_has_no_shadow_slots():
    eng = make_engine(tarragon=False)
    assert eng.api.placement.num_shadow_slots == 0
    toks = eng.generate("r0", PROMPT, 10)
    assert len(toks) == 10


def test_orchestrator_detection_and_provisioning(ref_tokens):
    eng = make_engine()
    orch = Orchestrator(eng, worker_init_time=1.0)
    eng.submit("r0", PROMPT, 14)
    for _ in range(4):
        eng.step()
    orch.inject_failure("ew", 0, now=10.0)
    # before detection latency nothing fires
    assert orch.tick(10.01) == []
    assert 0 not in eng.failed_ews
    fired = orch.tick(10.0 + orch.detection_latency() + 1e-6)
    assert [e.kind for e in fired] == ["detected"]
    assert 0 in eng.failed_ews
    while not eng.requests["r0"].done:
        eng.step()
    assert eng.requests["r0"].tokens == ref_tokens
    # background provisioning restores the EW and re-points shadows
    fired = orch.tick(12.0)
    assert any(e.kind == "provisioned" for e in fired)
    assert 0 not in eng.failed_ews


def test_orchestrator_aw_flow(ref_tokens):
    eng = make_engine()
    orch = Orchestrator(eng, worker_init_time=1.0)
    eng.submit("r0", PROMPT, 14)
    for _ in range(4):
        eng.step()
    orch.inject_failure("aw", 0, now=5.0)
    fired = orch.tick(5.1)
    assert any("restored 1 requests" in e.detail for e in fired)
    while not eng.requests["r0"].done:
        eng.step()
    assert eng.requests["r0"].tokens == ref_tokens


def test_repoint_shadows_protects_other_ew(ref_tokens):
    """After re-pointing shadows to protect EW1, failing EW1 is exact."""
    eng = make_engine()
    eng.repoint_shadows(1)
    eng.submit("r0", PROMPT, 14)
    for _ in range(4):
        eng.step()
    eng.fail_ew(1)
    while not eng.requests["r0"].done:
        eng.step()
    assert eng.requests["r0"].tokens == ref_tokens


def test_dense_arch_aw_failover_exact():
    """AW-side restoration is architecture-agnostic: dense GQA arch."""
    cfg = reduced("qwen2_1_5b")
    ecfg = EngineConfig(max_batch=4, max_seq=40, num_aw=2, num_ew=1)
    ref = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(3)).generate(
        "r", PROMPT, 10)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(3))
    eng.submit("r", PROMPT, 10)
    for _ in range(3):
        eng.step()
    eng.fail_aw(0)
    eng.recover_aw_requests()
    while not eng.requests["r"].done:
        eng.step()
    assert eng.requests["r"].tokens == ref


@pytest.mark.parametrize("arch", ["zamba2_7b", "xlstm_350m"])
def test_ssm_arch_aw_failover_exact(arch):
    """Recurrent-state archs: the 'segment' is a state snapshot; restoration
    must resume the recurrence exactly."""
    cfg = reduced(arch)
    ecfg = EngineConfig(max_batch=4, max_seq=40, num_aw=2, num_ew=1)
    ref = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(5)).generate(
        "r", PROMPT, 8)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(5))
    eng.submit("r", PROMPT, 8)
    for _ in range(3):
        eng.step()
    eng.fail_aw(0)
    eng.recover_aw_requests()
    while not eng.requests["r"].done:
        eng.step()
    assert eng.requests["r"].tokens == ref
