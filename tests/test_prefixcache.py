"""Prefix-cache plane (serving/prefixcache.py): radix matching, slot
adoption, LRU+cost eviction under pressure, live-entry protection, and
checkpoint-backed restoration of cached prefixes across AW failure.

Acceptance bar (ISSUE 5):
  * prefix-hit generation is bit-identical to a cache-disabled run;
  * a full cache evicts LRU prefixes to admit new requests, never evicts
    refcounted-live prefixes, and admission still succeeds;
  * AW failure restores cached session prefixes on the failover AW with
    zero new jit traces, and the session's next turn still hits;
  * ``session_affinity`` re-pins a session whose pinned AW died and emits
    a ``session_repinned`` event.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced
from repro.core.checkpoint import CheckpointStore
from repro.data.workloads import chat_history_tokens, make_workload
from repro.serving.api import RequestSpec
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.prefixcache import AWPrefixCache, RadixIndex
from repro.serving.scheduler import FailurePlan, run_serving
from repro.serving.workers import AttentionWorker


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    defaults = dict(max_batch=4, max_seq=64, num_aw=2, num_ew=2,
                    chunk_token_budget=8, placement="session_affinity",
                    prefix_cache_slots=2)
    defaults.update(kw)
    return InferenceEngine(cfg, EngineConfig(**defaults),
                           jax.random.PRNGKey(0))


def run_to_done(eng, handles, release=True, max_steps=300):
    hs = handles if isinstance(handles, list) else [handles]
    n = 0
    while not all(h.done() for h in hs) and n < max_steps:
        eng.step()
        if release:
            # release as the serving loop does: finished slots are offered
            # to the prefix cache (or freed) every tick
            for rid in [r.rid for r in eng.requests.values() if r.done]:
                eng.release_request(rid)
        n += 1
    assert all(h.done() for h in hs)
    if release:
        for rid in [r.rid for r in eng.requests.values() if r.done]:
            eng.release_request(rid)


def submit_run(eng, rid, prompt, max_new=4, session=None, release=True):
    h = eng.client.submit(RequestSpec(rid=rid, prompt=prompt,
                                      max_new=max_new, session=session))
    run_to_done(eng, h, release=release)
    return h.tokens()


def prompts(lens, seed=11, vocab=200):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=(n,)).astype(np.int32)
            for n in lens]


# --------------------------------------------------------------------------
# radix index unit tests (no engine)
# --------------------------------------------------------------------------

def test_radix_insert_match_remove():
    idx = RadixIndex()
    idx.insert([1, 2, 3, 4], slot=0)
    idx.insert([1, 2, 9, 9], slot=1)          # splits the [1,2,3,4] edge
    idx.insert([7, 7], slot=2)
    usable = {0, 1, 2}
    # exact and extending matches walk to the deepest entry
    assert idx.match([1, 2, 3, 4, 5, 6], usable) == (0, 4)
    assert idx.match([1, 2, 9, 9, 1], usable) == (1, 4)
    # divergence mid-edge: shares exactly the common prefix
    s, lcp = idx.match([1, 2, 3, 8], usable)
    assert (s, lcp) == (0, 3)
    s, lcp = idx.match([1, 2, 5], usable)     # diverges at the split node
    assert s in (0, 1) and lcp == 2
    assert idx.match([9, 9], usable) == (-1, 0)
    # usable filtering: skip slot 0, fall back to the sibling branch
    s, lcp = idx.match([1, 2, 3, 4], {1, 2})
    assert (s, lcp) == (1, 2)
    # removal is collision-safe and path-exact
    idx.remove([1, 2, 3, 4], slot=5)          # wrong slot: no-op
    assert idx.exact_slot([1, 2, 3, 4]) == 0
    idx.remove([1, 2, 3, 4], slot=0)
    assert idx.exact_slot([1, 2, 3, 4]) == -1
    assert idx.match([1, 2, 3, 4], usable) == (1, 2)


def test_aw_prefix_cache_budgets_and_lru():
    """Slot/token budgets enforced at offer time; eviction is LRU with a
    shortest-first (cheapest recompute) tie-break."""
    w = AttentionWorker(0, 0, 4, CheckpointStore())
    cache = AWPrefixCache(w.slots, max_slots=2, max_tokens=0)
    w.prefix_cache = cache
    sa, sb, sc = w.slots.alloc(), w.slots.alloc(), w.slots.alloc()
    assert cache.offer(sa, np.arange(1, 6), "ra", None, now=1.0)
    assert cache.offer(sb, np.arange(50, 60), "rb", None, now=2.0)
    assert cache.evictable_count() == 2
    # at the slot budget: offering a third evicts the LRU entry (sa) and
    # returns its slot to the partition
    free0 = w.slots.free_count()
    assert cache.offer(sc, np.arange(80, 88), "rc", None, now=3.0)
    assert cache.evictable_count() == 2
    assert w.slots.free_count() == free0 + 1
    assert cache.match_len(np.arange(1, 6)) == 0          # sa evicted
    assert cache.match_len(np.arange(50, 60)) == 9        # sb kept
    # token budget refuses an oversized sequence outright
    tiny = AWPrefixCache(w.slots, max_slots=4, max_tokens=4)
    s = w.slots.alloc()
    assert not tiny.offer(s, np.arange(0, 9), "rx", None, now=0.0)


# --------------------------------------------------------------------------
# bit-identity + hit accounting
# --------------------------------------------------------------------------

def test_warm_turn_bit_identical_and_counted():
    """Turn 2 of a session shares turn 1's prompt as a prefix: the warm
    engine adopts the cached slot, prefills only the tail, produces
    bit-identical tokens, and triggers zero new decode traces."""
    p1, tail = prompts([12, 7], seed=3)
    p2 = np.concatenate([p1, tail])

    cold = make_engine(prefix_cache_slots=0)
    ref1 = submit_run(cold, "s-1", p1, session="sessA")
    ref2 = submit_run(cold, "s-2", p2, session="sessA")

    warm = make_engine()
    assert warm.prefix_plane is not None
    assert submit_run(warm, "s-1", p1, session="sessA") == ref1
    traces = warm._decode._cache_size()
    assert submit_run(warm, "s-2", p2, session="sessA") == ref2
    st = warm.gateway.stats
    assert st.prefix_hits == 1 and st.prefix_misses == 1
    assert st.prefix_hit_tokens >= len(p1)
    # only the uncached tail was chunk-prefilled
    n_pre = len(p2) - 1
    assert warm.chunked.stats.prefilled_tokens["s-2"] == \
        n_pre - st.prefix_hit_tokens
    assert warm._decode._cache_size() == traces
    # the handle surfaces the hit
    assert warm.client.handle("s-2").status().prefix_hit == \
        st.prefix_hit_tokens


def test_fully_cached_prompt_skips_prefill_entirely():
    """A replayed prompt (same tokens, shorter or equal) adopts the whole
    prefix: zero chunk-prefill work, straight to decode."""
    p = prompts([16], seed=5)[0]
    cold = make_engine(prefix_cache_slots=0)
    ref = submit_run(cold, "r-1", p, session="s")

    eng = make_engine()
    submit_run(eng, "r-1", p, session="s")
    assert submit_run(eng, "r-2", p, session="s") == ref
    assert eng.gateway.stats.prefix_hit_tokens == len(p) - 1
    assert eng.chunked.stats.prefilled_tokens.get("r-2", 0) == 0


def test_multi_turn_chat_bit_identical_vs_cache_disabled():
    """Whole-workload exactness: multi_turn_chat through run_serving with
    the cache on vs off produces identical outputs, with a real hit rate
    on the warm turns."""
    wl = make_workload("multi_turn_chat", rate_rps=9.0, duration=1.0,
                       seed=1, chat_turns=3, chat_turn_gap=0.4)
    assert len(wl) >= 6

    def run(slots):
        eng = make_engine(max_batch=8, max_seq=96, prefix_cache_slots=slots,
                          chunk_token_budget=16)
        m = run_serving(eng, wl, duration=300.0, step_time=0.02)
        return m

    m_off = run(0)
    m_on = run(2)
    assert len(m_on.finished) == len(m_off.finished) == len(wl)
    for rid, toks in m_off.outputs.items():
        assert m_on.outputs[rid] == toks, rid
    assert m_on.gateway["prefix"]["hits"] > 0
    assert m_on.gateway["prefix"]["hit_tokens"] > 0
    assert m_off.gateway["prefix"]["hits"] == 0


# --------------------------------------------------------------------------
# eviction under slot pressure / live-entry protection
# --------------------------------------------------------------------------

def test_full_cache_evicts_lru_to_admit_new_requests():
    """One AW, all four slots cached: fresh admissions must evict LRU
    prefixes transparently (free_slots counts evictable capacity), and
    outputs stay correct. Prompts have disjoint first tokens, so no
    accidental prefix matches muddy the eviction accounting."""
    eng = make_engine(num_aw=1, prefix_cache_slots=4)
    olds = [np.arange(1 + 10 * i, 9 + 10 * i, dtype=np.int32)
            for i in range(4)]
    for i, p in enumerate(olds):
        submit_run(eng, f"old-{i}", p, session=f"o{i}")
    aw = eng.aws[0]
    assert len(aw.prefix_cache.entries) == 4
    assert aw.slots.free_count() == 0
    assert aw.free_slots() == 4                 # evictable capacity counts

    cold = make_engine(num_aw=1, prefix_cache_slots=0)
    news = [np.arange(101 + 10 * i, 110 + 10 * i, dtype=np.int32)
            for i in range(2)]
    for i, p in enumerate(news):
        ref = submit_run(cold, f"new-{i}", p, session=f"n{i}")
        assert submit_run(eng, f"new-{i}", p, session=f"n{i}") == ref
    assert eng.gateway.stats.prefix_evictions >= 2
    assert eng.gateway.stats.prefix_hits == 0


def test_lru_order_respects_recency():
    """A recently re-used prefix survives; the stale one is evicted."""
    eng = make_engine(num_aw=1, max_batch=2, prefix_cache_slots=2,
                      num_ew=2)
    pa = np.arange(1, 9, dtype=np.int32)
    pb = np.arange(50, 58, dtype=np.int32)
    submit_run(eng, "a-1", pa, session="A")     # cached, older
    submit_run(eng, "b-1", pb, session="B")     # cached, newer
    # touch A: a warm turn re-adopts and re-caches it (fresher last_use)
    submit_run(eng, "a-2",
               np.concatenate([pa, np.arange(200, 204, dtype=np.int32)]),
               session="A")
    # pressure: a no-match admission must evict B (the LRU), not A
    pc = np.arange(150, 158, dtype=np.int32)
    submit_run(eng, "c-1", pc, session="C", release=False)
    cache = eng.aws[0].prefix_cache
    assert cache.match_len(pa) > 0              # A (recently used) kept
    assert cache.match_len(pb) == 0             # B evicted
    assert any(e.session == "A" for e in cache.entries.values())


def test_live_prefixes_are_never_evicted():
    """An adopted (refcounted-live) prefix shares its slot with the live
    request: slot pressure must queue the newcomer rather than evict it,
    and admit once the adopter completes."""
    eng = make_engine(num_aw=1, max_batch=2, prefix_cache_slots=2)
    p = prompts([10], seed=6)[0]
    submit_run(eng, "x-1", p, 2, session="X")   # cached on one slot
    # adopt it with a long-running warm turn (live entry)
    p2 = np.concatenate([p, prompts([5], seed=9)[0]])
    h2 = eng.client.submit(RequestSpec(rid="x-2", prompt=p2, max_new=30,
                                       session="X"))
    eng.step()
    assert eng.gateway.stats.prefix_hits == 1
    # fill the second slot with another live request
    h3 = eng.client.submit(RequestSpec(rid="y-1",
                                       prompt=prompts([6], seed=10)[0],
                                       max_new=30, session="Y"))
    eng.step()
    assert h3.state() in ("placed", "prefilling", "decoding")
    # pool saturated, only a LIVE cache entry resident: newcomer queues
    h4 = eng.client.submit(RequestSpec(rid="z-1",
                                       prompt=prompts([6], seed=12)[0],
                                       max_new=2, session="Z"))
    assert h4.state() == "queued"
    live = [e for w in eng.aws if w.prefix_cache
            for e in w.prefix_cache.entries.values()]
    assert len(live) == 1 and live[0].live
    # the adopter finishing frees capacity; the queue drains
    run_to_done(eng, [h2, h3, h4])
    assert h4.done()


# --------------------------------------------------------------------------
# failure restoration + session re-pinning
# --------------------------------------------------------------------------

def test_aw_failure_restores_prefix_on_failover_aw():
    """The tentpole resilience claim: a dead AW's cached session prefix is
    restored per-request from the checkpoint store onto a healthy AW with
    zero new jit traces; the session re-pins there (event emitted) and its
    next turn hits the restored prefix, bit-identical to the cold run."""
    p1, tail = prompts([12, 6], seed=13)
    p2 = np.concatenate([p1, tail])
    cold = make_engine(prefix_cache_slots=0)
    submit_run(cold, "s-1", p1, session="S")
    ref2 = submit_run(cold, "s-2", p2, session="S")

    eng = make_engine()
    submit_run(eng, "s-1", p1, session="S")
    holders = [w.aw_id for w in eng.aws
               if w.prefix_cache and w.prefix_cache.entries]
    assert len(holders) == 1
    traces = eng._decode._cache_size()
    eng.fail_aw(holders[0])
    eng.recover_aw_requests(now=1.0)
    assert eng.gateway.stats.prefix_restored == 1
    assert eng._decode._cache_size() == traces
    new_holders = [w.aw_id for w in eng.aws
                   if w.alive and w.prefix_cache and w.prefix_cache.entries]
    assert new_holders and new_holders[0] != holders[0]
    # the next turn hits the restored prefix on the failover AW...
    assert submit_run(eng, "s-2", p2, session="S") == ref2
    assert eng.gateway.stats.prefix_hits == 1
    assert eng.requests.get("s-2") is None       # released
    # ...and the session was re-pinned with an audited event
    assert eng.gateway.stats.session_repins == 1
    evs = eng.drain_request_events()
    kinds = {e.kind for e in evs}
    assert "prefix_restored" in kinds and "session_repinned" in kinds
    assert eng._decode._cache_size() == traces   # still zero new traces


def test_prefix_restore_disabled_drops_orphans():
    eng = make_engine(prefix_restore=False)
    p = prompts([10], seed=14)[0]
    submit_run(eng, "s-1", p, session="S")
    holder = next(w.aw_id for w in eng.aws
                  if w.prefix_cache and w.prefix_cache.entries)
    eng.fail_aw(holder)
    eng.recover_aw_requests(now=1.0)
    assert eng.gateway.stats.prefix_restored == 0
    assert all(not w.prefix_cache.entries for w in eng.aws
               if w.prefix_cache is not None)
    # the store log was released, not leaked
    assert eng.store._logs == {}


def test_session_repin_points_future_turns_at_healthy_aw():
    """Even without a cached prefix to restore, a session pinned to a dead
    AW must be re-pinned to a healthy one by the placement fallback."""
    eng = make_engine(prefix_cache_slots=0, placement="session_affinity")
    p = prompts([8], seed=15)[0]
    submit_run(eng, "t-1", p, 2, session="T")
    pol = eng.gateway.policy
    home = pol.pins["T"]
    eng.fail_aw(home)
    h = eng.client.submit(RequestSpec(rid="t-2", prompt=p, max_new=2,
                                      session="T"))
    run_to_done(eng, h)
    assert pol.pins["T"] != home
    assert eng.gateway.stats.session_repins == 1
    assert any(e.kind == "session_repinned"
               for e in eng.drain_request_events())


def test_recovery_entry_resumes_with_prefix_hit_intact():
    """A warm-admitted request whose AW dies mid-stream restores through
    its OWN log — the adopted prefix was re-checkpointed at adoption, so
    the recovery entry resumes at (at least) the hit cursor instead of
    re-prefilling the conversation from token zero."""
    p1, tail = prompts([12, 20], seed=16)
    p2 = np.concatenate([p1, tail])
    cold = make_engine(prefix_cache_slots=0)
    submit_run(cold, "s-1", p1, session="S")
    ref2 = submit_run(cold, "s-2", p2, session="S")

    eng = make_engine()
    submit_run(eng, "s-1", p1, session="S")
    h = eng.client.submit(RequestSpec(rid="s-2", prompt=p2, max_new=4,
                                      session="S"))
    r = eng.requests["s-2"]
    hit = r.prefill_cursor
    assert hit >= len(p1)                       # adopted the cached prefix
    eng.step()                                  # one chunk past the hit
    assert r.prefilling
    eng.fail_aw(r.aw)
    eng.recover_aw_requests(now=1.0)
    assert r.prefill_cursor >= hit              # never back to token 0
    run_to_done(eng, h)
    assert h.tokens() == ref2
    # recomputed chunk work excludes the adopted prefix
    assert eng.chunked.stats.prefilled_tokens["s-2"] <= len(p2) - 1 - hit


def test_cancelled_adopter_forgets_the_live_entry():
    """Cancelling a request that adopted a cached prefix must drop the
    (truncated, live) entry with it — no stale index entry, no leaked
    slot, and later sessions are unaffected."""
    eng = make_engine(num_aw=1, max_batch=2)
    p = prompts([10], seed=17)[0]
    submit_run(eng, "c-1", p, 2, session="C")
    p2 = np.concatenate([p, prompts([6], seed=18)[0]])
    h = eng.client.submit(RequestSpec(rid="c-2", prompt=p2, max_new=20,
                                      session="C"))
    eng.step()
    assert eng.gateway.stats.prefix_hits == 1
    assert h.cancel()
    cache = eng.aws[0].prefix_cache
    assert not cache.entries                    # live entry forgotten
    assert eng.aws[0].slots.free_count() == 2   # both slots back
    # cache still functional afterwards
    ref = submit_run(make_engine(num_aw=1, max_batch=2,
                                 prefix_cache_slots=0), "d-1", p2, 3,
                     session="D")
    assert submit_run(eng, "d-1", p2, 3, session="D") == ref


def test_rid_reuse_does_not_corrupt_cached_log():
    """A cached entry keeps its finished request's checkpoint log under a
    reserved key: resubmitting the SAME rid must get a fresh log (and a
    prefix hit against its own previous life), stay bit-identical, and
    survive a crash of the new life."""
    p = prompts([10], seed=20)[0]
    p2 = np.concatenate([p, prompts([6], seed=21)[0]])
    cold = make_engine(prefix_cache_slots=0)
    submit_run(cold, "r", p, 3, session="S")
    ref2 = submit_run(cold, "r", p2, 8, session="S")

    eng = make_engine()
    submit_run(eng, "r", p, 3, session="S")          # cached
    h = eng.client.submit(RequestSpec(rid="r", prompt=p2, max_new=8,
                                      session="S"))  # same rid, new life
    assert eng.gateway.stats.prefix_hits == 1
    for _ in range(2):
        eng.step()
    r = eng.requests["r"]
    eng.fail_aw(r.aw)                                # crash the new life
    eng.recover_aw_requests(now=1.0)
    run_to_done(eng, h)
    assert h.tokens() == ref2
