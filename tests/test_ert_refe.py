"""ERT / REFE property tests (hypothesis): routing invariants that must hold
for ANY placement, health state and token batch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis (CI)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ert as ert_lib
from repro.core import refe


SETTINGS = dict(max_examples=40, deadline=None)


@st.composite
def placements(draw):
    num_ew = draw(st.sampled_from([2, 4, 8]))
    e = draw(st.integers(2, 24))
    return ert_lib.default_placement(e, num_ew)


@given(placements())
@settings(**SETTINGS)
def test_placement_geometry(p):
    assert p.primary_slots % p.num_ew == 0
    assert p.primary_slots >= p.num_experts
    owner = p.slot_owner()
    assert owner.shape == (p.num_slots,)
    assert owner.min() >= 0 and owner.max() < p.num_ew


@given(placements(), st.integers(0, 7))
@settings(**SETTINGS)
def test_shadow_assignment_covers_protected_ew(p, protect):
    protect = protect % p.num_ew
    assign = ert_lib.initial_shadow_assignment(p, protect)
    cand = ert_lib.build_candidates(p, assign)
    owner = p.slot_owner()
    protected = [e for e in range(protect * p.experts_per_ew,
                                  (protect + 1) * p.experts_per_ew)
                 if e < p.num_experts]
    for e in protected:
        s = cand[e, 1]
        assert s >= 0, f"expert {e} unprotected"
        assert owner[s] != owner[e], "shadow on same EW as primary"


@given(placements(), st.integers(0, 7))
@settings(**SETTINGS)
def test_resolve_never_routes_to_dead_ew(p, dead):
    dead = dead % p.num_ew
    assign = ert_lib.initial_shadow_assignment(p, dead)
    cand = ert_lib.build_candidates(p, assign)
    health = np.ones((p.num_ew,), bool)
    health[dead] = False
    owner = p.slot_owner()
    active, alive = ert_lib.resolve_active_slots(
        jnp.asarray(cand), jnp.asarray(health), jnp.asarray(owner))
    active, alive = np.asarray(active), np.asarray(alive)
    for e in range(p.num_experts):
        if alive[e]:
            assert health[owner[active[e]]], \
                f"expert {e} routed to dead EW {owner[active[e]]}"
    # with the dead EW protected by shadows, every expert stays reachable
    assert alive.all()


@given(st.integers(2, 6), st.integers(1, 3), st.integers(4, 40),
       st.integers(0, 1000))
@settings(**SETTINGS)
def test_dispatch_conservation(e_, k_, t, seed):
    """Every (token, choice) lands in at most one (slot, cap) cell; combine
    weights of surviving tokens sum to <= 1 (= 1 when nothing dropped)."""
    e = max(e_, k_ + 1)
    p = ert_lib.default_placement(e, 2)
    rs = refe.RouteState.healthy(p, num_aw=2)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (t, 8))
    logits = jax.random.normal(jax.random.fold_in(key, 1), (t, e))
    r = refe.route(x, logits, rs, p, top_k=k_, capacity_factor=2.0, batch=t)
    disp_j, comb_j = refe.routing_onehots(r)
    disp = np.asarray(disp_j)
    comb = np.asarray(comb_j)
    assert disp.min() >= 0 and disp.max() <= 1
    # each capacity cell used by at most one token
    assert (disp.sum(axis=0) <= 1 + 1e-6).all()
    # combine weight per token bounded by 1 (renormalized top-k)
    per_tok = comb.sum(axis=(1, 2))
    assert (per_tok <= 1 + 1e-5).all()


def test_masked_aw_equals_healthy_subset():
    """EW-side self-healing: the expert batch with AW0 dead equals the dense
    batch computed over only AW1's tokens (the 'sufficient subset')."""
    e, k, t = 4, 2, 8
    p = ert_lib.default_placement(e, 2)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (t, 16))
    logits = jax.random.normal(jax.random.fold_in(key, 1), (t, e))

    rs_healthy = refe.RouteState.healthy(p, num_aw=2)
    rs_fail = rs_healthy._replace(
        aw_health=jnp.asarray([False, True]))

    cap = 16
    r_fail = refe.route(x, logits, rs_fail, p, top_k=k, capacity_factor=2.0,
                        capacity=cap, batch=t)
    d_fail, _ = refe.routing_onehots(r_fail)
    expert_in_fail = jnp.einsum("tpc,td->pcd", d_fail.astype(x.dtype), x)
    # dense run over only the healthy half's tokens
    xh = x[t // 2:]
    r_h = refe.route(xh, logits[t // 2:], rs_healthy, p, top_k=k,
                     capacity_factor=2.0, capacity=cap, batch=t // 2)
    d_h, _ = refe.routing_onehots(r_h)
    expert_in_h = jnp.einsum("tpc,td->pcd", d_h.astype(xh.dtype), xh)
    # same token multisets per slot: compare per-slot sums (order-free)
    np.testing.assert_allclose(np.asarray(expert_in_fail.sum(axis=1)),
                               np.asarray(expert_in_h.sum(axis=1)),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 8), st.integers(0, 100))
@settings(**SETTINGS)
def test_grouped_path_equals_flat_path(n_groups, seed):
    """§Perf iteration 1: GShard-style grouped dispatch must equal the flat
    one-hot path when capacity is ample (drop policy differs per group, so
    equivalence is tested drop-free)."""
    import repro.core.refe as refe_mod
    e, k, s_g = 4, 2, 8
    t = n_groups * s_g
    p = ert_lib.default_placement(e, 2)
    rs = refe.RouteState.healthy(p, num_aw=2)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (t, 16))
    logits = jax.random.normal(jax.random.fold_in(key, 1), (t, e))

    def expert_fn(expert_in):
        return expert_in * 2.0

    # flat path (t <= ONEHOT_MAX_TOKENS), ample capacity
    r_flat = refe.route(x, logits, rs, p, top_k=k, capacity_factor=1.0,
                        capacity=t, batch=t)
    assert not r_flat["grouped"]
    y_flat = refe.expert_io(x, r_flat, expert_fn)

    # force grouping at the same small scale
    old_max, old_gs = refe_mod.ONEHOT_MAX_TOKENS, refe_mod.GROUP_SIZE
    refe_mod.ONEHOT_MAX_TOKENS, refe_mod.GROUP_SIZE = 0, s_g
    try:
        r_g = refe.route(x, logits, rs, p, top_k=k, capacity_factor=1.0,
                         capacity=s_g, batch=t)
        assert r_g["grouped"] and r_g["groups"] == n_groups
        y_g = refe.expert_io(x, r_g, expert_fn)
    finally:
        refe_mod.ONEHOT_MAX_TOKENS, refe_mod.GROUP_SIZE = old_max, old_gs
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_flat),
                               rtol=1e-5, atol=1e-5)


def test_expert_io_reroutes_to_shadow_exactly():
    """Shadow slot holds identical weights -> identical outputs after an EW
    failure (for covered experts)."""
    from repro.core import shadow as shadow_lib
    e, k, t, d = 4, 2, 6, 16
    p = ert_lib.default_placement(e, 2)
    rs = refe.RouteState.healthy(p, num_aw=1)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (t, d))
    logits = jax.random.normal(jax.random.fold_in(key, 3), (t, e))
    w = jax.random.normal(jax.random.fold_in(key, 4), (e, d, d)) * 0.1
    bank_w = shadow_lib.resident_slot_bank({"w": w}, rs.slot_expert)["w"]

    def expert_fn(expert_in):
        return jnp.einsum("pcd,pde->pce", expert_in, bank_w)

    r0 = refe.route(x, logits, rs, p, top_k=k, capacity_factor=4.0, batch=t)
    y0 = refe.expert_io(x, r0, expert_fn)
    rs_f = rs._replace(ew_health=jnp.asarray([False, True]))
    r1 = refe.route(x, logits, rs_f, p, top_k=k, capacity_factor=4.0,
                    capacity=r0["capacity"], batch=t)
    y1 = refe.expert_io(x, r1, expert_fn)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)
