"""Chunked-prefill plane (serving/chunked.py): token-budget scheduling,
exactness vs whole-prompt prefill, pad-free dispatch, and mid-prefill
failure recovery.

Acceptance:
  * chunked generation is bit-identical to the whole-prompt path across
    chunk budgets, including a budget smaller than one prompt;
  * a failure injected mid-prefill recovers by resuming from the last
    committed chunk — never re-prefilling from token 0 — with both the
    output match and the recomputed-token count asserted via the plane's
    token accounting;
  * admission is token-aware: the Gateway stops admitting when the plane
    holds too many outstanding prefill tokens, even with free slots.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced
from repro.core import ert as ert_lib
from repro.core import refe
from repro.core.orchestrator import Orchestrator
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.scheduler import FailurePlan, run_serving


def make_engine(budget=0, **kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=8, max_seq=64, num_aw=2, num_ew=2,
                        chunk_token_budget=budget, **kw)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(0))


def prompts(lens, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 200, size=(n,)).astype(np.int32) for n in lens]


def drain(eng, limit=500):
    """Minimal serving loop: admit (as run_serving does each tick), then
    step, until nothing is queued, prefilling, or decoding."""
    n = 0
    while (eng.active_requests() or eng.prefilling_requests()
           or eng.gateway.depth()) and n < limit:
        eng.scheduler.admit(float(n))
        eng.step()
        n += 1
    assert n < limit, "engine did not drain"


# --------------------------------------------------------------------------
# exactness
# --------------------------------------------------------------------------

def test_chunked_matches_whole_prefill_across_budgets():
    """Same prompts, same decode: the chunk stream must reproduce the
    whole-prompt path bit-for-bit. Budget 6 is smaller than two of the
    prompts, so they take multiple chunks (and chunk shapes stay a small
    power-of-two set -> bounded jit keys)."""
    lens = [6, 20, 33]
    ps = prompts(lens)
    eng_w = make_engine()
    for i, p in enumerate(ps):
        assert eng_w.submit(f"r{i}", p, 5)
    drain(eng_w)
    ref = {f"r{i}": eng_w.requests[f"r{i}"].tokens for i in range(3)}

    for budget in (6, 16):
        eng_c = make_engine(budget)
        assert eng_c.chunked is not None
        for i, p in enumerate(ps):
            assert eng_c.submit(f"r{i}", p, 5)
        drain(eng_c)
        for i in range(3):
            assert eng_c.requests[f"r{i}"].tokens == ref[f"r{i}"], \
                (budget, i)
        st = eng_c.chunked.stats
        assert st.requests == 3
        assert st.real_tokens == sum(n - 1 for n in lens)
        # shapes are powers of two bounded by the budget's pow2 ceiling
        assert all(s & (s - 1) == 0 for s in st.shapes)
        assert max(st.shapes) <= 2 * budget


def test_decode_interleaves_with_prefill():
    """A short request admitted together with a long one starts decoding
    while the long prompt is still streaming chunks — the point of
    bounding per-tick prefill work."""
    eng = make_engine(budget=4)
    short, long_ = prompts([4, 40])
    assert eng.submit("s", short, 8)
    assert eng.submit("l", long_, 4)
    eng.step()
    rs, rl = eng.requests["s"], eng.requests["l"]
    assert rl.prefilling and rl.prefill_cursor > 0
    assert len(rs.tokens) >= 1          # short decoded during long prefill
    drain(eng)
    assert len(rl.tokens) == 4


# --------------------------------------------------------------------------
# pad-free dispatch (satellite)
# --------------------------------------------------------------------------

def test_route_token_mask_excludes_pads_from_capacity():
    """Appending masked pad tokens must not change any real token's rank
    or keep decision, at the same capacity."""
    e, k, t = 8, 2, 12
    placement = ert_lib.default_placement(e, num_ew=2, num_shadow_slots=0)
    rs = refe.RouteState.healthy(placement, num_aw=1)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(t, 16)).astype(np.float32)
    logits = rng.normal(size=(t, e)).astype(np.float32)

    r_real = refe.route(jnp.asarray(x), jnp.asarray(logits), rs, placement,
                        top_k=k, capacity_factor=1.0, capacity=2, batch=t)

    n_pad = 12
    xp = np.concatenate([x, rng.normal(size=(n_pad, 16)).astype(np.float32)])
    lp = np.concatenate([logits,
                         rng.normal(size=(n_pad, e)).astype(np.float32)])
    mask = np.concatenate([np.ones(t, bool), np.zeros(n_pad, bool)])
    r_pad = refe.route(jnp.asarray(xp), jnp.asarray(lp), rs, placement,
                       top_k=k, capacity_factor=1.0, capacity=2,
                       batch=t + n_pad, token_mask=jnp.asarray(mask))

    np.testing.assert_array_equal(np.asarray(r_real["pos"]),
                                  np.asarray(r_pad["pos"])[:t])
    np.testing.assert_array_equal(np.asarray(r_real["keep"]),
                                  np.asarray(r_pad["keep"])[:t])
    # pads themselves are never kept
    assert not np.asarray(r_pad["keep"])[t:].any()


def test_padded_prefill_matches_exact_at_tight_capacity():
    """With the validity mask and real-token-derived capacity, the padded
    scheme is exact even at a tight capacity factor: bucket padding cannot
    evict (or re-rank) real tokens."""
    cfg = reduced("mixtral_8x7b", cap_factor=1.0)      # tight
    p = prompts([21], seed=3)[0]

    def run(bucket):
        ecfg = EngineConfig(max_batch=4, max_seq=64, num_aw=2, num_ew=2,
                            prefill_bucket=bucket)
        eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(0))
        assert eng.submit("r", p, 6)
        drain(eng)
        return eng.requests["r"].tokens

    padded = run(16)        # bucket 32: 12 pad columns
    exact = run(20)         # bucket 20: no padding (n-1 == 20)
    assert padded == exact


# --------------------------------------------------------------------------
# mid-prefill failure recovery
# --------------------------------------------------------------------------

def test_mid_prefill_failure_resumes_from_cursor():
    """AW dies after two chunks: recovery restores the committed prefix
    and resumes from the cursor. All chunk segments were flushed, so
    nothing is recomputed and the output matches the failure-free run."""
    p = prompts([40], seed=7)[0]
    n_pre = len(p) - 1

    eng0 = make_engine(budget=8)
    assert eng0.submit("r", p, 5)
    drain(eng0)
    ref = eng0.requests["r"].tokens

    eng = make_engine(budget=8)
    assert eng.submit("r", p, 5)
    r = eng.requests["r"]
    aw0 = r.aw
    for _ in range(2):
        eng.step()
    cursor_at_fail = r.prefill_cursor
    assert 0 < cursor_at_fail < n_pre          # genuinely mid-prefill
    eng.fail_aw(aw0)
    assert r.paused
    committed = eng.store.committed_token("r")
    assert committed == cursor_at_fail - 1     # every chunk was committed
    assert eng.recover_aw_requests(now=1.0) == ["r"]
    assert r.aw != aw0 and r.prefilling
    assert r.prefill_cursor == committed + 1   # resumed, NOT from token 0
    drain(eng)
    assert eng.requests["r"].tokens == ref

    st = eng.chunked.stats
    assert st.resumed == 1
    assert st.restored_tokens["r"] == cursor_at_fail
    # zero recompute: total prefilled work == the prompt prefix, exactly
    assert st.prefilled_tokens["r"] == n_pre


def test_mid_prefill_failure_recomputes_only_uncommitted_tail():
    """With a WR reorder window, the last chunk's segments are still
    pending on the AW when it dies; they never commit, and exactly that
    tail is recomputed after recovery."""
    p = prompts([40], seed=7)[0]
    n_pre = len(p) - 1

    eng0 = make_engine(budget=8)
    assert eng0.submit("r", p, 5)
    drain(eng0)
    ref = eng0.requests["r"].tokens

    eng = make_engine(budget=8, checkpoint_reorder=6)
    assert eng.submit("r", p, 5)
    r = eng.requests["r"]
    aw0 = r.aw
    # drive the plane directly: no decode step, so no end-of-step flush
    eng.chunked.tick(0.0)
    eng.chunked.tick(0.0)
    cursor_at_fail = r.prefill_cursor
    assert cursor_at_fail == 16
    assert len(eng.aws[aw0].checkpointer._pending) > 0
    eng.fail_aw(aw0)                           # pending WRs die with the AW
    committed = eng.store.committed_token("r")
    assert committed < cursor_at_fail - 1      # an uncommitted tail exists
    assert committed >= 0                      # but earlier chunks committed
    eng.recover_aw_requests(now=1.0)
    assert r.prefill_cursor == committed + 1
    drain(eng)
    assert eng.requests["r"].tokens == ref
    recomputed = eng.chunked.stats.prefilled_tokens["r"] - n_pre
    assert recomputed == cursor_at_fail - (committed + 1)
    assert 0 < recomputed < cursor_at_fail     # tail only, never from 0


def test_mid_prefill_failure_through_orchestrator():
    """Integration: the failure lands through the serving loop while long
    prompts are mid-stream; every request still completes with the
    failure-free outputs."""
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)

    def run(failures):
        ecfg = EngineConfig(max_batch=8, max_seq=64, num_aw=2, num_ew=2,
                            chunk_token_budget=8)
        eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(1))
        orch = Orchestrator(eng, worker_init_time=0.6)
        wl = make_workload("random", rate_rps=4.0, duration=1.0, seed=6)
        wl = [dataclasses.replace(w, arrival=0.0, prompt_len=30 + 3 * i,
                                  max_new_tokens=5)
              for i, w in enumerate(wl)][:4]
        m = run_serving(eng, wl, duration=200.0, orchestrator=orch,
                        failures=failures, step_time=0.05)
        return eng, m

    eng_ref, m_ref = run([])
    eng, m = run([FailurePlan(0.0, "aw", 0)])
    assert len(m.finished) == len(m_ref.finished) == 4
    assert eng.chunked.stats.resumed >= 1      # someone was mid-prefill
    for rid, toks in m_ref.outputs.items():
        assert m.outputs[rid] == toks, rid


def test_budget_larger_than_cache_extent_is_clamped():
    """A budget whose pow2 ceiling exceeds max_seq must not crash the
    chunk-shape set or the bulk checkpoint extractor — shapes are clamped
    to the largest power of two fitting the cache."""
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=4, max_seq=96, num_aw=2, num_ew=2,
                        chunk_token_budget=80)
    eng = InferenceEngine(cfg, ecfg, jax.random.PRNGKey(0))
    assert eng.chunked.max_shape == 64
    p = prompts([80], seed=9)[0]
    assert eng.submit("r", p, 3)
    drain(eng)
    assert len(eng.requests["r"].tokens) == 3
    assert max(eng.chunked.stats.shapes) <= 64


def test_commit_watermark_survives_repeated_failures():
    """A dropped pending WR must not leave a permanent sequence gap:
    restoration truncates the log to the commit record, so segments
    checkpointed after recovery still commit — a second failure rewinds
    to the *latest* watermark, not the pre-first-failure one."""
    p = prompts([40], seed=7)[0]

    eng0 = make_engine(budget=8)
    assert eng0.submit("r", p, 5)
    drain(eng0)
    ref = eng0.requests["r"].tokens

    eng = make_engine(budget=8, checkpoint_reorder=6)
    assert eng.submit("r", p, 5)
    r = eng.requests["r"]
    aw_first = r.aw
    eng.chunked.tick(0.0)
    eng.chunked.tick(0.0)
    eng.fail_aw(aw_first)              # pending WRs die -> seq gap
    first_committed = eng.store.committed_token("r")
    eng.recover_aw_requests(now=1.0)
    for _ in range(6):                 # finish prefill + some decode
        eng.step()
    assert not r.prefilling and len(r.tokens) >= 1
    # post-recovery checkpoints commit past the first watermark
    assert eng.store.committed_token("r") > first_committed
    eng.provision_aw(aw_first)         # capacity for the second recovery
    eng.fail_aw(r.aw)
    eng.recover_aw_requests(now=2.0)
    drain(eng)
    assert eng.requests["r"].tokens == ref


# --------------------------------------------------------------------------
# token-aware admission + workload generator (satellites)
# --------------------------------------------------------------------------

def test_gateway_counts_outstanding_prefill_tokens():
    """Slots alone no longer gate admission: with a prefill token cap, the
    Gateway holds back fresh prompts while the plane is saturated, and
    admits them as the stream drains."""
    eng = make_engine(budget=8, prefill_token_cap=48)
    ps = prompts([40, 40, 40])
    for i, p in enumerate(ps):
        eng.gateway.enqueue(f"r{i}", p, 4, now=0.0)
    eng.scheduler.admit(0.0)
    # slots are plentiful (8), but 40 + 40 > 48: only one admitted
    assert "r0" in eng.requests and "r1" not in eng.requests
    assert eng.gateway.depth() == 2
    assert eng.gateway.stats.blocked_ticks >= 1
    drain(eng)                                  # plane drains -> admissions
    assert all(len(eng.requests[f"r{i}"].tokens) == 4 for i in range(3))


def test_recovery_entries_bypass_token_cap():
    """A preempted request's re-admission restores from the store; it must
    not be blocked behind the fresh-prefill token cap."""
    eng = make_engine(budget=8, prefill_token_cap=48)
    p = prompts([40])[0]
    assert eng.submit("r", p, 4)
    for _ in range(2):
        eng.step()
    # saturate the cap with queued fresh work, then fail the AW
    for i, q in enumerate(prompts([40, 40], seed=2)):
        eng.gateway.enqueue(f"q{i}", q, 2, now=0.0)
    eng.fail_aw(eng.requests["r"].aw)
    assert eng.recover_aw_requests(now=1.0) == ["r"]
    drain(eng)
    assert len(eng.requests["r"].tokens) == 4


def test_long_prompt_burst_workload_shape():
    wl = make_workload("long_prompt_burst", rate_rps=30.0, duration=2.0,
                       seed=0, max_prompt=64, max_new=32)
    assert len(wl) > 10
    lens = np.asarray([w.prompt_len for w in wl])
    arr = np.asarray([w.arrival for w in wl])
    assert (np.diff(arr) >= 0).all() and arr.min() >= 0.0
    assert arr.max() <= 2.0
    # bimodal: both a short mode and a long (>= max_prompt/2) mode present
    assert (lens >= 32).any() and (lens < 8).any()
    assert lens.max() <= 64
    # bursts: several arrivals packed within one burst spread
    gaps = np.diff(arr)
    assert (gaps < 0.021).sum() >= len(wl) // 3


def test_workload_exposed_in_example():
    import ast
    src = open("examples/serve_workload.py").read()
    assert "long_prompt_burst" in src
    ast.parse(src)
