"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced variant of the same family, runs one forward/train step and one
prefill+decode step on CPU with shape checks and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import all_arch_ids, make_batch, reduced
from repro.models import get_model
from repro.training import init_opt_state, make_train_step


@pytest.mark.parametrize("arch", all_arch_ids())
def test_forward_shapes_no_nans(arch, key):
    cfg = reduced(arch)
    api = get_model(cfg, num_aw=2, num_ew=2)
    params = api.init_params(key)
    rs = api.init_route_state()
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    logits, aux = api.forward_train(params, batch, rs)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", all_arch_ids())
def test_prefill_decode_step(arch, key):
    cfg = reduced(arch)
    api = get_model(cfg, num_aw=2, num_ew=2)
    params = api.init_params(key)
    rs = api.init_route_state()
    b, s = 2, 12
    batch = make_batch(cfg, b, s)
    last, cache = api.prefill(params, batch, rs, max_seq=s + 8)
    assert last.shape == (b, cfg.vocab_size)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    pos = jnp.full((b,), s, jnp.int32)
    logits, cache2 = api.decode(params, tok, pos, cache, rs)
    assert logits.shape == (b, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache pytree structure preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "mixtral_8x7b",
                                  "zamba2_7b", "xlstm_350m",
                                  "whisper_small"])
def test_train_step_runs(arch, key):
    cfg = reduced(arch)
    api = get_model(cfg, num_aw=1, num_ew=2)
    params = api.init_params(key)
    rs = api.init_route_state()
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(api, lr=1e-3))
    batch = make_batch(cfg, 2, 8, with_labels=True)
    params2, opt2, loss = step(params, opt, batch, rs)
    assert np.isfinite(float(loss))
    assert int(opt2.step) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "xlstm_350m"])
def test_loss_decreases(arch, key):
    cfg = reduced(arch)
    api = get_model(cfg, num_aw=1, num_ew=1)
    params = api.init_params(key)
    rs = api.init_route_state()
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(api, lr=3e-3))
    batch = make_batch(cfg, 2, 8, with_labels=True)
    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt, batch, rs)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
