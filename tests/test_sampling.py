"""Decode head: greedy vs temperature/top-k sampling, and the decode-path
routing capacity override (EngineConfig.capacity_factor_decode)."""
import jax
import numpy as np
import pytest

from conftest import reduced
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPT = np.arange(1, 9, dtype=np.int32)


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=4, max_seq=48, num_aw=2, num_ew=2, **kw)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(2))


def test_sampled_decode_valid_and_seed_deterministic():
    a = make_engine(greedy=False, temperature=0.8, top_k=8,
                    sample_seed=5).generate("r", PROMPT, 12)
    b = make_engine(greedy=False, temperature=0.8, top_k=8,
                    sample_seed=5).generate("r", PROMPT, 12)
    assert a == b                       # same sample seed -> same stream
    vocab = make_engine().cfg.vocab_size
    assert len(a) == 12 and all(0 <= t < vocab for t in a)


def test_sampling_differs_from_greedy():
    greedy = make_engine().generate("r", PROMPT, 12)
    hot = make_engine(greedy=False, temperature=5.0,
                      sample_seed=1).generate("r", PROMPT, 12)
    assert hot != greedy


def test_top_k_one_equals_greedy():
    """top_k=1 collapses the distribution to the argmax token."""
    greedy = make_engine().generate("r", PROMPT, 10)
    k1 = make_engine(greedy=False, temperature=0.7, top_k=1,
                     sample_seed=9).generate("r", PROMPT, 10)
    assert k1 == greedy


def test_capacity_factor_decode_plumbed():
    eng_default = make_engine()
    assert eng_default.decode_capacity is None
    # cf_decode matching the model's factor: same capacity value the
    # routing would derive itself -> identical tokens
    eng_same = make_engine(capacity_factor_decode=4.0)
    assert eng_same.decode_capacity == \
        round(4.0 * eng_same.cfg.moe.top_k * eng_same.ecfg.max_batch /
              eng_same.cfg.moe.num_experts)
    ref = eng_default.generate("r", PROMPT, 10)
    assert eng_same.generate("r", PROMPT, 10) == ref
    # a tight decode capacity degrades (drops tokens at capacity) but must
    # keep decoding valid token ids
    eng_tight = make_engine(capacity_factor_decode=0.25)
    assert eng_tight.decode_capacity == 1
    toks = eng_tight.generate("r", PROMPT, 10)
    assert len(toks) == 10
    assert all(0 <= t < eng_tight.cfg.vocab_size for t in toks)
