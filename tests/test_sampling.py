"""Decode head: greedy vs temperature/top-k sampling, and the decode-path
routing capacity override (EngineConfig.capacity_factor_decode)."""
import jax
import numpy as np
import pytest

from conftest import reduced
from repro.serving.engine import EngineConfig, InferenceEngine

PROMPT = np.arange(1, 9, dtype=np.int32)


def make_engine(**kw):
    cfg = reduced("mixtral_8x7b", cap_factor=4.0)
    ecfg = EngineConfig(max_batch=4, max_seq=48, num_aw=2, num_ew=2, **kw)
    return InferenceEngine(cfg, ecfg, jax.random.PRNGKey(2))


def test_sampled_decode_valid_and_seed_deterministic():
    a = make_engine(greedy=False, temperature=0.8, top_k=8,
                    sample_seed=5).generate("r", PROMPT, 12)
    b = make_engine(greedy=False, temperature=0.8, top_k=8,
                    sample_seed=5).generate("r", PROMPT, 12)
    assert a == b                       # same sample seed -> same stream
    vocab = make_engine().cfg.vocab_size
    assert len(a) == 12 and all(0 <= t < vocab for t in a)


def test_sampling_differs_from_greedy():
    greedy = make_engine().generate("r", PROMPT, 12)
    hot = make_engine(greedy=False, temperature=5.0,
                      sample_seed=1).generate("r", PROMPT, 12)
    assert hot != greedy


def test_top_k_one_equals_greedy():
    """top_k=1 collapses the distribution to the argmax token."""
    greedy = make_engine().generate("r", PROMPT, 10)
    k1 = make_engine(greedy=False, temperature=0.7, top_k=1,
                     sample_seed=9).generate("r", PROMPT, 10)
    assert k1 == greedy


def test_sample_token_shim_distribution_equivalence():
    """The rewritten host shim (top-k sliced *before* the float32 softmax,
    counter-based Philox draw) samples from the same distribution as the
    historical formula (float64 softmax over the full vocab with
    sub-threshold logits masked to -inf)."""
    eng = make_engine(greedy=False, temperature=0.7, top_k=8, sample_seed=0)
    rng = np.random.default_rng(5)
    logits = (rng.standard_normal(64) * 3).astype(np.float32)

    scaled = logits.astype(np.float64) / 0.7          # historical formula
    kth = np.partition(scaled, -8)[-8]
    masked = np.where(scaled >= kth, scaled, -np.inf)
    p_old = np.exp(masked - masked.max())
    p_old /= p_old.sum()

    n = 4000
    counts = np.zeros(64)
    for pos in range(n):                   # counter-based: pos is the draw
        counts[eng.sample_token(logits, pos=pos)] += 1
    freq = counts / n
    # support is exactly the top-k set, and frequencies match within
    # sampling noise (4 sigma at n=4000 is ~0.03)
    assert freq[p_old == 0.0].sum() == 0.0
    assert np.abs(freq - p_old).max() < 0.03


def test_sample_token_shim_counter_reproducible():
    """Same (seed, pos) => same draw; the shim holds no RNG state."""
    eng = make_engine(greedy=False, temperature=0.9, top_k=6)
    rng = np.random.default_rng(8)
    logits = rng.standard_normal(48).astype(np.float32)
    a = [eng.sample_token(logits, seed=4, pos=p) for p in range(12)]
    b = [eng.sample_token(logits, seed=4, pos=p) for p in range(12)]
    assert a == b
    assert a != [eng.sample_token(logits, seed=5, pos=p) for p in range(12)]


def test_capacity_factor_decode_plumbed():
    eng_default = make_engine()
    assert eng_default.decode_capacity is None
    # cf_decode matching the model's factor: same capacity value the
    # routing would derive itself -> identical tokens
    eng_same = make_engine(capacity_factor_decode=4.0)
    assert eng_same.decode_capacity == \
        round(4.0 * eng_same.cfg.moe.top_k * eng_same.ecfg.max_batch /
              eng_same.cfg.moe.num_experts)
    ref = eng_default.generate("r", PROMPT, 10)
    assert eng_same.generate("r", PROMPT, 10) == ref
    # a tight decode capacity degrades (drops tokens at capacity) but must
    # keep decoding valid token ids
    eng_tight = make_engine(capacity_factor_decode=0.25)
    assert eng_tight.decode_capacity == 1
    toks = eng_tight.generate("r", PROMPT, 10)
    assert len(toks) == 10
    assert all(0 <= t < eng_tight.cfg.vocab_size for t in toks)
